"""Device prep == numpy prep, and the batched ``count_many`` lane.

The device-resident prep pipeline (``repro.core.prep`` over the jitted
stages in ``repro.graphs.device``) must reproduce the numpy parity path
bit-for-bit: orientation (row_ptr + ordered edge list), bucket contents
(u/v neighbor lists, edge endpoints, widths, sentinel padding), the 2-core
peel mask, and the sort-based CSR build — on adversarial graphs (empty,
isolated vertices, star, clique with its all-equal degree ties, paths) and
on a hypothesis sweep of random multigraph edge lists.

The batching half covers ``TriangleCounter.count_many``: batch-vs-loop
agreement, lazy (chunked) consumption of generators, and the acceptance
assertion that ≥ 8 same-policy graphs are counted by ONE vmapped dispatch
from the shape-policy-keyed batch-executable cache.
"""

import numpy as np
import pytest

from repro.graphs import (
    complete_graph,
    edges_to_csr,
    grid_graph,
    path_graph,
    rmat_graph,
    star_graph,
)
from repro.graphs.device import (
    DEFAULT_SHAPE_POLICY,
    DeviceCSR,
    DeviceGraph,
    ShapePolicy,
    next_pow2,
)
from repro.graphs.formats import orient_forward
from repro.core import (
    CountOptions,
    GraphBatch,
    TriangleCounter,
    executable_cache_info,
    plan_triangle_count,
    prep,
    triangle_count_scipy,
)
import repro.core.api as api_module

# duplicate-degree ties everywhere (clique), leaf cascades (star/path/grid
# spurs), empty rows (isolated vertices), zero edges (empty)
ADVERSARIAL = [
    edges_to_csr([], [], n=6, name="empty6"),
    edges_to_csr([0, 1], [1, 2], n=9, name="isolated9"),
    star_graph(16),
    complete_graph(9),
    path_graph(10),
    grid_graph(5, spur_fraction=0.5, seed=3),
    rmat_graph(6, 8, seed=7),
]
_IDS = [g.name for g in ADVERSARIAL]


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
@pytest.mark.parametrize("variant", ["filtered", "full"])
def test_device_buckets_match_host(g, variant):
    host = prep.prepare_intersection_buckets_host(g, variant=variant)
    dev = prep.prepare_intersection_buckets_device(g, variant=variant)
    assert len(host) == len(dev)
    for hb, db in zip(host, dev):
        e = hb["u_lists"].shape[0]
        assert db.width == hb["width"]
        assert db.edges == e
        assert db.e_pad == DEFAULT_SHAPE_POLICY.round_edges(e)
        np.testing.assert_array_equal(np.asarray(db.u_lists)[:e],
                                      hb["u_lists"])
        np.testing.assert_array_equal(np.asarray(db.v_lists)[:e],
                                      hb["v_lists"])
        np.testing.assert_array_equal(np.asarray(db.src)[:e], hb["src"])
        np.testing.assert_array_equal(np.asarray(db.dst)[:e], hb["dst"])
        # whole-row padding uses the repo-wide disjoint sentinels
        assert (np.asarray(db.u_lists)[e:] == -1).all()
        assert (np.asarray(db.v_lists)[e:] == -2).all()


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
def test_device_orientation_matches_host(g):
    dag = orient_forward(g)
    fwd = DeviceGraph.from_graph(g).forward()
    kept = dag.m_directed
    assert fwd.m == kept == g.m_directed // 2
    np.testing.assert_array_equal(np.asarray(fwd.row_ptr), dag.row_ptr)
    np.testing.assert_array_equal(np.asarray(fwd.degrees), dag.degrees)
    host_src, host_dst = dag.edge_endpoints()
    np.testing.assert_array_equal(np.asarray(fwd.src)[:kept], host_src)
    np.testing.assert_array_equal(np.asarray(fwd.dst)[:kept], host_dst)
    assert bool(np.asarray(fwd.kvalid)[:kept].all())
    assert not np.asarray(fwd.kvalid)[kept:].any()


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
def test_device_peel_matches_host(g):
    host = prep.peel_to_two_core(g)
    dev = np.asarray(prep.peel_to_two_core_device(DeviceGraph.from_graph(g)))
    np.testing.assert_array_equal(dev, host)


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
def test_device_csr_from_edges_matches_host(g):
    src, dst = g.edge_endpoints()
    # shuffle to exercise the sort (the builder must not rely on CSR order)
    rng = np.random.default_rng(0)
    order = rng.permutation(src.shape[0])
    csr = DeviceCSR.from_edges(src[order], dst[order], g.n)
    assert csr.m == g.m_directed
    np.testing.assert_array_equal(np.asarray(csr.row_ptr), g.row_ptr)
    np.testing.assert_array_equal(np.asarray(csr.col_idx)[:csr.m], g.col_idx)
    assert (np.asarray(csr.col_idx)[csr.m:] == g.n).all()


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
def test_tile_schedule_wrapper_matches_prep(g):
    from repro.core.engine import build_tile_schedule

    l1, u1, a1, s1 = build_tile_schedule(g, block=16)
    l2, u2, a2, s2 = prep.build_tile_schedule(g, block=16)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(u1, u2)
    np.testing.assert_array_equal(a1, a2)
    assert s1 == s2


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
@pytest.mark.parametrize("algorithm", ["intersection", "subgraph"])
def test_device_and_host_plans_agree_with_oracle(g, algorithm):
    truth = triangle_count_scipy(g)
    dev = plan_triangle_count(g, algorithm, prep_backend="device")
    host = plan_triangle_count(g, algorithm, prep_backend="host")
    assert dev.count() == host.count() == truth
    assert dev.meta["prep_backend"] == "device"
    assert host.meta["prep_backend"] == "host"


def test_device_planning_runs_no_host_numpy_prep(monkeypatch):
    """Tentpole acceptance: under ``prep_backend="device"`` (the default)
    plan CONSTRUCTION never touches the numpy prep helpers — the old poison
    test only guarded ``count()`` after planning."""

    def _boom(*a, **k):
        raise AssertionError("host numpy prep ran under prep_backend='device'")

    for name in ("prepare_intersection_buckets_host", "orient_forward",
                 "bucket_edges_by_degree", "csr_to_padded_neighbors",
                 "peel_to_two_core"):
        monkeypatch.setattr(prep, name, _boom)
    g = rmat_graph(6, 6, seed=5)
    truth = triangle_count_scipy(g)
    assert plan_triangle_count(g, "intersection").count() == truth
    assert plan_triangle_count(g, "intersection", variant="full").count() \
        == truth
    assert plan_triangle_count(g, "subgraph").count() == truth


def test_shape_policy_rounding_and_validation():
    p = ShapePolicy()
    assert p.round_edges(0) == p.min_edges
    assert p.round_edges(9) == 16
    assert p.round_edges(1000) == 1024
    assert ShapePolicy(edge_rounding="exact").round_edges(9) == 9
    assert next_pow2(0) == 1 and next_pow2(5) == 8 and next_pow2(8) == 8
    with pytest.raises(ValueError):
        ShapePolicy(edge_rounding="pow3")
    with pytest.raises(ValueError):
        ShapePolicy(min_edges=0)
    # options validation + key participation
    with pytest.raises(ValueError):
        CountOptions(prep_backend="gpu")
    with pytest.raises(ValueError):
        CountOptions(shape_policy="pow2")
    o_def = CountOptions()
    assert o_def.key() == CountOptions(shape_policy=ShapePolicy()).key()
    assert o_def.key() != CountOptions(
        shape_policy=ShapePolicy(edge_rounding="exact")).key()
    assert o_def.key() != CountOptions(prep_backend="host").key()


def test_exact_policy_plans_still_agree():
    g = rmat_graph(6, 6, seed=11)
    truth = triangle_count_scipy(g)
    exact = ShapePolicy(edge_rounding="exact", min_edges=1)
    plan = plan_triangle_count(g, "intersection", shape_policy=exact)
    assert plan.count() == truth
    # exact rounding reproduces the host shapes bit for bit
    host = plan_triangle_count(g, "intersection", prep_backend="host")
    assert plan.shape_keys == host.shape_keys


# --- hypothesis sweep -------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: skip, don't error
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    def _graph_strategy(max_n=28, max_m=100):
        return st.integers(2, max_n).flatmap(
            lambda n: st.tuples(
                st.just(n),
                st.lists(st.tuples(st.integers(0, n - 1),
                                   st.integers(0, n - 1)),
                         min_size=0, max_size=max_m),
            ))

    @given(_graph_strategy())
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_device_prep_parity(spec):
        n, edges = spec
        src = np.array([e[0] for e in edges], dtype=np.int64)
        dst = np.array([e[1] for e in edges], dtype=np.int64)
        g = edges_to_csr(src, dst, n=n)
        # bucket contents
        host = prep.prepare_intersection_buckets_host(g)
        dev = prep.prepare_intersection_buckets_device(g)
        assert [b["width"] for b in host] == [b.width for b in dev]
        for hb, db in zip(host, dev):
            e = hb["u_lists"].shape[0]
            np.testing.assert_array_equal(np.asarray(db.u_lists)[:e],
                                          hb["u_lists"])
            np.testing.assert_array_equal(np.asarray(db.v_lists)[:e],
                                          hb["v_lists"])
        # peel + end-to-end counts
        np.testing.assert_array_equal(
            np.asarray(prep.peel_to_two_core_device(DeviceGraph.from_graph(g))),
            prep.peel_to_two_core(g))
        truth = triangle_count_scipy(g)
        assert plan_triangle_count(g, "intersection").count() == truth
        assert plan_triangle_count(g, "subgraph").count() == truth


# --- count_many batching ----------------------------------------------------

def test_count_many_batch_agrees_with_loop():
    graphs = ([rmat_graph(6, 5, seed=s) for s in range(5)]
              + [star_graph(12), complete_graph(10),
                 grid_graph(6, spur_fraction=0.3, seed=8)])
    opts = CountOptions(algorithm="intersection")
    tc = TriangleCounter(graphs[0], opts)
    res = tc.count_many(graphs, batch_size=4)
    assert len(res) == len(graphs)
    for g, r in zip(graphs, res):
        assert r == triangle_count_scipy(g), g.name
        assert r == TriangleCounter(g, opts).count()
    # the session's own graph reused the session plan
    assert res[0].plan is tc.plan


def test_count_many_consumes_generators_lazily():
    pulls = []

    def gen():
        for s in range(12):
            pulls.append(s)
            yield rmat_graph(5, 4, seed=s)

    tc = TriangleCounter(rmat_graph(5, 4, seed=99),
                         CountOptions(algorithm="intersection"))
    it = tc.iter_counts(gen(), batch_size=3)
    next(it)
    # only the first chunk was pulled before the first result
    assert len(pulls) == 3
    rest = list(it)
    assert len(rest) == 11 and len(pulls) == 12


def test_count_many_issues_one_vmapped_dispatch(monkeypatch):
    """Acceptance: ≥ 8 same-policy graphs → ONE GraphBatch, ONE device
    dispatch, no per-graph sessions, no host prep — and a second batch of
    the same shape class compiles nothing new (cache-stats assertion)."""
    graphs = [rmat_graph(6, 6, seed=60 + s) for s in range(8)]
    opts = CountOptions(algorithm="intersection")
    tc = TriangleCounter(rmat_graph(6, 6, seed=59), opts)

    def _boom(*a, **k):
        raise AssertionError("per-graph fallback ran for a batchable graph")

    monkeypatch.setattr(api_module, "TriangleCounter", _boom)
    monkeypatch.setattr(prep, "prepare_intersection_buckets_host", _boom)
    res = tc.count_many(iter(graphs), batch_size=8)
    assert len(res) == 8
    batch = res[0].plan
    assert isinstance(batch, GraphBatch)
    assert all(r.plan is batch for r in res)
    assert batch.executions == 1  # one vmapped dispatch for the whole chunk
    for g, r in zip(graphs, res):
        assert r == triangle_count_scipy(g)
        assert r.meta["batched"] and r.meta["batch_size"] == 8

    # same shape class again: the batch-plan cache serves everything
    info1 = executable_cache_info()
    res2 = tc.count_many(iter(graphs), batch_size=8)
    info2 = executable_cache_info()
    assert [int(r) for r in res2] == [int(r) for r in res]
    assert info2["misses"] == info1["misses"]
    assert info2["hits"] > info1["hits"]


def test_count_many_batch_size_validation():
    tc = TriangleCounter(rmat_graph(5, 4, seed=1))
    with pytest.raises(ValueError):
        list(tc.iter_counts([], batch_size=0))


def test_graph_batch_rejects_unbatchable_options():
    graphs = [rmat_graph(5, 4, seed=s) for s in range(2)]
    with pytest.raises(ValueError):
        GraphBatch.from_graphs([], CountOptions(algorithm="intersection"))
    with pytest.raises(ValueError):
        GraphBatch.from_graphs(
            graphs, CountOptions(algorithm="intersection", backend="pallas"))
    with pytest.raises(ValueError):
        GraphBatch.from_graphs(
            graphs,
            CountOptions(algorithm="intersection", prep_backend="host"))


def test_graph_batch_heterogeneous_sizes_and_variants():
    """Mixed n / mixed layouts harmonize via padding; full variant's ×6
    divisor applies per graph."""
    graphs = [star_graph(30), complete_graph(12), rmat_graph(5, 6, seed=2),
              edges_to_csr([], [], n=4, name="empty4")]
    truth = [triangle_count_scipy(g) for g in graphs]
    for variant in ("filtered", "full"):
        batch = GraphBatch.from_graphs(
            graphs, CountOptions(algorithm="intersection", variant=variant))
        assert [int(c) for c in batch.counts()] == truth, variant
