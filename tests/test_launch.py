"""Launcher machinery tests that don't need placeholder devices."""

import jax.numpy as jnp
import pytest

from repro.launch.specs import SHAPES, cell_spec, input_specs, skip_reason
from repro.models.registry import ARCHS, get_config


def test_shapes_cover_assignment():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"]["global_batch"] == 256
    assert SHAPES["long_500k"]["seq_len"] == 524288


def test_skip_rules():
    # sub-quadratic archs run long_500k; quadratic ones skip it
    assert skip_reason(get_config("mamba2-780m"), "long_500k") is None
    assert skip_reason(get_config("recurrentgemma-9b"), "long_500k") is None
    for arch in ("gemma2-2b", "qwen1.5-4b", "arctic-480b", "whisper-medium",
                 "paligemma-3b"):
        assert skip_reason(get_config(arch), "long_500k") is not None
    assert skip_reason(get_config("gemma2-2b"), "train_4k") is None


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_train(arch):
    specs = input_specs(arch, "train_4k")
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].shape == (256, 4096)
    cfg = get_config(arch)
    if cfg.family == "encdec":
        assert specs["frames"].shape == (256, cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm":
        assert specs["patches"].shape == (256, cfg.vision_tokens,
                                          cfg.vision_dim)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-780m",
                                  "recurrentgemma-9b", "whisper-medium",
                                  "qwen1.5-32b"])
def test_input_specs_decode_cache_abstract(arch):
    """Decode specs build abstract caches without allocating."""
    specs = input_specs(arch, "decode_32k")
    assert specs["tokens"].shape == (128, 1)
    import jax
    leaves = jax.tree.leaves(specs["cache"])
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    if arch == "qwen1.5-32b":  # int8 KV cache config
        dtypes = {str(l.dtype) for l in leaves}
        assert "int8" in dtypes


def test_model_flops_accounting():
    from repro.launch.dryrun import _model_flops_per_chip

    cfg = get_config("gemma2-2b")
    cell = cell_spec("gemma2-2b", "train_4k")
    f = _model_flops_per_chip(cfg, cell, 256)
    want = 6 * cfg.param_count() * 256 * 4096 / 256
    assert abs(f - want) / want < 1e-6


def test_report_roundtrip(tmp_path):
    import json
    from repro.launch.report import load, roofline_table, summary

    rec = dict(arch="a", shape="s", mesh="16x16", status="ok",
               memory={"temp_size_in_bytes": 1}, kind="train", chips=256,
               roofline=dict(t_compute=1.0, t_memory=2.0, t_collective=0.5,
                             dominant="memory", useful_ratio=0.5, flops=1,
                             hbm_bytes=1, coll_bytes=1, coll_by_kind={},
                             model_flops=1))
    p = tmp_path / "d.jsonl"
    p.write_text(json.dumps(rec) + "\n")
    recs = load(str(p))
    assert "1 ok" in summary(recs)
    assert "| a | s | ok " in roofline_table(recs)
