"""Executable pricing on triangle-count workloads (PR 7 satellite).

``launch/hlo_cost.py`` and ``launch/roofline.py`` were written for the
training substrate and sat unused by the counting side until the measured
chooser (``core/calibrate.py``) adopted them as its analytic cold-start.
That promotion makes their numbers load-bearing, so this module pins them
three ways:

* **golden-file parses** — hand-written HLO under ``tests/golden/`` with
  arithmetic small enough to check by hand: the dot module's exact
  flops/bytes, and the while module proving loop bodies are multiplied by
  ``known_trip_count`` (the whole reason ``analyze_hlo`` exists).
* **live executables** — a real intersection-lane stage is AOT-compiled
  and priced end to end (``analyze_hlo`` on the optimized HLO, then
  ``roofline_terms``), asserting the quantities the chooser consumes are
  positive, finite, and collective-free on a single device.
* **invariance** — pricing is a pure function of (graph, options):
  ``analytic_seed`` must return bit-identical numbers for equal
  ``CountOptions``, which is what makes cold-start choices deterministic.
"""

import math
import pathlib

import pytest

from repro.core import CountOptions
from repro.core.calibrate import (
    CHOOSER_LANES,
    analytic_seed,
    price_plan,
)
from repro.core.registry import get_algorithm
from repro.graphs import load_dataset
from repro.launch.hlo_cost import HloCost, analyze_hlo
from repro.launch.roofline import roofline_terms

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


def _golden(name: str) -> str:
    return (GOLDEN / name).read_text(encoding="utf-8")


def test_golden_dot_exact_flops_and_bytes():
    """f32[64,128] @ f32[128,32]: flops = 2·(64·32)·128, bytes = operands
    plus output, nothing else."""
    cost = analyze_hlo(_golden("hlo_dot.txt"))
    assert isinstance(cost, HloCost)
    assert cost.flops == 2.0 * (64 * 32) * 128  # 524288
    assert cost.bytes == (64 * 128 + 128 * 32 + 64 * 32) * 4  # 57344
    assert cost.coll_bytes == 0.0
    assert cost.coll_by_kind == {}


def test_golden_while_multiplies_by_trip_count():
    """The loop-awareness contract: body+cond cost × known_trip_count=8.

    Per iteration: the body add is 256 flops and 3·1024 bytes; the cond
    compare is 1 flop and 2·1024+1 bytes (pred[] scalar out)."""
    cost = analyze_hlo(_golden("hlo_while.txt"))
    per_iter_flops = 256 + 1
    per_iter_bytes = 3 * 1024 + (2 * 1024 + 1)
    assert cost.flops == 8 * per_iter_flops
    assert cost.bytes == 8 * per_iter_bytes
    assert cost.coll_bytes == 0.0


def test_golden_entry_required():
    """No ENTRY computation ⇒ the zero cost, never a crash."""
    cost = analyze_hlo("%orphan (x: f32[4]) -> f32[4] {\n}\n")
    assert (cost.flops, cost.bytes, cost.coll_bytes) == (0.0, 0.0, 0.0)


@pytest.fixture(scope="module")
def tc_plan():
    g = load_dataset("tiny-rmat")
    return get_algorithm("intersection")(g, CountOptions())


def test_live_tc_executable_prices_positive(tc_plan):
    """A real counting stage AOT-compiles and prices to positive finite
    flops/bytes with zero collective traffic (single device)."""
    st = tc_plan.stages[0]
    compiled = st.executable.lower(*st.args).compile()
    cost = analyze_hlo(compiled.as_text())
    assert cost.flops > 0.0 and math.isfinite(cost.flops)
    assert cost.bytes > 0.0 and math.isfinite(cost.bytes)
    assert cost.coll_bytes == 0.0


def test_live_tc_roofline_terms(tc_plan):
    """roofline_terms on the same executable: both time terms positive,
    collective term zero, dominant named accordingly, and
    model_flops_per_chip=0 (the chooser's setting) is safe."""
    st = tc_plan.stages[0]
    compiled = st.executable.lower(*st.args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    terms = roofline_terms(dict(cost or {}), compiled.as_text(),
                           model_flops_per_chip=0.0)
    assert terms.t_compute > 0.0 and terms.t_memory > 0.0
    assert terms.t_collective == 0.0
    assert terms.dominant in ("compute", "memory")
    assert terms.useful_ratio == 0.0  # 0 model flops, guarded division
    assert price_plan(tc_plan) > 0.0


def test_analytic_seed_invariant_for_equal_options():
    """Two independently constructed but equal CountOptions price every
    lane bit-identically — the determinism the cold-start table rides on."""
    g = load_dataset("tiny-grid")
    a = analytic_seed(g, CHOOSER_LANES, CountOptions())
    b = analytic_seed(g, CHOOSER_LANES, CountOptions())
    assert set(a) == set(CHOOSER_LANES)
    assert a == b  # bit-identical floats, not approx
    for lane, t in a.items():
        assert t >= 0.0 and math.isfinite(t), lane
    # and repeat pricing of the SAME plan object is equally stable
    plan = get_algorithm("intersection")(g, CountOptions())
    assert price_plan(plan) == price_plan(plan)
