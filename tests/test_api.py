"""The front-door facade: CountOptions validation + hash stability,
algorithm="auto" lane choice, session plan caching, count_many batches,
per-vertex analysis through the plan, and the deprecation shims."""

import warnings

import numpy as np
import pytest

from repro.graphs import (
    available_datasets,
    complete_graph,
    grid_graph,
    load_dataset,
    path_graph,
    rmat_graph,
    star_graph,
)
from repro.core import (
    CountOptions,
    CountResult,
    TriangleCounter,
    available_algorithms,
    choose_algorithm,
    executable_cache_info,
    set_auto_chooser,
    triangle_count_scipy,
)
import repro.core.listing as listing


G_SKEWED = rmat_graph(8, 8, seed=41)  # scale-free: high degree skew
G_UNIFORM = grid_graph(12, spur_fraction=0.3, seed=42)  # mesh-like: uniform
G_DENSE = complete_graph(64)  # small dense: MXU tiles fill


# --- CountOptions validation & hashing --------------------------------------

@pytest.mark.parametrize("bad", [
    dict(algorithm="bogus"),
    dict(variant="half"),
    dict(backend="cuda"),
    dict(strategy="hash-join"),
    dict(widths=()),
    dict(widths=(8, 8, 32)),  # not strictly ascending
    dict(widths=(0, 8)),
    dict(block=-1),
    dict(block=1.5),
    dict(bitmap_bits=33),  # not a multiple of 32
    dict(bitmap_bits=1 << 20),  # over BITMAP_MAX_BITS
    dict(interpret="yes"),
    dict(permute="yes"),
])
def test_count_options_validation(bad):
    with pytest.raises(ValueError):
        CountOptions(**bad)


def test_count_options_hash_stability():
    o1 = CountOptions(algorithm="intersection", widths=[8, 32])  # list ok
    o2 = CountOptions(algorithm="intersection", widths=(8, 32))
    assert o1 == o2
    assert hash(o1) == hash(o2)
    assert o1.key() == o2.key()
    assert o1.widths == (8, 32)  # normalized to a tuple
    # interpret=None resolves to DEFAULT_INTERPRET inside key()
    from repro.core import DEFAULT_INTERPRET
    assert CountOptions(interpret=None).key() == \
        CountOptions(interpret=DEFAULT_INTERPRET).key()
    # replace() re-validates
    assert o1.replace(strategy="probe").strategy == "probe"
    with pytest.raises(ValueError):
        o1.replace(strategy="nope")


def test_equal_options_share_cached_executables():
    """Acceptance: two counters from equal CountOptions share one cached
    executable — no cache growth, no new misses on the second build."""
    g = rmat_graph(8, 6, seed=43)
    truth = triangle_count_scipy(g)
    o1 = CountOptions(algorithm="intersection")
    o2 = CountOptions(algorithm="intersection")
    assert o1 == o2 and hash(o1) == hash(o2)
    c1 = TriangleCounter(g, o1)
    assert c1.count() == truth
    info1 = executable_cache_info()
    c2 = TriangleCounter(g, o2)
    assert c2.count() == truth
    info2 = executable_cache_info()
    assert info2["size"] == info1["size"]
    assert info2["misses"] == info1["misses"]
    assert info2["hits"] > info1["hits"]


# --- the edge lane's cache keys ---------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(max_peel_iters=0),
    dict(max_peel_iters=-3),
    dict(max_peel_iters=2.5),
    dict(max_peel_iters=True),  # bools are not iteration counts
    dict(peel_early_exit="yes"),
])
def test_peel_knob_validation(bad):
    with pytest.raises(ValueError):
        CountOptions(**bad)


def test_peel_knobs_participate_in_options_key():
    base = CountOptions(algorithm="edge")
    assert base.key() == CountOptions(algorithm="edge").key()
    assert base.key() != CountOptions(algorithm="edge",
                                      max_peel_iters=7).key()
    assert base.key() != CountOptions(algorithm="edge",
                                      peel_early_exit=False).key()


def test_equal_edge_options_share_cached_edge_executables():
    """Satellite acceptance (the test_prep_parity one-dispatch pattern):
    two sessions from equal CountOptions — peel knobs included — share the
    cached edge executables: no cache growth, no new misses, hits grow."""
    g = rmat_graph(7, 6, seed=46)
    truth = triangle_count_scipy(g)
    o1 = CountOptions(algorithm="edge", max_peel_iters=50)
    o2 = CountOptions(algorithm="edge", max_peel_iters=50)
    assert o1 == o2 and hash(o1) == hash(o2) and o1.key() == o2.key()
    c1 = TriangleCounter(g, o1)
    assert c1.count() == truth
    info1 = executable_cache_info()
    c2 = TriangleCounter(g, o2)
    assert c2.count() == truth
    info2 = executable_cache_info()
    assert info2["size"] == info1["size"]
    assert info2["misses"] == info1["misses"]
    assert info2["hits"] > info1["hits"]


@pytest.mark.parametrize("knobs", [
    dict(max_peel_iters=51),
    dict(peel_early_exit=False),
], ids=lambda d: next(iter(d)))
def test_unequal_peel_knobs_miss_the_edge_executable_cache(knobs):
    """Unequal peel knobs are distinct cache keys: a session differing only
    in a peel knob compiles its own edge executables (cache misses grow)."""
    g = rmat_graph(7, 6, seed=46)
    truth = triangle_count_scipy(g)
    base = CountOptions(algorithm="edge", max_peel_iters=50)
    assert TriangleCounter(g, base).count() == truth
    info1 = executable_cache_info()
    other = base.replace(**knobs)
    assert other.key() != base.key()
    assert TriangleCounter(g, other).count() == truth
    info2 = executable_cache_info()
    assert info2["misses"] > info1["misses"]
    assert info2["size"] > info1["size"]


def test_edge_sidecar_shares_session_options_executables():
    """k_truss from a non-edge session builds its sidecar from the SAME
    options, so a second session's sidecar compiles nothing new."""
    g = rmat_graph(7, 6, seed=47)
    t1 = TriangleCounter(g, CountOptions(algorithm="intersection"))
    t1.k_truss(3)
    info1 = executable_cache_info()
    t2 = TriangleCounter(g, CountOptions(algorithm="intersection"))
    t2.k_truss(3)
    info2 = executable_cache_info()
    assert info2["size"] == info1["size"]
    assert info2["misses"] == info1["misses"]


# --- algorithm="auto" -------------------------------------------------------

def test_auto_lane_choice_by_graph_shape():
    """The documented cost model: skewed scale-free -> intersection,
    uniform mesh-like -> subgraph, small dense -> matrix."""
    assert choose_algorithm(G_SKEWED) == "intersection"
    assert choose_algorithm(G_UNIFORM) == "subgraph"
    assert choose_algorithm(G_DENSE) == "matrix"


@pytest.mark.parametrize("g", [G_SKEWED, G_UNIFORM, G_DENSE,
                               star_graph(40), path_graph(40),
                               rmat_graph(9, 4, seed=44)],
                         ids=lambda g: g.name)
def test_auto_matches_oracle_and_reports_lane(g):
    res = TriangleCounter(g).count()
    assert isinstance(res, CountResult)
    assert res == triangle_count_scipy(g)
    assert res.algorithm in available_algorithms()
    assert res.options.algorithm == "auto"  # as written; resolution separate


@pytest.mark.parametrize("name", ["tiny-rmat", "tiny-grid"])
def test_auto_matches_oracle_on_datasets(name):
    g = load_dataset(name)
    res = TriangleCounter(g).count()
    assert res == triangle_count_scipy(g)


def test_auto_chooser_is_overridable():
    prev = set_auto_chooser(lambda g: "matrix")
    try:
        tc = TriangleCounter(G_SKEWED)  # would be intersection by default
        assert tc.algorithm == "matrix"
        assert tc.count() == triangle_count_scipy(G_SKEWED)
    finally:
        set_auto_chooser(prev)
    assert choose_algorithm(G_SKEWED) == "intersection"


# --- the session object -----------------------------------------------------

def test_session_owns_one_plan():
    tc = TriangleCounter(G_SKEWED, CountOptions(algorithm="intersection"))
    truth = triangle_count_scipy(G_SKEWED)
    r1, r2 = tc.count(), tc.count()
    assert r1 == r2 == truth
    assert r1.plan is r2.plan  # same cached plan replayed
    assert r1.plan.executions == 2
    assert r1.prep_seconds > 0.0 and r1.exec_seconds > 0.0


def test_counter_kwarg_overrides_match_options():
    a = TriangleCounter(G_SKEWED, algorithm="matrix", block=32)
    b = TriangleCounter(G_SKEWED,
                        CountOptions(algorithm="matrix", block=32))
    assert a.options == b.options
    assert a.count() == b.count() == triangle_count_scipy(G_SKEWED)
    with pytest.raises(TypeError):
        TriangleCounter(G_SKEWED, options="intersection")


def test_count_result_int_semantics():
    res = TriangleCounter(G_SKEWED).count()
    truth = triangle_count_scipy(G_SKEWED)
    assert res == truth and truth == int(res)
    assert res == np.int64(truth)
    assert not (res == truth + 1)
    assert res != "not-a-count"


def test_count_many_matches_per_graph_loop():
    batch = [rmat_graph(7, 6, seed=s) for s in (1, 2, 3)] + [G_UNIFORM]
    tc = TriangleCounter(batch[0])  # auto: per-graph lane resolution
    results = tc.count_many(batch)
    assert len(results) == len(batch)
    for g, res in zip(batch, results):
        assert res == triangle_count_scipy(g), g.name
        assert res == TriangleCounter(g).count()
    # the session's own graph reused the session plan
    assert results[0].plan is tc.plan


def test_count_many_mesh_fallback_warns_and_stays_correct():
    """Pin the documented mesh behavior (the sharded-GraphBatch baseline):
    distributed lanes are NOT batchable, so ``count_many`` under a mesh
    falls back to per-graph sessions — one ``UserWarning`` per session,
    results still exact. A 1-device mesh keeps this in-process (promotion
    only kicks in on multi-device meshes, but an explicit distributed
    algorithm exercises the same fallback path)."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    batch = [rmat_graph(7, 6, seed=s) for s in (11, 12)]
    tc = TriangleCounter(
        batch[0], CountOptions(algorithm="intersection_distributed"),
        mesh=mesh)
    with pytest.warns(UserWarning, match="not\\s+batchable"):
        results = tc.count_many(batch)
    for g, res in zip(batch, results):
        assert res == triangle_count_scipy(g), g.name
        assert res.meta.get("batched") is None  # per-graph, not stacked
    # the warning fires once per session, not once per graph/chunk
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        again = tc.count_many(batch)
    assert [int(r) for r in again] == [int(r) for r in results]


# --- per-vertex analysis through the cached plan ----------------------------

@pytest.mark.parametrize("opts", [
    CountOptions(algorithm="intersection"),
    CountOptions(algorithm="subgraph"),
    CountOptions(algorithm="matrix", block=32),  # sidecar fallback
    CountOptions(algorithm="intersection", variant="full"),  # sidecar
], ids=lambda o: f"{o.algorithm}-{o.variant}")
def test_vertex_analysis_matches_listing(opts):
    g = G_SKEWED
    tc = TriangleCounter(g, opts)
    assert np.array_equal(tc.triangles_per_vertex(),
                          listing.triangles_per_vertex(g))
    assert np.allclose(tc.clustering_coefficients(),
                       listing.clustering_coefficients(g))
    assert tc.transitivity() == pytest.approx(listing.transitivity(g))


def test_vertex_analysis_subgraph_scatters_through_prune():
    """Pruned (2-core-peeled) vertices must report zero triangles at their
    ORIGINAL ids."""
    g = G_UNIFORM  # spur_fraction > 0 ⇒ the peel removes leaves
    tc = TriangleCounter(g, CountOptions(algorithm="subgraph"))
    t = tc.triangles_per_vertex()
    assert t.shape == (g.n,)
    assert np.array_equal(t, listing.triangles_per_vertex(g))
    assert tc.count().meta["vertices_pruned"] > 0


# --- deprecation shims ------------------------------------------------------

def test_legacy_shims_warn_and_agree():
    from repro.core import (
        triangle_count_intersection,
        triangle_count_matrix,
        triangle_count_subgraph,
    )

    g = G_SKEWED
    truth = triangle_count_scipy(g)
    with pytest.warns(DeprecationWarning):
        assert triangle_count_intersection(g) == truth
    with pytest.warns(DeprecationWarning):
        assert triangle_count_intersection(g, variant="full") == truth
    with pytest.warns(DeprecationWarning):
        assert triangle_count_matrix(g, block=32) == truth
    with pytest.warns(DeprecationWarning):
        count, stats = triangle_count_subgraph(g, return_stats=True)
    assert count == truth
    assert stats["num_embeddings"] == 6 * truth
    assert {"vertices_pruned", "prune_fraction", "edges_after",
            "edges_before"} <= set(stats)


def test_legacy_distributed_shims_warn_and_agree():
    from repro.core import (
        triangle_count_intersection_distributed,
        triangle_count_matrix_distributed,
    )

    g = rmat_graph(7, 6, seed=45)  # single host device: mesh defaults
    truth = triangle_count_scipy(g)
    with pytest.warns(DeprecationWarning):
        assert triangle_count_intersection_distributed(g) == truth
    with pytest.warns(DeprecationWarning):
        assert triangle_count_matrix_distributed(g, block=32) == truth


def test_facade_itself_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert TriangleCounter(G_SKEWED).count() == \
            triangle_count_scipy(G_SKEWED)


# --- registry ---------------------------------------------------------------

def test_registry_surface():
    from repro.core import register_algorithm

    assert set(available_algorithms()) >= {
        "intersection", "matrix", "subgraph", "edge",
        "intersection_distributed", "matrix_distributed",
    }
    with pytest.raises(ValueError):
        register_algorithm("intersection", lambda g, o, mesh=None: None)
    with pytest.raises(ValueError):
        CountOptions(algorithm="not-registered")


def test_custom_algorithm_registration_roundtrip():
    from repro.core import register_algorithm
    from repro.core.registry import OneShotPlan, _REGISTRY

    name = "test-constant-lane"

    def planner(g, options, *, mesh=None):
        return OneShotPlan(fn=lambda: 7, algorithm=name)

    register_algorithm(name, planner)
    try:
        res = TriangleCounter(G_SKEWED, CountOptions(algorithm=name)).count()
        assert res.count == 7 and res.algorithm == name
    finally:
        _REGISTRY.pop(name, None)


# --- datasets satellite -----------------------------------------------------

def test_load_dataset_unknown_name_lists_available():
    with pytest.raises(ValueError, match="tiny-rmat"):
        load_dataset("road-lik")  # typo
    names = available_datasets()
    assert names == sorted(names)
    assert "road-like" in names and "tiny-grid" in names


# --- interpret default satellite --------------------------------------------

def test_default_interpret_env_override():
    import subprocess, sys, os
    code = ("import repro.core.options as o; "
            "print(o.DEFAULT_INTERPRET, o.resolve_interpret(None), "
            "o.resolve_interpret(True))")
    env = dict(os.environ, PYTHONPATH="src", TC_INTERPRET="0")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr
    assert out.stdout.split() == ["False", "False", "True"]
    env["TC_INTERPRET"] = "1"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.stdout.split() == ["True", "True", "True"]
