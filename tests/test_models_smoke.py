"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting shapes and finiteness. Full configs are exercised only
via the dry-run (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCHS, get_model, get_reduced_config
from repro.train.data import SyntheticDataConfig, make_batch
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def _batch(cfg, b=2, s=16, step=0):
    return {k: jnp.asarray(v)
            for k, v in make_batch(cfg, SyntheticDataConfig(b, s + 1),
                                   step).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0), dtype=jnp.float32)
    batch = _batch(cfg)
    logits, aux = jax.jit(model.apply_train)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_reduced_config(arch).replace(microbatches=1)
    model = get_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, stable_steps=5,
                          decay_steps=2, moment_dtype=jnp.float32)
    params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                                   dtype=jnp.float32)
    step = jax.jit(make_train_step(model, cfg, opt_cfg))
    params, opt, metrics = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-780m",
                                  "whisper-medium", "recurrentgemma-9b"])
def test_decode_agrees_with_train_forward(arch):
    """Prefill+decode must reproduce the teacher-forced forward logits."""
    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(1), dtype=jnp.float32)
    batch = _batch(cfg, b=2, s=12, step=3)
    full, _ = jax.jit(model.apply_train)(params, batch)
    if arch == "recurrentgemma-9b":  # step-by-step decode from empty cache
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        outs = []
        step = jax.jit(model.decode_step)
        for t in range(12):
            lg, cache = step(params, cache, batch["tokens"][:, t:t + 1])
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=5e-3, atol=5e-3)
        return
    pre_batch = dict(batch)
    pre_batch.pop("labels")
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               rtol=5e-3, atol=5e-3)
    nxt = batch["tokens"][:, :1] * 0 + 5
    dl, _ = jax.jit(model.decode_step)(params, cache, nxt)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    batch2["labels"] = jnp.concatenate(
        [batch["labels"], batch["labels"][:, :1]], axis=1)
    full2, _ = jax.jit(model.apply_train)(params, batch2)
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(full2[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_param_counts_match_published():
    """Sanity: full configs land within 10% of the published sizes."""
    expected = {
        "gemma2-2b": 2.6e9, "qwen1.5-4b": 3.6e9, "qwen1.5-32b": 34e9,
        "minicpm-2b": 2.7e9, "mamba2-780m": 0.78e9, "arctic-480b": 477e9,
        "dbrx-132b": 131e9, "whisper-medium": 0.76e9, "paligemma-3b": 2.5e9,
        "recurrentgemma-9b": 8.5e9,
    }
    from repro.models.registry import get_config

    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.10, (arch, got, want)
