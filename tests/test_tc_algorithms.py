"""All three TC formulations must agree exactly with the oracle.

Deliberately exercises the DEPRECATED one-shot shims (the facade equivalents
live in tests/test_api.py): the shims must keep returning unchanged values
while they exist."""

import numpy as np
import pytest

from repro.graphs import (
    complete_graph, erdos_renyi_graph, grid_graph, path_graph, rmat_graph,
    star_graph, watts_strogatz_graph,
)
from repro.core import (
    triangle_count_intersection, triangle_count_matrix,
    triangle_count_subgraph, triangle_count_scipy, triangle_count_brute,
    triangle_count_forward_cpu,
)

GRAPHS = [
    complete_graph(4),
    complete_graph(9),
    star_graph(40),
    path_graph(40),
    grid_graph(10, seed=0),
    grid_graph(8, diagonals=False, spur_fraction=0.0),
    rmat_graph(8, 8, seed=1),
    rmat_graph(9, 4, seed=2),
    erdos_renyi_graph(300, 10.0, seed=3),
    watts_strogatz_graph(200, 8, 0.2, seed=4),
]


@pytest.mark.parametrize("g", GRAPHS, ids=lambda g: g.name)
def test_intersection_matches_oracle(g):
    assert triangle_count_intersection(g) == triangle_count_scipy(g)


@pytest.mark.parametrize("g", GRAPHS, ids=lambda g: g.name)
def test_intersection_full_variant(g):
    assert triangle_count_intersection(g, variant="full") == \
        triangle_count_scipy(g)


@pytest.mark.parametrize("g", GRAPHS, ids=lambda g: g.name)
def test_matrix_matches_oracle(g):
    assert triangle_count_matrix(g, block=32) == triangle_count_scipy(g)


@pytest.mark.parametrize("g", GRAPHS, ids=lambda g: g.name)
def test_subgraph_matches_oracle(g):
    assert triangle_count_subgraph(g) == triangle_count_scipy(g)


def test_closed_forms():
    for n in (3, 5, 8, 12):
        expect = n * (n - 1) * (n - 2) // 6
        assert triangle_count_intersection(complete_graph(n)) == expect
    assert triangle_count_matrix(star_graph(100), block=32) == 0
    assert triangle_count_subgraph(path_graph(100)) == 0


def test_matrix_without_permutation():
    g = rmat_graph(8, 8, seed=5)
    truth = triangle_count_scipy(g)
    assert triangle_count_matrix(g, block=32, permute=False) == truth
    assert triangle_count_matrix(g, block=64, permute=True) == truth


def test_cpu_forward_baseline_agrees():
    g = rmat_graph(7, 6, seed=6)
    assert triangle_count_forward_cpu(g) == triangle_count_scipy(g)


def test_brute_force_tiny():
    g = complete_graph(6)
    assert triangle_count_brute(g) == 20


def test_subgraph_prune_stats_mesh_graph():
    """The paper's claim: mesh-like graphs have many leaves the SM filter
    removes (road-like spur fraction ⇒ large prune)."""
    g = grid_graph(20, diagonals=True, spur_fraction=0.4, seed=7)
    count, stats = triangle_count_subgraph(g, return_stats=True)
    assert count == triangle_count_scipy(g)
    assert stats["prune_fraction"] > 0.2  # leaf spurs pruned
    assert stats["edges_after"] < stats["edges_before"]
    assert stats["num_embeddings"] == 6 * count
