"""Optimizer / microbatching / data / checkpoint / compression / listing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_model, get_reduced_config
from repro.train.checkpoint import (
    latest_step, restore_checkpoint, save_checkpoint,
)
from repro.train.compression import compress_decompress, ef_init
from repro.train.data import SyntheticDataConfig, SyntheticDataset, make_batch
from repro.train.elastic import ElasticTrainer, rescale_microbatches
from repro.train.optimizer import AdamWConfig, adamw_init, wsd_schedule
from repro.train.train_step import init_train_state, make_train_step


def test_wsd_schedule_phases():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, stable_steps=100,
                      decay_steps=10)
    assert float(wsd_schedule(jnp.asarray(5), cfg)) == pytest.approx(0.5)
    assert float(wsd_schedule(jnp.asarray(50), cfg)) == pytest.approx(1.0)
    assert float(wsd_schedule(jnp.asarray(120), cfg)) == pytest.approx(0.01)


def test_microbatch_grad_parity():
    """Strided microbatch accumulation == single-batch gradients."""
    cfg = get_reduced_config("gemma2-2b")
    model = get_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=0.0, warmup_steps=1, weight_decay=0.0,
                          moment_dtype=jnp.float32)
    params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                                   dtype=jnp.float32)
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, SyntheticDataConfig(8, 17), 0).items()}
    s1 = jax.jit(make_train_step(model, cfg, opt_cfg, microbatches=1))
    s4 = jax.jit(make_train_step(model, cfg, opt_cfg, microbatches=4))
    _, _, m1 = s1(params, opt, batch)
    _, _, m4 = s4(params, opt, batch)
    assert float(m1["xent"]) == pytest.approx(float(m4["xent"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]),
                                                   rel=1e-3)


def test_loss_decreases():
    cfg = get_reduced_config("minicpm-2b")
    model = get_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=2, stable_steps=50,
                          decay_steps=5, moment_dtype=jnp.float32)
    params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                                   dtype=jnp.float32)
    step = jax.jit(make_train_step(model, cfg, opt_cfg, microbatches=1))
    losses = []
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in make_batch(
            cfg, SyntheticDataConfig(4, 17), i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert min(losses[5:]) < losses[0]


def test_data_determinism_and_seek():
    cfg = get_reduced_config("gemma2-2b")
    dc = SyntheticDataConfig(4, 33, seed=7)
    ds1 = SyntheticDataset(cfg, dc)
    b0, b1 = next(ds1), next(ds1)
    ds2 = SyntheticDataset(cfg, dc)
    ds2.seek(1)
    np.testing.assert_array_equal(next(ds2)["tokens"], b1["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_checkpoint_roundtrip_gc_and_resume():
    cfg = get_reduced_config("mamba2-780m")
    model = get_model(cfg)
    opt_cfg = AdamWConfig(moment_dtype=jnp.float32)
    params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                                   dtype=jnp.float32)
    state = {"params": params, "opt": opt}
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            save_checkpoint(d, s, state, extra={"next_step": s + 1}, keep=2)
        assert latest_step(d) == 40
        assert sorted(os.listdir(d)) == ["step_30", "step_40"]  # GC kept 2
        restored, extra = restore_checkpoint(d, 40, state)
        assert extra["next_step"] == 41
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # elastic shell resumes from latest
        et = ElasticTrainer(ckpt_dir=d)
        resumed, start = et.resume_or_init(lambda: state)
        assert start == 41


def test_rescale_microbatches():
    assert rescale_microbatches(8, 32, 16) == 16  # half the dp → double micro
    assert rescale_microbatches(8, 16, 32) == 4


def test_compression_error_feedback():
    """Quantization error must be carried, not lost: over many steps the
    mean dequantized gradient converges to the true mean."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32) * 1e-3)
    ef = ef_init({"w": g_true})["w"]
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, ef = compress_decompress({"w": g_true}, {"w": ef})
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true),
                               atol=1e-6)


def test_listing_and_truss():
    from repro.core import (clustering_coefficients, k_truss, transitivity,
                            enumerate_triangles)
    from repro.graphs import complete_graph, grid_graph

    k4 = complete_graph(4)
    assert enumerate_triangles(k4).shape == (4, 3)
    np.testing.assert_allclose(clustering_coefficients(k4), np.ones(4))
    assert transitivity(k4) == pytest.approx(1.0)
    # k-truss of K4 at k=4: every edge in 2 triangles ⇒ survives; k=5 empty
    assert k_truss(k4, 4).m_undirected == 6
    assert k_truss(k4, 5).m_undirected == 0
    g = grid_graph(8, seed=0)
    assert k_truss(g, 3).m_undirected <= g.m_undirected


def test_labeled_subgraph_match():
    from repro.core import subgraph_match_triangle
    from repro.graphs.formats import edges_to_csr

    # triangle 0-1-2 labeled (0,1,2) + triangle 3-4-5 labeled (0,0,0)
    g = edges_to_csr(np.array([0, 1, 2, 3, 4, 5]),
                     np.array([1, 2, 0, 4, 5, 3]), n=6)
    labels = np.array([0, 1, 2, 0, 0, 0])
    # ordered embeddings of labeled triangle (0,1,2): exactly one per
    # orientation of the 0-1 edge = 1 (u=0,v=1,w=2)
    assert subgraph_match_triangle(g, labels, (0, 1, 2)) == 1
    assert subgraph_match_triangle(g, labels, (0, 0, 0)) == 6  # all perms
    assert subgraph_match_triangle(g, labels, (2, 2, 2)) == 0
