"""Tiled out-of-core execution == the monolithic plan, bit for bit.

``CountOptions.max_device_bytes`` bounds the bytes any one bucket may hold
resident; buckets over the budget stream through the SAME cached
executables chunk-by-chunk (pow2 chunk rows, inert tail padding, host
accumulation). This module is the differential harness:

* strategy × prep_backend × budget sweep on the intersection lane — every
  cell asserts tiled == monolithic == scipy, and forced-small budgets
  assert the plan REALLY streamed (≥2 chunks in the meta);
* the matrix lane's (T, B, B) tile-stack streaming (float partials are
  exact small integers, so host accumulation is bit-identical);
* the subgraph lane inheriting streaming through its inner intersection;
* the zero-recompile contract: steady-state replays of a tiled plan hit
  the executable cache only (chunk shapes are pow2 classes, so ONE compile
  per (chunk, width) then pure replays);
* ``triangles_per_vertex`` over a tiled filtered plan (the vertex
  executable streams the same chunks);
* budget semantics: a budget big enough for everything tiles nothing and
  keys a distinct plan from the unbudgeted options.
"""

import numpy as np
import pytest

from repro.core import (
    CountOptions,
    TriangleCounter,
    executable_cache_info,
    triangle_count_scipy,
)
from repro.graphs import erdos_renyi_graph, rmat_graph

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def g_rmat():
    return rmat_graph(8, edge_factor=8, seed=21)


@pytest.fixture(scope="module")
def g_er():
    return erdos_renyi_graph(400, avg_degree=10.0, seed=4)


def _count(g, **kw):
    return TriangleCounter(g, CountOptions(**kw)).count()


@pytest.mark.parametrize("strategy", ["broadcast", "probe", "bitmap"])
@pytest.mark.parametrize("prep_backend", ["device", "host"])
@pytest.mark.parametrize("budget", [1 << 13, 1 << 16])
def test_tiled_intersection_sweep(g_rmat, strategy, prep_backend, budget):
    oracle = int(triangle_count_scipy(g_rmat))
    mono = _count(g_rmat, algorithm="intersection", strategy=strategy,
                  prep_backend=prep_backend)
    tiled = _count(g_rmat, algorithm="intersection", strategy=strategy,
                   prep_backend=prep_backend, max_device_bytes=budget)
    assert int(mono) == int(tiled) == oracle
    if budget <= 1 << 13:
        assert tiled.meta["num_chunks"] >= 2, tiled.meta
        assert tiled.meta["tiled_buckets"], tiled.meta
    for tb in tiled.meta["tiled_buckets"]:
        # chunk rows are pow2 and respect the budget per-row cost
        c = tb["chunk_rows"]
        assert c >= 1 and (c & (c - 1)) == 0
        assert tb["num_chunks"] >= 2


@pytest.mark.parametrize("variant", ["filtered", "full"])
def test_tiled_variants(g_er, variant):
    oracle = int(triangle_count_scipy(g_er))
    tiled = _count(g_er, algorithm="intersection", variant=variant,
                   max_device_bytes=1 << 13)
    assert int(tiled) == oracle
    assert tiled.meta["num_chunks"] >= 2


def test_tiled_matrix(g_er):
    oracle = int(triangle_count_scipy(g_er))
    mono = _count(g_er, algorithm="matrix")
    tiled = _count(g_er, algorithm="matrix", max_device_bytes=1 << 14)
    assert int(mono) == int(tiled) == oracle
    assert tiled.meta["num_chunks"] >= 2


def test_tiled_subgraph(g_er):
    oracle = int(triangle_count_scipy(g_er))
    tiled = _count(g_er, algorithm="subgraph", max_device_bytes=1 << 13)
    assert int(tiled) == oracle
    assert tiled.meta["num_chunks"] >= 2


def test_tiled_steady_state_never_recompiles(g_rmat):
    tc = TriangleCounter(g_rmat, CountOptions(algorithm="intersection",
                                              max_device_bytes=1 << 13))
    first = tc.count()
    assert first.meta["num_chunks"] >= 2
    before = executable_cache_info()["misses"]
    for _ in range(3):
        assert int(tc.plan.count()) == int(first)
    assert executable_cache_info()["misses"] == before, \
        "steady-state tiled replays must be pure cache hits"


def test_tiled_vertex_counts_match_monolithic(g_rmat):
    mono = TriangleCounter(g_rmat, CountOptions(algorithm="intersection"))
    tiled = TriangleCounter(g_rmat, CountOptions(algorithm="intersection",
                                                 max_device_bytes=1 << 13))
    pv_m = mono.triangles_per_vertex()
    pv_t = tiled.triangles_per_vertex()
    assert pv_m.shape == pv_t.shape == (g_rmat.n,)
    np.testing.assert_array_equal(pv_m, pv_t)
    assert int(pv_t.sum()) == 3 * int(triangle_count_scipy(g_rmat))


def test_generous_budget_tiles_nothing(g_er):
    res = _count(g_er, algorithm="intersection", max_device_bytes=1 << 30)
    assert int(res) == int(triangle_count_scipy(g_er))
    assert res.meta["num_chunks"] == 0
    assert res.meta["tiled_buckets"] == []


def test_budget_is_part_of_the_options_key():
    a = CountOptions(algorithm="intersection")
    b = CountOptions(algorithm="intersection", max_device_bytes=1 << 13)
    c = CountOptions(algorithm="intersection", max_device_bytes=1 << 16)
    assert len({a.key(), b.key(), c.key()}) == 3
    with pytest.raises(ValueError):
        CountOptions(max_device_bytes=0)
    with pytest.raises(ValueError):
        CountOptions(max_device_bytes=-5)
