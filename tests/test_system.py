"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import load_dataset, rmat_graph
from repro.core import CountOptions, TriangleCounter, triangle_count_scipy


def test_end_to_end_all_methods_on_datasets():
    """The paper's core experiment at smoke scale: every lane through the
    front door, both topology classes, exact agreement — plus the auto
    cost model's pick."""
    for name in ("tiny-rmat", "tiny-grid"):
        g = load_dataset(name)
        truth = triangle_count_scipy(g)
        for opts in (CountOptions(algorithm="intersection"),
                     CountOptions(algorithm="matrix"),
                     CountOptions(algorithm="subgraph")):
            assert TriangleCounter(g, opts).count() == truth, (name, opts)
        auto = TriangleCounter(g).count()
        assert auto == truth
        assert auto.algorithm in ("intersection", "matrix", "subgraph")


def test_serving_loop_end_to_end():
    """prefill → N greedy decode steps through the public serve API."""
    from repro.models.registry import get_model, get_reduced_config
    from repro.train.serve_step import greedy_generate

    cfg = get_reduced_config("gemma2-2b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0), dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    out = jax.jit(lambda p, b: greedy_generate(
        model, cfg, p, b, steps=4, max_len=16))(params, {"tokens": prompts})
    assert out.shape == (2, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())


def test_train_then_serve_roundtrip():
    """Train a few steps, checkpoint, restore, decode — the full lifecycle."""
    import tempfile

    from repro.models.registry import get_model, get_reduced_config
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.data import SyntheticDataConfig, make_batch
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_reduced_config("minicpm-2b")
    model = get_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                          moment_dtype=jnp.float32)
    params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                                   dtype=jnp.float32)
    step = jax.jit(make_train_step(model, cfg, opt_cfg, microbatches=1))
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in make_batch(
            cfg, SyntheticDataConfig(4, 17), i).items()}
        params, opt, metrics = step(params, opt, batch)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"params": params})
        restored, _ = restore_checkpoint(d, 3, {"params": params})
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 24))(
        restored["params"], {"tokens": batch["tokens"][:, :12]})
    assert bool(jnp.isfinite(logits).all())
