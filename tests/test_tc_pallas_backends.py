"""The public TC API through the Pallas kernels (interpret mode), the
set-intersection strategy × width sweep against the ref oracle, and the
multi-host-device sharded intersection path."""

import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import grid_graph, rmat_graph
from repro.core import (
    triangle_count_intersection, triangle_count_matrix, triangle_count_scipy,
)
from repro.kernels.intersect import (
    BITMAP_MAX_BITS,
    STRATEGIES,
    choose_strategy,
    intersect_counts,
    intersect_counts_bitmap,
    intersect_counts_bitmap_ref,
    intersect_counts_probe_ref,
    intersect_counts_ref,
    packed_bits,
    resolve_strategy,
)

# ------------------------------------------------------- end-to-end graphs

GRAPHS = [rmat_graph(8, 6, seed=11), grid_graph(9, seed=2)]


@pytest.mark.parametrize("g", GRAPHS, ids=lambda g: g.name)
@pytest.mark.parametrize("strategy", ("auto",) + STRATEGIES)
def test_pallas_intersection_end_to_end(g, strategy):
    truth = triangle_count_scipy(g)
    assert triangle_count_intersection(g, backend="pallas", interpret=True,
                                       strategy=strategy) == truth


@pytest.mark.parametrize("block", [16, 32])
def test_pallas_matrix_end_to_end(block):
    g = rmat_graph(8, 6, seed=12)
    truth = triangle_count_scipy(g)
    assert triangle_count_matrix(g, block=block, backend="pallas",
                                 interpret=True) == truth


# -------------------------------------------- strategy × width oracle sweep

def _padded_lists(e, w, n, seed):
    """Synthetic degree-bucket rows following the engine's sentinel rules:
    sorted neighbor lists padded in-row with n (u) / n+1 (v), plus one pair
    of fully-padded sentinel rows at the end."""
    rng = np.random.default_rng(seed)

    def make(fill):
        rows = []
        for _ in range(e - 1):
            k = int(rng.integers(0, min(w, n) + 1))
            vals = np.sort(rng.choice(n, size=k, replace=False))
            rows.append(np.concatenate([vals, np.full(w - k, fill)]))
        rows.append(np.full(w, fill))  # fully-padded sentinel row
        return np.asarray(rows, dtype=np.int32)

    return make(n), make(n + 1)


@pytest.mark.parametrize("width", [8, 32, 128])
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_strategy_matches_ref_oracle(width, strategy, backend):
    n = 100  # id range (n + 2 sentinels) fits every bitmap capacity below
    u, v = _padded_lists(50, width, n, seed=width * 7 + len(strategy))
    uj, vj = jnp.asarray(u), jnp.asarray(v)
    ref = np.asarray(intersect_counts_ref(uj, vj))
    out = intersect_counts(uj, vj, strategy=strategy, backend=backend,
                           tile_edges=16, interpret=True,
                           bitmap_bits=128)
    np.testing.assert_array_equal(np.asarray(out), ref, err_msg=f"{strategy}/{backend}")
    # the independent numpy refs agree too
    np.testing.assert_array_equal(intersect_counts_probe_ref(u, v), ref)
    np.testing.assert_array_equal(
        intersect_counts_bitmap_ref(u, v, num_bits=128), ref)


def test_bitmap_id_range_boundary():
    """Ids at num_bits-1 are counted; ids ≥ num_bits are masked out (and the
    auto cost model never hands such a bucket to bitmap)."""
    bits = 64
    u = jnp.asarray([[5, bits - 1, bits, bits + 7]], dtype=jnp.int32)
    v = jnp.asarray([[5, bits - 1, bits, bits + 7]], dtype=jnp.int32)
    # oracle counts all four matches; bitmap must count only the in-range two
    assert int(intersect_counts_ref(u, v)[0]) == 4
    for backend in ("jnp", "pallas"):
        got = intersect_counts(u, v, strategy="bitmap", backend=backend,
                               bitmap_bits=bits, tile_edges=1)
        assert int(got[0]) == 2, backend
    assert int(intersect_counts_bitmap_ref(u, v, num_bits=bits)[0]) == 2
    # cost model: bitmap only when the id range fits the packed width
    assert choose_strategy(64, bits) == "bitmap"
    assert choose_strategy(64, bits + 9) != "bitmap"
    assert packed_bits(64) == 64
    # forced bitmap beyond the packed width widens the bitmap to cover it
    strat, forced_bits = resolve_strategy(64, bits + 9, strategy="bitmap")
    assert (strat, forced_bits) == ("bitmap", 96)
    got = intersect_counts(u, v, strategy="bitmap", backend="jnp",
                           bitmap_bits=forced_bits)
    assert int(got[0]) == 4  # all ids < 96 are in range again


def test_forced_bitmap_over_huge_id_range_refuses():
    """The packer unrolls num_bits/32 iterations, so a forced bitmap over a
    huge id range raises instead of tracing an unbounded graph — and the
    auto selector never picks bitmap past the cap either."""
    with pytest.raises(ValueError, match="BITMAP_MAX_BITS"):
        resolve_strategy(8, 10**7, strategy="bitmap")
    huge_width = 1 << 20  # packed width over the cap: auto must not bitmap
    assert choose_strategy(huge_width, 1000) != "bitmap"
    assert resolve_strategy(512, BITMAP_MAX_BITS, "bitmap")[1] == BITMAP_MAX_BITS


def test_auto_never_selects_undersized_bitmap():
    """Regression: a caller-supplied bitmap_bits is a capacity for
    strategy="bitmap" only — the auto selector derives the id range from the
    data and must not mask out-of-capacity ids by picking bitmap anyway."""
    row = jnp.asarray([[10, 200, 300, 301]], dtype=jnp.int32)
    assert int(intersect_counts_ref(row, row)[0]) == 4
    got = intersect_counts(row, row, strategy="auto", backend="jnp",
                           bitmap_bits=64)
    assert int(got[0]) == 4


def test_bitmap_counts_trailing_padding_as_zero():
    """The v-row padding run (n+1 repeated) sets its bit once and u never
    queries it; the u-row padding (n) queries an unset bit."""
    n = 40
    u = jnp.asarray([[1, 2, n, n]], dtype=jnp.int32)
    v = jnp.asarray([[2, 3, n + 1, n + 1]], dtype=jnp.int32)
    out = intersect_counts_bitmap(u, v, num_bits=64)
    assert int(out[0]) == 1


# -------------------------------------- multi-host-device sharded dispatch

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
from repro.launch.mesh import make_mesh
from repro.graphs import rmat_graph, complete_graph
from repro.core import (triangle_count_intersection_distributed,
                        triangle_count_scipy)

out = {"devices": jax.device_count() == 4}
mesh = make_mesh((4,), ("data",))
g = rmat_graph(8, 8, seed=41)
truth = triangle_count_scipy(g)
for s in ("auto", "broadcast", "probe", "bitmap"):
    out[s] = triangle_count_intersection_distributed(g, mesh, strategy=s) == truth
# dense graph whose id range fits the packed width => auto shards the bitmap core
k = complete_graph(100)
out["bitmap_auto_dense"] = (
    triangle_count_intersection_distributed(k, mesh) == triangle_count_scipy(k))
print("RESULT:" + json.dumps(out))
"""


def test_distributed_intersection_strategies():
    """Sharded intersection agrees with the oracle for every strategy on a
    4-host-device mesh (subprocess so the XLA device-count flag never leaks)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert all(out.values()), out
