"""The public TC API through the Pallas kernels (interpret mode)."""

import pytest

from repro.graphs import grid_graph, rmat_graph
from repro.core import (
    triangle_count_intersection, triangle_count_matrix, triangle_count_scipy,
)


@pytest.mark.parametrize("g", [rmat_graph(8, 6, seed=11), grid_graph(9, seed=2)],
                         ids=lambda g: g.name)
def test_pallas_intersection_end_to_end(g):
    truth = triangle_count_scipy(g)
    assert triangle_count_intersection(g, backend="pallas",
                                       interpret=True) == truth


@pytest.mark.parametrize("block", [16, 32])
def test_pallas_matrix_end_to_end(block):
    g = rmat_graph(8, 6, seed=12)
    truth = triangle_count_scipy(g)
    assert triangle_count_matrix(g, block=block, backend="pallas",
                                 interpret=True) == truth
