"""Dynamic sessions: incremental counts == from-scratch recounts, bit for bit.

The differential harness for the dynamic lane (``DynamicTriangleCounter`` /
``repro.core.engine.DynamicPlan``): after every batched edge update the
incrementally maintained count must equal both the scipy oracle on a host
snapshot and the lane's own full-recount parity oracle. Alongside parity:
the shape-class contract (zero recompiles within a class, exactly one when
a capacity or width extent overflows — asserted through the executable
cache's hit/miss stats), dirty-input semantics (duplicate inserts, deletes
of absent edges, self-loops, last-wins within a batch), the empty → dense →
empty round trip, drift detection, the shared ``CounterSession`` surface,
and a hypothesis insert/delete soak.
"""

import numpy as np
import pytest

from repro.core import (
    CountOptions,
    CounterSession,
    DynamicTriangleCounter,
    EdgeUpdate,
    TriangleCounter,
    available_algorithms,
    available_strategies,
    executable_cache_info,
    normalize_edge_updates,
    plan_dynamic_count,
    triangle_count_scipy,
)
from repro.graphs import (
    ShapePolicy,
    complete_graph,
    edges_to_csr,
    erdos_renyi_graph,
    path_graph,
)


def _empty_graph(n, name="empty"):
    z = np.array([], dtype=np.int64)
    return edges_to_csr(z, z, n=n, name=name)


def _random_updates(rng, n, k, p_insert=0.6):
    u = rng.integers(0, n, size=k)
    v = rng.integers(0, n, size=k)
    ins = rng.random(k) < p_insert
    return [(int(a), int(b), bool(f)) for a, b, f in zip(u, v, ins)]


# ---------------------------------------------------------------------------
# normalize_edge_updates — the host half of the update contract
# ---------------------------------------------------------------------------

def test_normalize_accepts_all_spellings_and_orients():
    lo, hi, ins = normalize_edge_updates(
        [EdgeUpdate(3, 1), (0, 2), (4, 0, False)], n=5)
    assert lo.tolist() == [1, 0, 0]
    assert hi.tolist() == [3, 2, 4]
    assert ins.tolist() == [True, True, False]
    assert lo.dtype == np.int32 and hi.dtype == np.int32


def test_normalize_last_wins_and_drops_self_loops():
    lo, hi, ins = normalize_edge_updates(
        [(0, 1, True), (2, 2, True), (1, 0, False), (3, 4, False),
         (4, 3, True)], n=5)
    # (0,1): delete wins (later); (2,2) dropped; (3,4): insert wins
    assert list(zip(lo.tolist(), hi.tolist(), ins.tolist())) == [
        (0, 1, False), (3, 4, True)]


def test_normalize_rejects_bad_input():
    with pytest.raises(ValueError, match="out of range"):
        normalize_edge_updates([(0, 9)], n=5)
    with pytest.raises(ValueError, match="out of range"):
        normalize_edge_updates([(-1, 2)], n=5)
    with pytest.raises(ValueError):
        normalize_edge_updates([(1,)], n=5)


# ---------------------------------------------------------------------------
# incremental == oracle on small deterministic streams
# ---------------------------------------------------------------------------

def test_insert_then_delete_matches_oracle():
    g = edges_to_csr(np.array([0, 0, 1, 2]), np.array([1, 2, 2, 3]), n=5,
                     name="seed")
    dc = DynamicTriangleCounter(g, update_batch_size=8, recount_interval=0)
    assert dc.count() == 1
    res = dc.apply_updates([(1, 3), (2, 4), (3, 4)])
    assert res == triangle_count_scipy(dc.snapshot())
    assert res.algorithm == "dynamic"
    res = dc.apply_updates([EdgeUpdate(0, 1, insert=False)])
    assert res == triangle_count_scipy(dc.snapshot())
    assert dc.recount() == int(res)


def test_dirty_updates_are_noops():
    g = complete_graph(6)
    dc = DynamicTriangleCounter(g, update_batch_size=8, recount_interval=0)
    before = int(dc.count())
    assert before == 20  # C(6,3)
    # duplicate insert, delete of an absent edge, self loop: all no-ops
    dc.apply_updates([(0, 1, True), (0, 1, True)])
    assert int(dc.count()) == before
    dc.apply_updates([(2, 2, True)])
    assert int(dc.count()) == before
    g2 = _empty_graph(6, "e6")
    dc2 = DynamicTriangleCounter(g2, update_batch_size=8, recount_interval=0)
    dc2.apply_updates([(0, 1, False)])  # delete from an empty graph
    assert int(dc2.count()) == 0
    assert dc2.plan.meta["deleted"] == 0
    dc2.recount()


def test_empty_dense_empty_round_trip():
    n = 10
    dc = DynamicTriangleCounter(_empty_graph(n), update_batch_size=16,
                                recount_interval=0)
    assert dc.count() == 0
    allp = [(a, b) for a in range(n) for b in range(a + 1, n)]
    assert dc.apply_updates(allp) == 120  # C(10,3)
    dc.recount()
    assert dc.apply_updates([(a, b, False) for a, b in allp]) == 0
    assert dc.m_undirected == 0
    assert dc.snapshot().m_undirected == 0
    dc.recount()


def test_randomized_stream_parity():
    rng = np.random.default_rng(7)
    g = erdos_renyi_graph(64, avg_degree=6, seed=3)
    dc = DynamicTriangleCounter(g, update_batch_size=32, recount_interval=0)
    assert dc.count() == triangle_count_scipy(g)
    for _ in range(6):
        res = dc.apply_updates(_random_updates(rng, g.n, 50))
        assert res == triangle_count_scipy(dc.snapshot())
    assert dc.recount() == int(dc.count())


def test_multi_chunk_batch_and_update_batch_size():
    # one apply_updates call longer than update_batch_size chunks internally
    rng = np.random.default_rng(11)
    g = erdos_renyi_graph(40, avg_degree=5, seed=5)
    dc = DynamicTriangleCounter(g, update_batch_size=8, recount_interval=0)
    res = dc.apply_updates(_random_updates(rng, g.n, 60))
    assert res == triangle_count_scipy(dc.snapshot())
    assert dc.plan.meta["batches"] >= 2
    assert dc.options.update_batch_size == 8


# ---------------------------------------------------------------------------
# shape classes: zero recompiles inside, exactly one on extent overflow
# ---------------------------------------------------------------------------

def test_steady_state_batches_never_recompile():
    rng = np.random.default_rng(3)
    g = erdos_renyi_graph(48, avg_degree=6, seed=1)
    dc = DynamicTriangleCounter(g, update_batch_size=16, recount_interval=0)
    dc.apply_updates(_random_updates(rng, g.n, 16))  # warm both executables
    warm = dc.cache_stats()
    for _ in range(5):
        dc.apply_updates(_random_updates(rng, g.n, 16))
    stats = dc.cache_stats()
    assert stats["misses"] == warm["misses"]
    assert stats["hits"] > warm["hits"]
    assert dc.recount() == int(dc.count())


def test_capacity_overflow_recompiles_exactly_once():
    # pow2 capacity class: crossing it re-plans the step ONCE, and the next
    # batches replay it — not once per subsequent batch (the ShapePolicy
    # extent-overflow regression this test pins down)
    n = 40
    pairs = [(a, b) for a in range(n) for b in range(a + 1, n)]
    seed = pairs[:120]
    g = edges_to_csr(np.array([p[0] for p in seed]),
                     np.array([p[1] for p in seed]), n=n, name="capgrow")
    dc = DynamicTriangleCounter(g, update_batch_size=16, recount_interval=0)
    assert dc.plan.cap == 128
    dc.apply_updates([pairs[120]])  # warm (m=121, still inside cap 128)
    warm = dc.cache_stats()
    # 16 inserts push m past 128 -> capacity class doubles, one new step
    # executable (the delta executables are capacity-independent)
    dc.apply_updates(pairs[121:137])
    assert dc.plan.cap == 256
    grown = dc.cache_stats()
    assert grown["misses"] == warm["misses"] + 1
    # subsequent batches inside the new class: zero new compiles
    for s in range(137, 185, 16):
        dc.apply_updates(pairs[s:s + 16])
    assert dc.cache_stats()["misses"] == grown["misses"]
    assert dc.count() == triangle_count_scipy(dc.snapshot())
    assert dc.recount() == int(dc.count())


def test_width_overflow_rebuckets_once_and_stays_exact():
    # degree pushed past the top width class mid-stream: the session grows
    # its monotone top bound, re-gathers the neighbor matrix once, and the
    # batch that crossed is still bit-exact
    g = path_graph(24)
    dc = DynamicTriangleCounter(
        g, update_batch_size=16, recount_interval=0, widths=(8,))
    assert dc.plan.bounds == (8,)
    star = [(0, b) for b in range(2, 14)]  # degree(0) -> 13 > 8
    res = dc.apply_updates(star)
    assert dc.plan.bounds == (8, 16)
    assert res == triangle_count_scipy(dc.snapshot())
    # widths never shrink back, even after the hub is deleted again
    dc.apply_updates([(u, v, False) for u, v in star])
    assert dc.plan.bounds == (8, 16)
    assert dc.recount() == int(dc.count())


# ---------------------------------------------------------------------------
# the parity oracle: cadence and drift detection
# ---------------------------------------------------------------------------

def test_periodic_recount_cadence():
    rng = np.random.default_rng(5)
    g = erdos_renyi_graph(32, avg_degree=4, seed=2)
    dc = DynamicTriangleCounter(g, update_batch_size=8, recount_interval=2)
    for _ in range(5):
        dc.apply_updates(_random_updates(rng, g.n, 8))
    assert dc.plan.meta["batches"] == 5
    assert dc.plan.meta["recounts"] == 2  # after batches 2 and 4


def test_recount_raises_on_drift():
    g = complete_graph(7)
    dc = DynamicTriangleCounter(g, update_batch_size=8, recount_interval=0)
    dc.plan._count += 1  # corrupt the maintained count
    with pytest.raises(RuntimeError, match="drifted"):
        dc.recount()


# ---------------------------------------------------------------------------
# session surface + discovery helpers + invalid-name errors
# ---------------------------------------------------------------------------

def test_sessions_share_the_counter_session_surface():
    g = complete_graph(5)
    tc = TriangleCounter(g)
    dc = DynamicTriangleCounter(g, recount_interval=0)
    assert isinstance(tc, CounterSession)
    assert isinstance(dc, CounterSession)
    for sess in (tc, dc):
        c, stats = sess.count_with_stats()
        assert c == 10
        assert stats["algorithm"] == sess.algorithm
        cs = sess.cache_stats()
        # the bounded LRU (PR 8) added maxsize/evictions to the snapshot
        assert set(cs) == {"size", "hits", "misses", "maxsize",
                           "evictions"}
    assert tc.cache_stats() == executable_cache_info()


def test_dynamic_session_rejects_other_lanes():
    g = complete_graph(4)
    with pytest.raises(ValueError, match="'auto', 'dynamic'"):
        DynamicTriangleCounter(g, algorithm="matrix")
    # the dynamic lane is opt-in: auto never picks it for a static session
    assert TriangleCounter(g).algorithm != "dynamic"


def test_discovery_helpers():
    assert "dynamic" in available_algorithms()
    assert available_strategies() == ("bitmap", "broadcast", "probe")
    assert available_strategies() == tuple(sorted(available_strategies()))


def test_invalid_names_raise_value_errors_listing_choices():
    with pytest.raises(ValueError, match="intersection"):
        CountOptions(algorithm="bogus")
    with pytest.raises(ValueError, match="broadcast"):
        CountOptions(strategy="bogus")
    with pytest.raises(ValueError, match="dynamic"):
        CountOptions().plan_kwargs("bogus")
    with pytest.raises(ValueError, match="update_batch_size"):
        CountOptions(update_batch_size=0)
    with pytest.raises(ValueError, match="recount_interval"):
        CountOptions(recount_interval=-1)


def test_options_key_folds_dynamic_knobs():
    a = CountOptions()
    b = CountOptions(update_batch_size=32)
    c = CountOptions(recount_interval=0)
    assert len({a.key(), b.key(), c.key()}) == 3


def test_plan_dynamic_count_validates():
    g = complete_graph(4)
    with pytest.raises(ValueError, match="update_batch_size"):
        plan_dynamic_count(g, update_batch_size=0)
    with pytest.raises(ValueError, match="recount_interval"):
        plan_dynamic_count(g, recount_interval=-1)
    with pytest.raises(ValueError, match="backend"):
        plan_dynamic_count(g, backend="bogus")


def test_shape_policy_exact_still_exact():
    # the "exact" policy trades maximal retracing for minimal padding; the
    # counts must be unaffected
    g = erdos_renyi_graph(24, avg_degree=4, seed=9)
    dc = DynamicTriangleCounter(
        g, update_batch_size=8, recount_interval=0,
        shape_policy=ShapePolicy(edge_rounding="exact"))
    dc.apply_updates([(0, 1), (1, 2), (0, 2), (2, 3)])
    assert dc.count() == triangle_count_scipy(dc.snapshot())
    assert dc.recount() == int(dc.count())


# ---------------------------------------------------------------------------
# numpy-rng soak (always runs; the hypothesis twin with minimization lives
# in test_dynamic_property.py and skips where hypothesis is absent)
# ---------------------------------------------------------------------------

def test_soak_random_streams_stay_exact():
    n = 12  # fixed so every round shares the compiled shape classes
    for round_seed in range(4):
        rng = np.random.default_rng(100 + round_seed)
        pairs = _random_updates(rng, n, rng.integers(0, 20), p_insert=1.0)
        lo, hi, _ = normalize_edge_updates(pairs, n)
        g = edges_to_csr(lo.astype(np.int64), hi.astype(np.int64), n=n,
                         name=f"soak{round_seed}")
        dc = DynamicTriangleCounter(g, update_batch_size=8,
                                    recount_interval=0)
        assert dc.count() == triangle_count_scipy(g)
        for _ in range(3):
            res = dc.apply_updates(
                _random_updates(rng, n, int(rng.integers(0, 30))))
            assert res == triangle_count_scipy(dc.snapshot())
            assert dc.recount() == int(res)
        # drain everything: back to the empty graph, count 0
        slo, shi = dc.snapshot().edge_list_unique()
        if slo.size:
            assert dc.apply_updates(
                [(int(a), int(b), False) for a, b in zip(slo, shi)]) == 0
        assert dc.m_undirected == 0
