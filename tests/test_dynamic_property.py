"""Hypothesis soak for the dynamic lane: incremental == recount, bit for bit.

Randomized insert/delete streams (duplicates, deletes of absent edges, and
self loops included by construction) against two independent oracles after
every batch — the scipy count of a host snapshot and the lane's own
full-recount parity check — then a full drain back to the empty graph.
Mirrors ``test_tc_property.py``: the module skips where hypothesis is not
installed; ``test_dynamic.py``'s numpy-rng soak still runs there.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import DynamicTriangleCounter, triangle_count_scipy
from repro.graphs import edges_to_csr

_N = 12  # fixed so every example shares the compiled shape classes

_updates_strategy = st.lists(
    st.tuples(st.integers(0, _N - 1), st.integers(0, _N - 1),
              st.booleans()),
    min_size=0, max_size=30)


@settings(max_examples=20, deadline=None)
@given(seed_edges=st.lists(
    st.tuples(st.integers(0, _N - 1), st.integers(0, _N - 1)),
    max_size=20),
    batches=st.lists(_updates_strategy, min_size=1, max_size=3))
def test_soak_incremental_equals_recount(seed_edges, batches):
    src = np.array([min(a, b) for a, b in seed_edges if a != b], np.int64)
    dst = np.array([max(a, b) for a, b in seed_edges if a != b], np.int64)
    g = edges_to_csr(src, dst, n=_N, name="soak")
    dc = DynamicTriangleCounter(g, update_batch_size=8, recount_interval=0)
    assert dc.count() == triangle_count_scipy(g)
    for ups in batches:
        res = dc.apply_updates(ups)
        assert res == triangle_count_scipy(dc.snapshot())
        assert dc.recount() == int(res)
    # drain everything: back to the empty graph, count 0
    lo, hi = dc.snapshot().edge_list_unique()
    if lo.size:
        assert dc.apply_updates(
            [(int(a), int(b), False) for a, b in zip(lo, hi)]) == 0
    assert dc.m_undirected == 0
