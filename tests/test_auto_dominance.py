"""Cross-lane dominance & calibration harness — the measured-chooser gate.

The PR 7 headline: with five single-host counting lanes registered, the
``algorithm="auto"`` story is only honest if (a) every lane is bit-exact
against the scipy oracle on the full fixture sweep and (b) the measured
chooser's pick is never slower than the best fixed lane beyond a stated
tolerance. This module asserts both, plus the calibration-table mechanics
the chooser rides on (persistence round-trip, analytic cold-start,
heuristic fallback).

Runs in its own CI job (``pytest -m sweep``) so the timing sweep never
slows tier-1 (which runs ``-m "not sweep"``); the graphs stay smoke-sized
so a bare ``pytest`` invocation is still safe. Set ``RUN_SLOW_TC=1`` to
extend the sweep to the full dataset registry.

Tolerance: the pick must satisfy ``t_pick <= DOMINANCE_TOL * t_best +
DOMINANCE_SLACK_S`` against the same measured table it chose from. The
2× multiplicative band absorbs single-core timer noise between the
calibration micro-runs and this re-check; the 200µs additive slack keeps
sub-millisecond fixtures (where jitter exceeds any real lane gap) from
flaking. A pick outside that band means the chooser selected a lane the
table itself says is materially slower — a real regression.
"""

import os

import pytest

from repro.core import (
    CountOptions,
    TriangleCounter,
    available_algorithms,
    choose_algorithm,
    install_measured_chooser,
    set_auto_chooser,
    set_default_table,
    triangle_count_scipy,
)
from repro.core import calibrate as _calibrate_fn
from repro.core.calibrate import (
    CHOOSER_LANES,
    calibrate,
    choose_measured,
    feature_key,
    graph_features,
    load_table,
    measure_lanes,
    save_table,
)
from repro.graphs import available_datasets, load_dataset
from repro.graphs.generators import complete_graph, rmat_graph

pytestmark = pytest.mark.sweep

assert calibrate is _calibrate_fn  # package re-export stays the module fn

DOMINANCE_TOL = 2.0       # multiplicative band over the best fixed lane
DOMINANCE_SLACK_S = 200e-6  # additive floor for sub-ms smoke fixtures


def _sweep_graphs():
    """The dominance fixtures: the tiny dataset registry plus two shape
    extremes (dense clique, skewed R-MAT). RUN_SLOW_TC=1 widens to every
    registered dataset."""
    names = (sorted(available_datasets()) if os.environ.get("RUN_SLOW_TC")
             else ["tiny-rmat", "tiny-grid"])
    graphs = [load_dataset(n) for n in names]
    graphs.append(complete_graph(32))
    graphs.append(rmat_graph(7, 6, seed=7, name="rmat7-sweep"))
    return graphs


@pytest.fixture(scope="module")
def sweep_graphs():
    return _sweep_graphs()


@pytest.fixture(scope="module")
def sweep_table(sweep_graphs):
    """One measured calibration table over the whole sweep (module-scoped:
    every dominance assertion reads the same timings it audits)."""
    return calibrate(sweep_graphs, iters=3, warmup=1)


def test_all_lanes_bit_exact_on_sweep(sweep_graphs):
    """Every chooser lane — including the new hash and bfs lanes — agrees
    with the scipy oracle bit-exactly on every sweep fixture."""
    for lane in CHOOSER_LANES:
        assert lane in available_algorithms()
    for g in sweep_graphs:
        truth = triangle_count_scipy(g)
        for lane in CHOOSER_LANES:
            got = TriangleCounter(g, CountOptions(algorithm=lane)).count()
            assert got == truth, (g.name, lane, int(got), truth)


def test_measured_pick_dominates(sweep_graphs, sweep_table):
    """The headline gate: on every fixture, the measured chooser's pick is
    never slower than the best fixed lane beyond the stated tolerance,
    judged against the very timings the table measured."""
    for g in sweep_graphs:
        timings = sweep_table.lookup(g)
        assert timings and set(timings) == set(CHOOSER_LANES), g.name
        pick = choose_measured(g, sweep_table)
        assert pick in CHOOSER_LANES, (g.name, pick)
        t_best = min(timings.values())
        t_pick = timings[pick]
        assert t_pick <= DOMINANCE_TOL * t_best + DOMINANCE_SLACK_S, (
            f"{g.name}: auto picked {pick} at {t_pick * 1e6:.0f}us but the "
            f"best fixed lane runs {t_best * 1e6:.0f}us "
            f"(tol {DOMINANCE_TOL}x + {DOMINANCE_SLACK_S * 1e6:.0f}us)")


def test_measured_pick_recheck_within_tolerance(sweep_graphs, sweep_table):
    """Re-measure the picked lane fresh and re-apply the same band against
    the table's best — catches a table whose timings have gone stale
    relative to what the lane actually costs now."""
    for g in sweep_graphs:
        timings = sweep_table.lookup(g)
        pick = choose_measured(g, sweep_table)
        fresh = measure_lanes(g, [pick], iters=3, warmup=1)[pick]
        t_best = min(timings.values())
        assert fresh <= DOMINANCE_TOL * t_best + DOMINANCE_SLACK_S, (
            f"{g.name}: picked lane {pick} re-measures at "
            f"{fresh * 1e6:.0f}us vs table best {t_best * 1e6:.0f}us")


def test_facade_auto_uses_table_and_matches_oracle(sweep_graphs,
                                                   sweep_table):
    """``chooser="measured"`` through the facade resolves to the table's
    pick and still counts bit-exactly."""
    prev = set_default_table(sweep_table)
    try:
        for g in sweep_graphs:
            tc = TriangleCounter(g, CountOptions(chooser="measured"))
            assert tc.algorithm == choose_measured(g, sweep_table), g.name
            assert tc.count() == triangle_count_scipy(g), g.name
    finally:
        set_default_table(prev)


def test_install_measured_chooser_swaps_and_restores(sweep_graphs,
                                                     sweep_table):
    """The registry-level hook: ``install_measured_chooser`` reroutes
    ``choose_algorithm`` process-wide and hands back the previous chooser."""
    g = sweep_graphs[0]
    prev = install_measured_chooser(sweep_table)
    try:
        assert choose_algorithm(g) == choose_measured(g, sweep_table)
    finally:
        set_auto_chooser(prev)
    assert choose_algorithm(g) in available_algorithms()


def test_table_round_trip_preserves_choices(sweep_graphs, sweep_table,
                                            tmp_path):
    """Persisting and reloading the sidecar must not change a single pick."""
    path = save_table(sweep_table, str(tmp_path / "CALIB_roundtrip.json"))
    reloaded = load_table(path)
    assert reloaded.entries == sweep_table.entries
    for g in sweep_graphs:
        assert reloaded.choose(g) == sweep_table.choose(g), g.name


def test_analytic_cold_start_is_usable(sweep_graphs):
    """A measure=False table (pure HLO/roofline pricing, no kernel ever
    runs) still yields a registered, bit-exact lane for every fixture —
    the cold-start contract."""
    table = calibrate(sweep_graphs[:2], measure=False)
    assert set(table.sources.values()) == {"analytic"}
    for g in sweep_graphs:
        pick = choose_measured(g, table)
        assert pick in available_algorithms(), (g.name, pick)
        got = TriangleCounter(g, CountOptions(algorithm=pick)).count()
        assert got == triangle_count_scipy(g), (g.name, pick)


def test_chooser_falls_back_without_table(sweep_graphs):
    """No table installed and no sidecar on disk ⇒ the measured chooser
    degrades to the heuristic, never an error."""
    g = sweep_graphs[0]
    prev = set_default_table(None)
    env = os.environ.pop("TC_CALIB", None)
    os.environ["TC_CALIB"] = "/nonexistent/CALIB_missing.json"
    try:
        assert choose_measured(g) == choose_algorithm(g)
    finally:
        if env is None:
            os.environ.pop("TC_CALIB", None)
        else:
            os.environ["TC_CALIB"] = env
        set_default_table(prev)


def test_feature_bins_are_stable(sweep_graphs):
    """Feature extraction is deterministic and every bin is well-formed —
    the table key contract the sidecar schema relies on."""
    for g in sweep_graphs:
        k1 = feature_key(graph_features(g))
        k2 = feature_key(graph_features(g))
        assert k1 == k2
        w, skew, dens = k1
        assert w.startswith("w:") and int(w[2:]) >= 0
        assert skew in ("skew:low", "skew:mid", "skew:high")
        assert dens in ("dens:thin", "dens:sparse", "dens:dense")
