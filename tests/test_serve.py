"""repro.serve: admission/shedding contracts, coalescer edge cases,
determinism against the sequential facade, and the zero-steady-state-
recompile warmup guarantee."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    CountOptions,
    TriangleCounter,
    clear_caches,
    executable_cache_info,
    triangle_count_scipy,
)
from repro.core.api import DynamicTriangleCounter
from repro.graphs import rmat_graph
from repro.serve import (
    SHED_DEADLINE,
    SHED_QUEUE_FULL,
    SHED_SHUTDOWN,
    Coalescer,
    RequestShed,
    ServeConfig,
    ServeResult,
    TriangleService,
)
from repro.serve.coalescer import _pow2_chunks
from repro.serve.metrics import quantile

POOL = [rmat_graph(6, 6, seed=510 + i, name=f"serve-t{i}") for i in range(4)]
ORACLE = [triangle_count_scipy(g) for g in POOL]
OPTS = CountOptions(algorithm="intersection")

# a generous window so quick back-to-back submits land in one group even
# on a slow CI box; tests that need NO coalescing use window 0 instead
WIDE = ServeConfig(batch_window_ms=250.0, max_batch=8)


def _svc(config=WIDE, options=OPTS, **overrides):
    return TriangleService(options, config=config, **overrides)


# --- unit pieces -------------------------------------------------------------


def test_pow2_chunk_decomposition():
    assert _pow2_chunks(1) == [1]
    assert _pow2_chunks(7) == [4, 2, 1]
    assert _pow2_chunks(8) == [8]
    for k in range(1, 33):
        assert sum(_pow2_chunks(k)) == k
        assert all(c & (c - 1) == 0 for c in _pow2_chunks(k))


def test_nearest_rank_quantile():
    vals = sorted(float(v) for v in range(1, 101))
    assert quantile(vals, 0.50) == 50.0
    assert quantile(vals, 0.99) == 99.0
    assert quantile([7.0], 0.99) == 7.0


def test_serve_config_validation():
    with pytest.raises(ValueError, match="max_queue_depth"):
        ServeConfig(max_queue_depth=0)
    with pytest.raises(ValueError, match="batch_window_ms"):
        ServeConfig(batch_window_ms=-1.0)
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError, match="default_deadline_ms"):
        ServeConfig(default_deadline_ms=0.0)


def test_submit_validation():
    svc = _svc()  # not started: validation happens before the queue
    with pytest.raises(ValueError, match="unknown kind"):
        svc.submit("frobnicate", POOL[0])
    with pytest.raises(ValueError, match="need a graph"):
        svc.submit("count")
    with pytest.raises(ValueError, match="k_truss requests need k="):
        svc.submit("k_truss", POOL[0])
    with pytest.raises(KeyError, match="unknown dynamic session"):
        svc.submit("update", handle="nope", updates=[(0, 1)])
    with pytest.raises(ValueError, match="not a graph"):
        svc.submit("update", POOL[0], handle="nope", updates=[(0, 1)])


# --- coalescing edge cases ---------------------------------------------------


def test_single_request_passes_through_with_batch_size_one():
    """A lone request flushes when the window times out and is served by
    the single-graph path (batch_size 1), not a padded batch."""
    with _svc(ServeConfig(batch_window_ms=20.0, max_batch=8)) as svc:
        res = svc.count(POOL[0])
    assert isinstance(res, ServeResult)
    assert res.count == ORACLE[0]
    assert res.batch_size == 1
    assert int(res) == ORACLE[0]


def test_compatible_burst_coalesces():
    with _svc() as svc:
        svc.warmup(POOL)
        futs = [svc.submit("count", POOL[i % 4], tenant=f"t{i % 2}")
                for i in range(8)]
        results = [f.result(timeout=120) for f in futs]
    for i, r in enumerate(results):
        assert r.count == ORACLE[i % 4]
    # the wide window must have merged at least one pair; a full merge
    # shows up as one shared batch_id over all eight
    assert max(r.batch_size for r in results) >= 2
    snap = svc.snapshot()
    assert snap["coalesce_factor"] > 1.0
    assert snap["counters"]["completed"] == 8


def test_incompatible_options_never_merge():
    """Different resolved CountOptions.key() => different compat keys:
    the groups dispatch separately (disjoint batch_ids) even when both
    are in flight inside one window."""
    a = OPTS
    b = OPTS.replace(strategy="probe")  # key() differs, still batchable
    assert a.key() != b.key()
    with _svc() as svc:
        futs_a = [svc.submit("count", POOL[0], options=a) for _ in range(3)]
        futs_b = [svc.submit("count", POOL[0], options=b) for _ in range(3)]
        res_a = [f.result(timeout=120) for f in futs_a]
        res_b = [f.result(timeout=120) for f in futs_b]
    assert all(r.count == ORACLE[0] for r in res_a + res_b)
    assert {r.batch_id for r in res_a}.isdisjoint(r.batch_id for r in res_b)


def test_auto_options_not_merged_with_explicit():
    """algorithm="auto" resolving to the intersection lane still has a
    different options key than an explicit "intersection" request — the
    conservative compat rule keeps them apart."""
    auto = CountOptions(algorithm="auto")
    with _svc() as svc:
        fa = svc.submit("count", POOL[1], options=auto)
        fe = svc.submit("count", POOL[1], options=OPTS)
        ra, re = fa.result(timeout=120), fe.result(timeout=120)
    assert ra.count == re.count == ORACLE[1]
    assert ra.batch_id != re.batch_id


def test_dynamic_updates_bypass_coalescing_and_stay_fifo():
    oracle = DynamicTriangleCounter(POOL[2], CountOptions(algorithm="dynamic"))
    batches = [[(0, 1), (1, 2), (0, 2)], [(3, 4), (4, 5), (3, 5)]]
    expected = [int(oracle.apply_updates(b)) for b in batches]
    with _svc() as svc:
        handle = svc.open_dynamic_session(POOL[2], tenant="dyn")
        # interleave with coalescible counts: the update must not be
        # folded into their batch
        cfut = svc.submit("count", POOL[2])
        ufuts = [svc.submit("update", handle=handle, updates=b)
                 for b in batches]
        got = [f.result(timeout=120) for f in ufuts]
        assert cfut.result(timeout=120).count == ORACLE[2]
        svc.close_dynamic_session(handle)
        with pytest.raises(KeyError):
            svc.submit("update", handle=handle, updates=[(0, 1)])
    assert [r.count for r in got] == expected
    assert all(r.batch_size == 1 and r.algorithm == "dynamic" for r in got)


def test_results_bit_identical_to_sequential_facade():
    """Coalesced (padded, vmapped, possibly heterogeneous-width) dispatch
    must agree exactly with one facade count per request."""
    graphs = [rmat_graph(6, e, seed=550 + e, name=f"het{e}")
              for e in (4, 8, 12, 16)]
    facade = [int(TriangleCounter(g, OPTS).count()) for g in graphs]
    with _svc() as svc:
        svc.warmup(graphs)
        futs = [svc.submit("count", graphs[i % 4]) for i in range(12)]
        results = [f.result(timeout=120) for f in futs]
    assert [r.count for r in results] == [facade[i % 4] for i in range(12)]


def test_analysis_kinds_match_facade():
    g = POOL[3]
    session = TriangleCounter(g, OPTS)
    with _svc() as svc:
        v = svc.submit("vertex", g).result(timeout=120).value
        src, dst, sup = svc.submit("edge_support", g).result(timeout=120).value
        kt = svc.submit("k_truss", g, k=3).result(timeout=120).value
    np.testing.assert_array_equal(v, session.triangles_per_vertex())
    f_src, f_dst, f_sup = session.edge_support()
    np.testing.assert_array_equal(src, f_src)
    np.testing.assert_array_equal(dst, f_dst)
    np.testing.assert_array_equal(sup, f_sup)
    assert kt.n == session.k_truss(3).n
    snap = svc.snapshot()
    assert snap["session_cache"]["hits"] >= 2  # one prep served all three


# --- admission control / shedding -------------------------------------------


def test_queue_full_sheds_with_reason():
    svc = _svc(ServeConfig(max_queue_depth=2, batch_window_ms=0.0))
    # dispatcher not started: the queue fills deterministically
    f1 = svc.submit("count", POOL[0])
    f2 = svc.submit("count", POOL[1])
    f3 = svc.submit("count", POOL[2])
    with pytest.raises(RequestShed) as ei:
        f3.result(timeout=5)
    assert ei.value.reason == SHED_QUEUE_FULL
    svc.stop(drain=False)  # sheds the backlog instead of serving it
    for f in (f1, f2):
        with pytest.raises(RequestShed) as ei:
            f.result(timeout=5)
        assert ei.value.reason == SHED_SHUTDOWN
    snap = svc.snapshot()
    assert snap["counters"]["shed"] == 3
    assert snap["counters"]["shed_queue-full"] == 1
    assert snap["counters"]["shed_shutdown"] == 2
    # a closed service refuses new work with "shutdown", it never hangs
    with pytest.raises(RequestShed) as ei:
        svc.submit("count", POOL[0]).result(timeout=5)
    assert ei.value.reason == SHED_SHUTDOWN


def test_expired_deadline_sheds_not_executes():
    with _svc() as svc:
        with pytest.raises(RequestShed) as ei:
            svc.submit("count", POOL[0], deadline_ms=1e-4).result(timeout=30)
    assert ei.value.reason == SHED_DEADLINE
    assert svc.snapshot()["counters"]["shed_deadline"] == 1


def test_default_deadline_applies_to_all_requests():
    cfg = ServeConfig(batch_window_ms=0.0, default_deadline_ms=1e-4)
    with _svc(cfg) as svc:
        with pytest.raises(RequestShed) as ei:
            svc.submit("count", POOL[0]).result(timeout=30)
    assert ei.value.reason == SHED_DEADLINE


def test_stop_with_drain_serves_the_backlog():
    svc = _svc(ServeConfig(batch_window_ms=0.0, max_batch=8))
    futs = [svc.submit("count", POOL[i % 4]) for i in range(6)]
    svc.start()
    svc.stop(drain=True)
    results = [f.result(timeout=120) for f in futs]
    assert [r.count for r in results] == [ORACLE[i % 4] for i in range(6)]


# --- shared caches / metrics -------------------------------------------------


def test_metrics_snapshot_schema():
    with _svc() as svc:
        svc.count(POOL[0])
        snap = svc.snapshot()
    assert {"counters", "latency", "coalesce_factor", "engine_cache",
            "plan_cache", "session_cache", "queue_depth"} <= set(snap)
    for name in ("queue_wait", "exec", "total"):
        stat = snap["latency"][name]
        assert {"count", "mean_ms", "p50_ms", "p90_ms", "p99_ms",
                "max_ms"} <= set(stat)
        assert stat["count"] == 1
    assert {"size", "hits", "misses", "maxsize",
            "evictions"} <= set(snap["engine_cache"])
    c = snap["counters"]
    assert c["offered"] == c["accepted"] == c["completed"] == 1
    assert c["dispatches"] == c["dispatched_requests"] == 1


def test_warmup_then_zero_steady_state_recompiles():
    """The acceptance contract: after warmup over the request pool, a
    mixed serving phase — every pow-2 batch size plus singles — compiles
    nothing new (engine-cache miss delta is exactly zero)."""
    clear_caches()
    with _svc() as svc:
        info = svc.warmup(POOL)
        assert info["batchable"] == len(POOL)
        misses0 = executable_cache_info()["misses"]
        for burst in (1, 2, 3, 8):  # 3 exercises the 2+1 chunk split
            futs = [svc.submit("count", POOL[i % 4]) for i in range(burst)]
            for i, f in enumerate(futs):
                assert f.result(timeout=120).count == ORACLE[i % 4]
        assert executable_cache_info()["misses"] == misses0
        # a second service inherits the process-wide engine cache: its
        # own warmup over the same pool also compiles nothing
        with _svc() as svc2:
            svc2.warmup(POOL)
            assert svc2.count(POOL[1]).count == ORACLE[1]
        assert executable_cache_info()["misses"] == misses0


def test_racing_submissions_share_one_plan_prep():
    """Concurrent same-graph requests from many threads hit the bounded
    plan cache: one prep miss per (graph, options), the rest hits."""
    with _svc() as svc:
        svc.warmup([POOL[0]])
        base = svc.snapshot()["plan_cache"]["misses"]
        barrier = threading.Barrier(6)
        futs, errs = [], []

        def fire():
            try:
                barrier.wait(timeout=30)
                futs.append(svc.submit("count", POOL[0]))
            except BaseException as e:  # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=fire) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert not errs
        assert [f.result(timeout=120).count for f in futs] == [ORACLE[0]] * 6
        assert svc.snapshot()["plan_cache"]["misses"] == base


def test_coalescer_plan_cache_is_bounded():
    coal = Coalescer(plan_cache_size=2)
    from repro.core.api import graph_fingerprint
    for g in POOL[:3]:
        coal.prep(g, graph_fingerprint(g), OPTS)
    info = coal.cache_info()
    assert info["size"] == 2
    assert info["maxsize"] == 2
    assert info["evictions"] == 1
