"""The BENCH_<figure>.json sidecar contract, guarded by tier-1.

PR 4 made every executed benchmark figure write a machine-readable sidecar
(rows + env + device + argv) so the perf trajectory is comparable across
PRs; until now only the CI bench-smoke job exercised it. This test runs the
``fig_truss --smoke`` sweep in-process (which also differentially asserts
host-vs-device k-truss agreement on every row pair) plus the ``fig_stream
--smoke`` sweep (incremental vs full-recount parity, the zero-recompile
contract, and the ≥3× smoke speedup gate all assert inside the sweep) and
validates both sidecar schemas: rows non-empty and well-formed,
env/device/argv present, no NaN cells.
"""

import json
import math
import pathlib
import runpy
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
RUN_PY = ROOT / "benchmarks" / "run.py"


def _run_smoke_figure(tmp_path_factory, figure: str) -> dict:
    """Run ``benchmarks/run.py --figures <figure> --smoke`` in-process
    (sharing this pytest process's warm executable cache) and load the
    sidecar it writes."""
    json_dir = tmp_path_factory.mktemp("bench")
    argv = ["run.py", "--figures", figure, "--smoke",
            "--json-dir", str(json_dir)]
    old_argv = sys.argv
    sys.argv = argv
    try:
        runpy.run_path(str(RUN_PY), run_name="__main__")
    finally:
        sys.argv = old_argv
    path = json_dir / f"BENCH_{figure}.json"
    assert path.exists(), f"{figure} must write its sidecar"
    with open(path, encoding="utf-8") as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fig_truss_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_truss")


@pytest.fixture(scope="module")
def fig_stream_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_stream")


def test_sidecar_toplevel_schema(fig_truss_sidecar):
    data = fig_truss_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_truss"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_truss", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_sidecar_rows_schema(fig_truss_sidecar):
    rows = fig_truss_sidecar["rows"]
    assert rows, "fig_truss must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_truss_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_sidecar_rows_pair_host_and_device(fig_truss_sidecar):
    """Every graph gets a _host/_device row pair (bit-identical edge sets
    are asserted inside the sweep itself), and each executed device row
    records the peel round count."""
    rows = {r["name"]: r for r in fig_truss_sidecar["rows"]}
    hosts = {n[: -len("_host")] for n in rows if n.endswith("_host")}
    devices = {n[: -len("_device")] for n in rows if n.endswith("_device")}
    assert hosts and hosts == devices
    assert any("clique-heavy" in n for n in hosts)  # the fixture row ran
    for base in devices:
        derived = rows[base + "_device"]["derived"]
        assert "rounds=" in derived
        # smoke lifts the budget, so every host row is a real measurement
        # and every device row carries the speedup against it
        assert "speedup=" in derived


def test_stream_sidecar_toplevel_schema(fig_stream_sidecar):
    data = fig_stream_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_stream"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_stream", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_stream_sidecar_rows_schema(fig_stream_sidecar):
    rows = fig_stream_sidecar["rows"]
    assert rows, "fig_stream must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_stream_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_stream_sidecar_pairs_incremental_and_full_recount(
        fig_stream_sidecar):
    """One _incremental/_full-recount row pair per fixture; the incremental
    row proves the zero-recompile shape-class contract and the full-recount
    row carries the speedup the smoke gate (≥3×) already enforced
    in-process."""
    rows = {r["name"]: r for r in fig_stream_sidecar["rows"]}
    incs = {n[: -len("_incremental")] for n in rows
            if n.endswith("_incremental")}
    fulls = {n[: -len("_full-recount")] for n in rows
             if n.endswith("_full-recount")}
    assert incs and incs == fulls
    for base in incs:
        assert "recompiles=0" in rows[base + "_incremental"]["derived"]
        assert "upd_per_s=" in rows[base + "_incremental"]["derived"]
        speedup = rows[base + "_full-recount"]["derived"]
        assert "speedup=" in speedup
        x = float(speedup.split("speedup=")[1].rstrip("x"))
        assert x >= 3.0
