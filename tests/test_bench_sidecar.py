"""The BENCH_<figure>.json sidecar contract, guarded by tier-1.

PR 4 made every executed benchmark figure write a machine-readable sidecar
(rows + env + device + argv) so the perf trajectory is comparable across
PRs; until now only the CI bench-smoke job exercised it. This test runs the
``fig_truss --smoke`` sweep in-process (which also differentially asserts
host-vs-device k-truss agreement on every row pair), the ``fig_stream
--smoke`` sweep (incremental vs full-recount parity, the zero-recompile
contract, and the ≥3× smoke speedup gate all assert inside the sweep), and
the ``fig_auto --smoke`` sweep (measured-chooser calibration: every auto
count asserts the scipy oracle inside the sweep, and the run additionally
writes the ``CALIB_<device>.json`` calibration sidecar this test schema-
gates alongside ``BENCH_fig_auto.json``), and the ``fig_serve --smoke``
sweep (service-vs-sequential-facade speedup ≥2×, below-knee zero shed,
bounded-p99 deadline shedding, and zero steady-state recompiles all
assert inside the sweep; this test re-reads the gates from the sidecar),
and the ``fig_dist --smoke`` sweep (the sharded plan/execute engine under
8 forced host devices in a subprocess: every row oracle-asserted, the
planned lanes' zero-recompile replays and their speedup over the one-shot
``shard_map`` baseline re-read from the sidecar).
The ``fig_tile --smoke`` sweep (tiled out-of-core streaming: the tiled
count asserts bit-identical parity against the monolithic plan AND the
scipy oracle in-process, ≥2 streamed chunks, zero steady-state recompiles,
and the ≤2× overhead gate; the tests below re-read those gates from the
sidecar).
All sidecar schemas: rows non-empty and well-formed, env/device/argv
present, no NaN cells.
"""

import json
import math
import pathlib
import runpy
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
RUN_PY = ROOT / "benchmarks" / "run.py"


def _run_smoke_figure(tmp_path_factory, figure: str) -> dict:
    """Run ``benchmarks/run.py --figures <figure> --smoke`` in-process
    (sharing this pytest process's warm executable cache) and load the
    sidecar it writes."""
    json_dir = tmp_path_factory.mktemp("bench")
    argv = ["run.py", "--figures", figure, "--smoke",
            "--json-dir", str(json_dir)]
    old_argv = sys.argv
    sys.argv = argv
    try:
        runpy.run_path(str(RUN_PY), run_name="__main__")
    finally:
        sys.argv = old_argv
    path = json_dir / f"BENCH_{figure}.json"
    assert path.exists(), f"{figure} must write its sidecar"
    with open(path, encoding="utf-8") as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fig_truss_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_truss")


@pytest.fixture(scope="module")
def fig_stream_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_stream")


@pytest.fixture(scope="module")
def fig_serve_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_serve")


@pytest.fixture(scope="module")
def fig_auto_run(tmp_path_factory):
    """The fig_auto smoke sweep: returns (BENCH sidecar dict, json_dir) —
    the same run writes the CALIB_<device>.json calibration sidecar into
    json_dir, which the tests below schema-gate."""
    json_dir = tmp_path_factory.mktemp("bench_auto")
    argv = ["run.py", "--figures", "fig_auto", "--smoke",
            "--json-dir", str(json_dir)]
    old_argv = sys.argv
    sys.argv = argv
    try:
        runpy.run_path(str(RUN_PY), run_name="__main__")
    finally:
        sys.argv = old_argv
    path = json_dir / "BENCH_fig_auto.json"
    assert path.exists(), "fig_auto must write its sidecar"
    with open(path, encoding="utf-8") as f:
        return json.load(f), json_dir


def test_sidecar_toplevel_schema(fig_truss_sidecar):
    data = fig_truss_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_truss"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_truss", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_sidecar_rows_schema(fig_truss_sidecar):
    rows = fig_truss_sidecar["rows"]
    assert rows, "fig_truss must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_truss_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_sidecar_rows_pair_host_and_device(fig_truss_sidecar):
    """Every graph gets a _host/_device row pair (bit-identical edge sets
    are asserted inside the sweep itself), and each executed device row
    records the peel round count."""
    rows = {r["name"]: r for r in fig_truss_sidecar["rows"]}
    hosts = {n[: -len("_host")] for n in rows if n.endswith("_host")}
    devices = {n[: -len("_device")] for n in rows if n.endswith("_device")}
    assert hosts and hosts == devices
    assert any("clique-heavy" in n for n in hosts)  # the fixture row ran
    for base in devices:
        derived = rows[base + "_device"]["derived"]
        assert "rounds=" in derived
        # smoke lifts the budget, so every host row is a real measurement
        # and every device row carries the speedup against it
        assert "speedup=" in derived


def test_stream_sidecar_toplevel_schema(fig_stream_sidecar):
    data = fig_stream_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_stream"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_stream", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_stream_sidecar_rows_schema(fig_stream_sidecar):
    rows = fig_stream_sidecar["rows"]
    assert rows, "fig_stream must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_stream_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_stream_sidecar_pairs_incremental_and_full_recount(
        fig_stream_sidecar):
    """One _incremental/_full-recount row pair per fixture; the incremental
    row proves the zero-recompile shape-class contract and the full-recount
    row carries the speedup the smoke gate (≥3×) already enforced
    in-process."""
    rows = {r["name"]: r for r in fig_stream_sidecar["rows"]}
    incs = {n[: -len("_incremental")] for n in rows
            if n.endswith("_incremental")}
    fulls = {n[: -len("_full-recount")] for n in rows
             if n.endswith("_full-recount")}
    assert incs and incs == fulls
    for base in incs:
        assert "recompiles=0" in rows[base + "_incremental"]["derived"]
        assert "upd_per_s=" in rows[base + "_incremental"]["derived"]
        speedup = rows[base + "_full-recount"]["derived"]
        assert "speedup=" in speedup
        x = float(speedup.split("speedup=")[1].rstrip("x"))
        assert x >= 3.0


def test_serve_sidecar_toplevel_schema(fig_serve_sidecar):
    data = fig_serve_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_serve"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_serve", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_serve_sidecar_rows_schema(fig_serve_sidecar):
    rows = fig_serve_sidecar["rows"]
    assert rows, "fig_serve must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_serve_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_serve_sidecar_speedup_and_shed_contract(fig_serve_sidecar):
    """The serving acceptance gates, re-read from the sidecar: the service
    burst beats the sequential-facade baseline by ≥2× (the in-process gate),
    below-knee QPS rows shed nothing, the over-knee and depth-bounded rows
    record nonzero shed rates, and steady state recompiled nothing."""
    rows = {r["name"]: r for r in fig_serve_sidecar["rows"]}
    seq = next((n for n in rows if n.endswith("_sequential")), None)
    batch = next((n for n in rows if n.endswith("_service-batch")), None)
    steady = next((n for n in rows if n.endswith("_steady-state")), None)
    assert seq and batch and steady
    assert "throughput=" in rows[seq]["derived"]
    x = float(rows[batch]["derived"].split("speedup=")[1].rstrip("x"))
    assert x >= 2.0
    coalesce = float(
        rows[batch]["derived"].split("coalesce=")[1].split(";")[0])
    assert coalesce > 1.0

    qps = {n: r for n, r in rows.items() if "_qps" in n}
    assert len(qps) >= 3  # below knee, over knee, depth-bounded burst
    shed_rates = {}
    for name, row in qps.items():
        derived = row["derived"]
        for field in ("p50_ms=", "p99_ms=", "throughput=", "shed_rate="):
            assert field in derived, (name, field)
        shed_rates[name] = float(
            derived.split("shed_rate=")[1].split(";")[0])
    assert min(shed_rates.values()) == 0.0  # below the knee: no shedding
    assert max(shed_rates.values()) > 0.0   # above it: typed load-shedding
    over_knee = [n for n, r in shed_rates.items() if r > 0.0]
    assert any("deadline_ms=" in rows[n]["derived"] or "depth=" in
               rows[n]["derived"] for n in over_knee)

    assert "recompiles=0" in rows[steady]["derived"]
    assert "plan_cache_hits=" in rows[steady]["derived"]


@pytest.fixture(scope="module")
def fig_dist_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_dist")


def test_dist_sidecar_toplevel_schema(fig_dist_sidecar):
    data = fig_dist_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_dist"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_dist", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_dist_sidecar_rows_schema(fig_dist_sidecar):
    rows = fig_dist_sidecar["rows"]
    assert rows, "fig_dist must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_dist_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_dist_sidecar_planned_beats_oneshot(fig_dist_sidecar):
    """The sharded-engine acceptance gates, re-read from the sidecar: every
    row oracle-asserted (inside the subprocess sweep), the single-device
    reference + the one-shot baseline + both planned 8-shard rows present,
    the planned rows report zero recompiles across their timed replays,
    and the planned intersection lane beats the one-shot shard_map
    baseline on wall time."""
    rows = {r["name"]: r for r in fig_dist_sidecar["rows"]}
    single = next((n for n in rows if n.endswith("_single")), None)
    oneshot = next((n for n in rows if "_oneshot" in n), None)
    planned = next((n for n in rows if "_planned" in n), None)
    matrix = next((n for n in rows if "_matrix" in n), None)
    assert single and oneshot and planned and matrix
    for name, row in rows.items():
        assert "oracle=ok" in row["derived"], name
    assert "devices=1" in rows[single]["derived"]
    assert "cached=no" in rows[oneshot]["derived"]
    for n in (planned, matrix):
        derived = rows[n]["derived"]
        assert "devices=8" in derived
        assert "recompiles=0" in derived
        assert "balance=" in derived
        balance = float(derived.split("balance=")[1].split(";")[0])
        assert 1.0 <= balance <= 2.0
    x = float(rows[planned]["derived"].split("speedup=")[1].split("x")[0])
    assert x > 1.0
    assert rows[planned]["count_us"] < rows[oneshot]["count_us"]


@pytest.fixture(scope="module")
def fig_tile_sidecar(tmp_path_factory):
    return _run_smoke_figure(tmp_path_factory, "fig_tile")


def test_tile_sidecar_toplevel_schema(fig_tile_sidecar):
    data = fig_tile_sidecar
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_tile"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_tile", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_tile_sidecar_rows_schema(fig_tile_sidecar):
    rows = fig_tile_sidecar["rows"]
    assert rows, "fig_tile must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_tile_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_tile_sidecar_streaming_contract(fig_tile_sidecar):
    """The out-of-core acceptance gates, re-read from the sidecar: a
    _mono/_tiled row pair, both oracle-asserted (inside the sweep), the
    tiled row streamed ≥2 chunks with ZERO steady-state recompiles, and
    its overhead over the monolithic replay stays within the 2× smoke
    gate the sweep already enforced in-process."""
    rows = {r["name"]: r for r in fig_tile_sidecar["rows"]}
    assert "fig_tile_mono" in rows and "fig_tile_tiled" in rows
    for row in rows.values():
        assert "oracle=ok" in row["derived"], row
    assert "budget=" in rows["fig_tile_mono"]["derived"]
    derived = rows["fig_tile_tiled"]["derived"]
    chunks = int(derived.split("chunks=")[1].split(";")[0])
    assert chunks >= 2
    assert "recompiles=0" in derived
    overhead = float(derived.split("overhead=")[1].rstrip("x"))
    assert 0.0 < overhead <= 2.0


def test_auto_sidecar_toplevel_schema(fig_auto_run):
    data, _ = fig_auto_run
    assert {"figure", "smoke", "argv", "env", "device", "rows"} <= set(data)
    assert data["figure"] == "fig_auto"
    assert data["smoke"] is True
    assert data["argv"][:3] == ["--figures", "fig_auto", "--smoke"]
    assert {"python", "jax", "numpy", "platform"} <= set(data["env"])
    assert isinstance(data["device"], str) and data["device"]


def test_auto_sidecar_rows_schema(fig_auto_run):
    rows, _ = fig_auto_run
    rows = rows["rows"]
    assert rows, "fig_auto must emit rows"
    for row in rows:
        assert {"name", "prep_us", "count_us", "derived"} <= set(row)
        assert row["name"].startswith("fig_auto_")
        for cell in ("prep_us", "count_us"):
            assert isinstance(row[cell], (int, float))
            assert not math.isnan(row[cell]) and not math.isinf(row[cell])
            assert row[cell] >= 0.0
        assert isinstance(row["derived"], str) and row["derived"]


def test_auto_sidecar_rows_pair_lanes_and_auto(fig_auto_run):
    """Every dataset gets one row per chooser lane plus the _auto row, and
    the _auto row's derived field carries the pick/best/ratio triple (the
    oracle equality already asserted inside the sweep)."""
    from repro.core.calibrate import CHOOSER_LANES

    data, _ = fig_auto_run
    rows = {r["name"]: r for r in data["rows"]}
    autos = {n[: -len("_auto")] for n in rows if n.endswith("_auto")}
    assert autos, "fig_auto must emit _auto rows"
    for base in autos:
        for lane in CHOOSER_LANES:
            assert f"{base}_{lane}" in rows, (base, lane)
        derived = rows[base + "_auto"]["derived"]
        assert "auto=" in derived and "best=" in derived
        assert "ratio=" in derived
        pick = derived.split("auto=")[1].split(";")[0]
        assert pick in CHOOSER_LANES, (base, pick)
        ratio = float(derived.split("ratio=")[1])
        assert ratio >= 1.0 and not math.isinf(ratio)


def test_calibration_sidecar_schema(fig_auto_run):
    """The CALIB_<device>.json sidecar the same run writes: schema version,
    device label, and well-formed measured entries for every chooser lane —
    and it loads back through the library with choices intact."""
    from repro.core.calibrate import (
        CALIB_SCHEMA_VERSION, CHOOSER_LANES, calib_path, load_table,
    )

    _, json_dir = fig_auto_run
    path = pathlib.Path(calib_path(str(json_dir)))
    assert path.exists(), "fig_auto must write the calibration sidecar"
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    assert doc["schema"] == CALIB_SCHEMA_VERSION
    assert isinstance(doc["device"], str) and doc["device"]
    assert isinstance(doc["created_unix"], (int, float))
    assert doc["entries"], "calibration must record at least one bin"
    for ent in doc["entries"]:
        assert {"key", "timings", "source"} <= set(ent)
        assert len(ent["key"]) == 3
        assert ent["source"] in ("measured", "analytic")
        assert set(ent["timings"]) == set(CHOOSER_LANES)
        for lane, t in ent["timings"].items():
            assert isinstance(t, (int, float)) and t >= 0.0
            assert not math.isnan(t) and not math.isinf(t)
    table = load_table(str(path))
    assert len(table.entries) == len(doc["entries"])
