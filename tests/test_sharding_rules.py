"""Sharding-rule unit tests (no devices needed — specs only)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.registry import get_model, get_reduced_config
from repro.train.sharding import param_specs, sanitize_spec


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_sanitize_spec_drops_nondivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert sanitize_spec(P("model", None), (50280, 64), mesh) == P(None, None)
    assert sanitize_spec(P("model", None), (256000, 64), mesh) == \
        P("model", None)
    assert sanitize_spec(P(("data", "model"), None), (1, 5), mesh) == \
        P(None, None)


def test_param_specs_rules():
    cfg = get_reduced_config("gemma2-2b")
    model = get_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0),
                                               dtype=jnp.float32))
    specs = param_specs(params)
    assert specs["embed"] == P("model", None)
    # scan-stacked layers get a leading None
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", None)
    assert specs["layers"]["ln1"]["scale"] == P()


def test_param_specs_fsdp_and_moe():
    cfg = get_reduced_config("arctic-480b")
    model = get_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0),
                                               dtype=jnp.float32))
    specs = param_specs(params, fsdp=True)
    assert specs["layers"]["moe"]["wi"] == P(None, "model", "data", None)
    assert specs["layers"]["moe"]["wo"] == P(None, "model", None, "data")
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")


def test_meshctx_noop_without_mesh():
    from repro.models.meshctx import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x
