"""The n≲46k capacity-bug class is dead: key modes, boundaries, the audit.

Packed pair keys ``a * (n + 1) + b`` overflow int32 once
``(n + 1)² > 2³¹ − 1`` — the flip sits exactly between n = 46339 (last
fitting) and n = 46340 (first wide). This module pins the whole capacity
layer introduced to kill that bug class:

* ``resolve_edge_key_mode`` — the ONE checkpoint: auto promotion to the
  x64-gated int64 "wide" mode at the flip, forced ``int32`` past the bound
  raising the typed ``GraphTooLargeError`` (a ``ValueError`` naming the
  lanes that DO support the graph), forced ``wide`` below it honored.
* Boundary regressions at n = 46339/46340/46341 through the real lanes,
  plus the ``EDGE_KEY_SENTINEL`` non-collision proof at the boundary
  (max real key ``(n + 1)² − 1`` < sentinel on the last fitting n).
* n > 46341 counting correctly end to end — the static intersection lane
  and a dynamic session with updates + the full-recount oracle, both in
  wide mode, scipy-asserted.
* Wide-vs-int32 parity: the SAME graph forced through both key modes must
  agree bit-for-bit on every lane that packs keys (edge/k-truss, dynamic),
  including a seeded-rng soak; a hypothesis twin runs when the plugin is
  installed.
* The source audit: every ``* (n + 1)`` packed-key construction site in
  the library lives in a file that routes through the checkpoint, and the
  checkpoint is the only ``raise GraphTooLargeError`` site.
"""

import pathlib
import re

import numpy as np
import pytest

from repro.core import (
    CountOptions,
    DynamicTriangleCounter,
    GraphTooLargeError,
    TriangleCounter,
    plan_dynamic_count,
    plan_edge_support,
    triangle_count_scipy,
)
from repro.core import prep
from repro.graphs import (
    edges_to_csr,
    erdos_renyi_graph,
    fits_int32_pair_keys,
    resolve_edge_key_mode,
)
from repro.graphs.device import (
    EDGE_KEY_SENTINEL,
    WIDE_EDGE_KEY_SENTINEL,
    DeviceCSR,
    edge_key_dtype,
    edge_key_sentinel,
    fits_int64_pair_keys,
)
from repro.graphs.formats import EdgeUpdate

# the exact int32 flip: (46339 + 1)² = 2_147_395_600 ≤ 2³¹ − 1 < (46340 + 1)²
N_LAST_INT32 = 46339


def _sparse_graph(n, m=200, seed=0, name="boundary"):
    """A few edges spread over a huge id range — triangles guaranteed by
    an explicit clique on the top three ids (the overflow-prone corner)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m).astype(np.int64)
    dst = rng.integers(0, n, size=m).astype(np.int64)
    keep = src != dst
    tri = np.array([[n - 3, n - 2], [n - 2, n - 1], [n - 3, n - 1]])
    lo = np.concatenate([src[keep], tri[:, 0]])
    hi = np.concatenate([dst[keep], tri[:, 1]])
    return edges_to_csr(lo, hi, n=n, name=name)


# -- the checkpoint -----------------------------------------------------------

def test_fits_predicates_flip_exactly_at_the_boundary():
    assert fits_int32_pair_keys(N_LAST_INT32)
    assert not fits_int32_pair_keys(N_LAST_INT32 + 1)
    assert fits_int64_pair_keys(N_LAST_INT32 + 1)
    assert fits_int64_pair_keys(3_000_000_000)
    assert not fits_int64_pair_keys(4_000_000_000)


def test_resolve_edge_key_mode_auto_promotes_at_the_flip():
    assert resolve_edge_key_mode(N_LAST_INT32) == "int32"
    assert resolve_edge_key_mode(N_LAST_INT32 + 1) == "wide"
    assert resolve_edge_key_mode(N_LAST_INT32 + 2) == "wide"


def test_resolve_edge_key_mode_forced_modes():
    # forcing wide below the bound is honored (parity-test hook)
    assert resolve_edge_key_mode(100, "wide") == "wide"
    assert resolve_edge_key_mode(100, "int32") == "int32"
    with pytest.raises(ValueError, match="key_mode"):
        resolve_edge_key_mode(100, "int16")


def test_forced_int32_past_the_bound_raises_typed_error_naming_lanes():
    with pytest.raises(GraphTooLargeError) as ei:
        resolve_edge_key_mode(N_LAST_INT32 + 1, "int32", lane="edge")
    msg = str(ei.value)
    # the message must route the user somewhere that works
    assert "wide" in msg and "auto" in msg
    assert "matrix" in msg or "hash" in msg
    assert isinstance(ei.value, ValueError)  # typed AND catchable as before


def test_past_int64_bound_raises_even_on_auto():
    with pytest.raises(GraphTooLargeError) as ei:
        resolve_edge_key_mode(4_000_000_000)
    assert "matrix" in str(ei.value) or "hash" in str(ei.value)


def test_mode_helpers_are_consistent():
    assert edge_key_dtype("int32") == np.dtype(np.int32)
    assert edge_key_dtype("wide") == np.dtype(np.int64)
    assert edge_key_sentinel("int32") == EDGE_KEY_SENTINEL
    assert edge_key_sentinel("wide") == WIDE_EDGE_KEY_SENTINEL


def test_sentinel_never_collides_with_a_real_key_at_the_boundary():
    """On the LAST fitting n the maximum real packed key is
    (n + 1)² − 1; the int32 sentinel must sit strictly above it (and the
    wide sentinel above the int64 bound's maximum key)."""
    max_real = (N_LAST_INT32 + 1) ** 2 - 1
    assert max_real < EDGE_KEY_SENTINEL
    assert EDGE_KEY_SENTINEL == np.iinfo(np.int32).max
    n_last_wide = 3_037_000_498  # isqrt(2⁶³ − 1) − 1
    assert fits_int64_pair_keys(n_last_wide)
    assert not fits_int64_pair_keys(n_last_wide + 1)
    assert (n_last_wide + 1) ** 2 - 1 < WIDE_EDGE_KEY_SENTINEL


# -- boundary regressions through the real lanes ------------------------------

@pytest.mark.parametrize("n", [N_LAST_INT32, N_LAST_INT32 + 1,
                               N_LAST_INT32 + 2])
def test_boundary_counts_are_exact_on_every_side_of_the_flip(n):
    g = _sparse_graph(n, seed=n)
    oracle = int(triangle_count_scipy(g))
    assert oracle >= 1  # the planted clique survived dedup
    res = TriangleCounter(g, CountOptions(algorithm="intersection")).count()
    assert int(res) == oracle
    # the key-packing lane (edge support) promotes transparently
    sup = plan_edge_support(g)
    want = "int32" if fits_int32_pair_keys(n) else "wide"
    assert sup.key_mode == want


@pytest.mark.parametrize("n", [N_LAST_INT32, N_LAST_INT32 + 1])
def test_boundary_dynamic_sessions_promote_and_stay_exact(n):
    g = _sparse_graph(n, seed=n)
    oracle = int(triangle_count_scipy(g))
    dt = DynamicTriangleCounter(g, CountOptions(recount_interval=0))
    want = "int32" if fits_int32_pair_keys(n) else "wide"
    assert dt.plan.key_mode == want
    assert dt.plan._keys.dtype == edge_key_dtype(want)
    assert int(dt.count()) == oracle
    # touch the overflow-prone corner: update edges among the top ids
    ups = [EdgeUpdate(n - 5, n - 4, True), EdgeUpdate(n - 4, n - 3, True),
           EdgeUpdate(n - 5, n - 3, True), EdgeUpdate(n - 3, n - 2, False)]
    dt.apply_updates(ups)
    assert dt.plan.recount() == int(dt.count())
    snap = dt.plan.snapshot()
    assert int(dt.count()) == int(triangle_count_scipy(snap))


def test_forced_int32_past_the_bound_raises_from_the_lanes():
    g = _sparse_graph(N_LAST_INT32 + 1, seed=1)
    with pytest.raises(GraphTooLargeError):
        plan_edge_support(g, key_mode="int32")
    with pytest.raises(GraphTooLargeError):
        plan_dynamic_count(g, key_mode="int32")
    with pytest.raises(GraphTooLargeError):
        prep.check_edge_key_range(g.n, "int32")


def test_large_graph_counts_exact_in_wide_mode():
    """The acceptance bar: n well past 46341 counts correctly via the
    intersection AND dynamic lanes (wide keys), scipy-asserted."""
    g = erdos_renyi_graph(50_000, avg_degree=4.0, seed=3)
    oracle = int(triangle_count_scipy(g))
    res = TriangleCounter(g, CountOptions(algorithm="intersection")).count()
    assert int(res) == oracle
    dt = DynamicTriangleCounter(g, CountOptions(recount_interval=0))
    assert dt.plan.key_mode == "wide"
    assert int(dt.count()) == oracle
    rng = np.random.default_rng(7)
    pairs = rng.integers(0, g.n, size=(64, 2))
    ups = [EdgeUpdate(int(a), int(b), True) for a, b in pairs if a != b]
    dt.apply_updates(ups)
    assert dt.plan.recount() == int(dt.count())


def test_device_csr_promotes_past_the_bound():
    g = _sparse_graph(N_LAST_INT32 + 1, seed=2)
    lo, hi = g.edge_list_unique()
    d = DeviceCSR.from_edges(lo, hi, g.n)
    assert int(d.m) == g.m_undirected
    with pytest.raises(GraphTooLargeError):
        DeviceCSR.from_edges(lo, hi, g.n, key_mode="int32")


# -- wide-vs-int32 parity on graphs where both modes fit ----------------------

def _mode_counts(g):
    out = {}
    for mode in ("int32", "wide"):
        opts = CountOptions(algorithm="edge", key_mode=mode)
        out[mode] = int(TriangleCounter(g, opts).count())
    return out


def test_wide_mode_parity_small_graph():
    g = erdos_renyi_graph(300, avg_degree=8.0, seed=11)
    counts = _mode_counts(g)
    assert counts["int32"] == counts["wide"] == int(triangle_count_scipy(g))


def test_wide_mode_parity_dynamic_stream():
    g = erdos_renyi_graph(200, avg_degree=6.0, seed=5)
    rng = np.random.default_rng(13)
    pairs = rng.integers(0, g.n, size=(120, 2))
    ins = rng.random(120) < 0.7
    ups = [EdgeUpdate(int(a), int(b), bool(i))
           for (a, b), i in zip(pairs, ins) if a != b]
    counts = {}
    for mode in ("int32", "wide"):
        dt = DynamicTriangleCounter(
            g, CountOptions(key_mode=mode, recount_interval=0))
        dt.apply_updates(ups)
        dt.plan.recount()
        counts[mode] = int(dt.count())
    assert counts["int32"] == counts["wide"]


def test_wide_mode_parity_rng_soak():
    """The always-running numpy-rng twin of the hypothesis sweep below:
    random sparse graphs forced through both key modes must agree with
    each other and the oracle on the edge lane and a k-truss peel."""
    rng = np.random.default_rng(99)
    for trial in range(6):
        n = int(rng.integers(20, 400))
        m = int(rng.integers(10, 4 * n))
        src = rng.integers(0, n, size=m)
        dst = rng.integers(0, n, size=m)
        keep = src != dst
        if not keep.any():
            continue
        g = edges_to_csr(src[keep], dst[keep], n=n, name=f"soak{trial}")
        oracle = int(triangle_count_scipy(g))
        counts = _mode_counts(g)
        assert counts["int32"] == counts["wide"] == oracle, trial
        k32 = plan_edge_support(g, key_mode="int32").k_truss(3)
        kw = plan_edge_support(g, key_mode="wide").k_truss(3)
        assert k32.m_undirected == kw.m_undirected, trial


def test_wide_mode_parity_hypothesis():
    """Property form of the soak (runs when hypothesis is installed)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        n=st.integers(min_value=8, max_value=300),
        edges=st.lists(st.tuples(st.integers(0, 299), st.integers(0, 299)),
                       min_size=1, max_size=200),
    )
    @hyp.settings(max_examples=25, deadline=None)
    def check(n, edges):
        lo = np.array([a % n for a, b in edges])
        hi = np.array([b % n for a, b in edges])
        keep = lo != hi
        hyp.assume(keep.any())
        g = edges_to_csr(lo[keep], hi[keep], n=n, name="hyp")
        counts = _mode_counts(g)
        assert counts["int32"] == counts["wide"] \
            == int(triangle_count_scipy(g))

    check()


# -- the source audit ---------------------------------------------------------

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

# every file allowed to construct packed pair keys; each routes its n
# through resolve_edge_key_mode (directly or via check_edge_key_range /
# DeviceCSR.from_edges) before packing
_PACKED_KEY_FILES = {
    "graphs/device.py",    # the key layer itself + CSR/sort primitives
    "graphs/formats.py",   # host dedup — explicit int64, overflow-free
    "core/engine.py",      # edge/dynamic lanes, delta executables
    "core/prep.py",        # forward edge keys (host + device)
}

_PACK_RE = re.compile(
    r"\*\s*(?:n1|nn1|\(\s*(?:(?:self|g|dg)\s*\.\s*)?n\s*\+\s*1\s*\))")


def _code_only_lines(text):
    """line number -> that line's code tokens joined (docstrings and
    comments dropped), so the audit never trips on prose."""
    import io
    import tokenize
    lines = {}
    for tok in tokenize.generate_tokens(io.StringIO(text).readline):
        if tok.type in (tokenize.STRING, tokenize.COMMENT):
            continue
        if tok.type in (tokenize.NAME, tokenize.OP, tokenize.NUMBER):
            lines.setdefault(tok.start[0], []).append(tok.string)
    return {ln: " ".join(parts) for ln, parts in lines.items()}


def test_every_packed_key_site_lives_in_an_audited_file():
    """Tokenize the library and scan real code for pair-key packing
    arithmetic: any NEW site must either land in an audited file or extend
    this allowlist consciously (and route through resolve_edge_key_mode)."""
    offenders = []
    for path in SRC.rglob("*.py"):
        rel = path.relative_to(SRC).as_posix()
        text = path.read_text(encoding="utf-8")
        for i, code in sorted(_code_only_lines(text).items()):
            if _PACK_RE.search(code) and rel not in _PACKED_KEY_FILES:
                offenders.append(f"{rel}:{i}: {code.strip()}")
    assert not offenders, (
        "packed-key arithmetic outside the audited files (route it "
        "through resolve_edge_key_mode and extend _PACKED_KEY_FILES):\n"
        + "\n".join(offenders))


def test_the_audit_regex_is_not_vacuous():
    """The known packing sites must trip the scanner — if a refactor
    renames them away from ``* (n + 1)`` / ``* n1`` shapes, the audit
    needs a matching update, not a silent pass."""
    for rel in ("graphs/device.py", "core/engine.py", "core/prep.py"):
        text = (SRC / rel).read_text(encoding="utf-8")
        hits = [c for c in _code_only_lines(text).values()
                if _PACK_RE.search(c)]
        assert hits, f"{rel}: no packed-key sites found by the audit regex"


def test_the_checkpoint_is_the_only_graph_too_large_raise_site():
    raise_sites = []
    for path in SRC.rglob("*.py"):
        rel = path.relative_to(SRC).as_posix()
        for i, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            if re.search(r"raise\s+GraphTooLargeError", line):
                raise_sites.append(rel)
    assert raise_sites and set(raise_sites) == {"graphs/device.py"}, \
        raise_sites
    # and the checkpoint really is inside resolve_edge_key_mode
    device_src = (SRC / "graphs" / "device.py").read_text(encoding="utf-8")
    body = device_src.split("def resolve_edge_key_mode")[1]
    body = body.split("\ndef ")[0]
    assert "raise GraphTooLargeError" in body
