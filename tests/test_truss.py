"""Device edge lane == listing.py numpy oracle, bit for bit.

The cross-lane differential harness for the edge-analytics lane
(``algorithm="edge"``): per-edge support, the k-truss peel, and the truss
decomposition computed by the engine's cached edge executables + device peel
loop must reproduce ``repro.core.listing``'s host enumeration exactly — on
adversarial graphs (empty, isolated vertices, star, full clique, two cliques
sharing an edge, duplicate-edge/self-loop inputs) across every match
strategy and both prep backends, plus a hypothesis random-graph sweep. The
poison gate asserts the device peel never calls the host enumeration, and
the RUN_SLOW_TC tier extends the agreement check to the full Table-1
analogue datasets.
"""

import os
import warnings

import numpy as np
import pytest

from repro.graphs import (
    complete_graph,
    edges_to_csr,
    grid_graph,
    load_dataset,
    path_graph,
    rmat_graph,
    star_graph,
)
from repro.core import (
    CountOptions,
    TriangleCounter,
    TrussPlan,
    plan_edge_support,
    triangle_count_scipy,
)
import repro.core.listing as listing
import repro.core.prep as prep_module


def _two_cliques_shared_edge():
    """K6 on {0..5} and K6 on {4..9}, sharing the edge (4, 5)."""
    edges = [(a, b) for a in range(6) for b in range(a + 1, 6)]
    edges += [(a, b) for a in range(4, 10) for b in range(a + 1, 10)]
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    return edges_to_csr(src, dst, n=10, name="two-cliques")


def _dirty_input_graph():
    """Duplicate edges + self loops; ``edges_to_csr`` cleans them, and the
    lane must agree with the oracle on the cleaned graph."""
    src = np.array([0, 0, 0, 1, 1, 2, 2, 3, 4, 4, 4])
    dst = np.array([1, 1, 0, 2, 2, 0, 2, 3, 5, 5, 0])
    return edges_to_csr(src, dst, n=6, name="dirty6")


ADVERSARIAL = [
    edges_to_csr([], [], n=6, name="empty6"),
    edges_to_csr([0, 1], [1, 2], n=9, name="isolated9"),
    star_graph(16),
    complete_graph(9),
    _two_cliques_shared_edge(),
    _dirty_input_graph(),
    path_graph(10),
    grid_graph(5, spur_fraction=0.5, seed=3),
    rmat_graph(6, 8, seed=7),
]
_IDS = [g.name for g in ADVERSARIAL]

_KS = (3, 4, 5)


def _oracle_trussness(g):
    """Per-edge trussness via the listing oracle's peel, level by level."""
    su, sv = g.edge_list_unique()
    keys = su.astype(np.int64) * (g.n + 1) + sv
    truss = np.full(keys.shape[0], 2, dtype=np.int64)
    cur, k = g, 3
    while cur.m_undirected:
        nxt = listing._k_truss_host(cur, k)
        csu, csv = cur.edge_list_unique()
        ck = csu.astype(np.int64) * (g.n + 1) + csv
        nsu, nsv = nxt.edge_list_unique()
        nk = nsu.astype(np.int64) * (g.n + 1) + nsv
        removed = ck[~np.isin(ck, nk)]
        truss[np.searchsorted(keys, removed)] = k - 1
        cur, k = nxt, k + 1
    return su, sv, truss


def _assert_same_graph(a, b, ctx):
    assert a.n == b.n, ctx
    np.testing.assert_array_equal(a.row_ptr, b.row_ptr, err_msg=str(ctx))
    np.testing.assert_array_equal(a.col_idx, b.col_idx, err_msg=str(ctx))


# --- the differential harness -----------------------------------------------

@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
@pytest.mark.parametrize("prep_backend", ["device", "host"])
def test_edge_support_matches_oracle(g, prep_backend):
    tc = TriangleCounter(g, CountOptions(algorithm="edge",
                                         prep_backend=prep_backend))
    su, sv, supp = tc.edge_support()
    hsu, hsv, hsupp = listing._edge_support_host(g)
    np.testing.assert_array_equal(su, hsu)
    np.testing.assert_array_equal(sv, hsv)
    np.testing.assert_array_equal(supp, hsupp)
    assert supp.dtype == hsupp.dtype == np.int64
    # Σ support = 3Δ, and the lane counts through it
    assert int(supp.sum()) == 3 * triangle_count_scipy(g)
    assert tc.count() == triangle_count_scipy(g)


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
@pytest.mark.parametrize("prep_backend", ["device", "host"])
def test_k_truss_bit_identical_to_oracle(g, prep_backend):
    """Tentpole acceptance: the surviving edge set is bit-identical to the
    listing oracle for every k, on every adversarial graph."""
    tc = TriangleCounter(g, CountOptions(algorithm="edge",
                                         prep_backend=prep_backend))
    for k in _KS:
        _assert_same_graph(tc.k_truss(k), listing._k_truss_host(g, k),
                           (g.name, prep_backend, k))


@pytest.mark.parametrize("strategy", ["broadcast", "probe", "bitmap"])
def test_k_truss_agrees_across_strategies(strategy):
    for g in (complete_graph(9), _two_cliques_shared_edge(),
              rmat_graph(6, 8, seed=7)):
        tc = TriangleCounter(g, CountOptions(algorithm="edge",
                                             strategy=strategy))
        _, _, supp = tc.edge_support()
        np.testing.assert_array_equal(supp, listing._edge_support_host(g)[2])
        _assert_same_graph(tc.k_truss(4), listing._k_truss_host(g, 4),
                           (g.name, strategy))


@pytest.mark.parametrize("g", ADVERSARIAL, ids=_IDS)
def test_truss_decomposition_matches_oracle(g):
    su, sv, tr = TriangleCounter(g, algorithm="edge").truss_decomposition()
    osu, osv, otr = _oracle_trussness(g)
    np.testing.assert_array_equal(su, osu)
    np.testing.assert_array_equal(sv, osv)
    np.testing.assert_array_equal(tr, otr)


def test_truss_decomposition_values():
    """Spot values: K9's edges all have trussness 9; two K6s sharing an edge
    are uniformly 6-truss edges; star/path edges sit at 2."""
    _, _, tr = TriangleCounter(complete_graph(9), algorithm="edge") \
        .truss_decomposition()
    assert (tr == 9).all()
    _, _, tr = TriangleCounter(_two_cliques_shared_edge(), algorithm="edge") \
        .truss_decomposition()
    assert (tr == 6).all()
    _, _, tr = TriangleCounter(star_graph(8), algorithm="edge") \
        .truss_decomposition()
    assert (tr == 2).all()


# --- peel semantics ---------------------------------------------------------

def test_k_truss_max_iters_parity_with_oracle():
    """A truncated peel (max_iters smaller than the fixpoint distance) must
    match the oracle truncated at the same round count."""
    g = grid_graph(6, spur_fraction=0.4, seed=9)
    tc = TriangleCounter(g, CountOptions(algorithm="edge"))
    full = tc.k_truss(4)
    assert tc.plan.meta["peel_converged"]
    rounds = tc.plan.meta["peel_rounds"]
    assert rounds >= 2  # the spur cascade takes multiple rounds
    for it in (1, rounds - 1):
        _assert_same_graph(tc.k_truss(4, max_iters=it),
                           listing._k_truss_host(g, 4, max_iters=it), it)
        assert not tc.plan.meta["peel_converged"]
    _assert_same_graph(full, listing._k_truss_host(g, 4), "full")


def test_peel_early_exit_false_same_result():
    """peel_early_exit=False runs exactly max_peel_iters rounds but the
    fixpoint is stable, so the result is unchanged."""
    g = rmat_graph(6, 8, seed=7)
    a = TriangleCounter(g, CountOptions(algorithm="edge")).k_truss(4)
    tc = TriangleCounter(g, CountOptions(algorithm="edge", max_peel_iters=8,
                                         peel_early_exit=False))
    b = tc.k_truss(4)
    _assert_same_graph(a, b, "early-exit")
    assert tc.plan.meta["peel_rounds"] == 8  # ran the full budget
    assert tc.plan.meta["peel_converged"]


def test_truss_decomposition_rejects_truncating_peel_bound():
    """Trussness is only defined at the fixpoint: a max_peel_iters that
    truncates a level must raise, not silently inflate labels."""
    g = grid_graph(6, spur_fraction=0.4, seed=9)  # multi-round cascade
    tc = TriangleCounter(g, CountOptions(algorithm="edge", max_peel_iters=1))
    with pytest.raises(ValueError, match="max_peel_iters"):
        tc.truss_decomposition()
    # a sufficient bound agrees with the oracle again
    tc2 = TriangleCounter(g, CountOptions(algorithm="edge"))
    np.testing.assert_array_equal(tc2.truss_decomposition()[2],
                                  _oracle_trussness(g)[2])


def test_device_peel_never_calls_host_enumeration(monkeypatch):
    """Tentpole acceptance (the PR 4 numpy-poison pattern): under the
    default device prep, edge_support / k_truss / truss_decomposition never
    touch listing's host enumeration NOR the numpy prep helpers."""

    def _boom(*a, **k):
        raise AssertionError("host enumeration ran under the device peel")

    for name in ("enumerate_triangles", "edge_support", "k_truss",
                 "_edge_support_host", "_k_truss_host"):
        monkeypatch.setattr(listing, name, _boom)
    for name in ("prepare_intersection_buckets_host", "forward_edge_keys_host",
                 "orient_forward", "bucket_edges_by_degree",
                 "csr_to_padded_neighbors"):
        monkeypatch.setattr(prep_module, name, _boom)

    g = rmat_graph(6, 8, seed=7)
    tc = TriangleCounter(g, CountOptions(algorithm="edge"))
    assert tc.count() == triangle_count_scipy(g)
    assert int(tc.edge_support()[2].sum()) == 3 * triangle_count_scipy(g)
    t4 = tc.k_truss(4)
    assert t4.m_undirected <= g.m_undirected
    _, _, tr = tc.truss_decomposition()
    assert tr.shape == (g.m_undirected,)


def test_truss_plan_surface():
    """TrussPlan is the session plan for algorithm="edge" and exposes the
    replay/meta surface the facade consumes."""
    g = rmat_graph(6, 6, seed=5)
    tc = TriangleCounter(g, CountOptions(algorithm="edge"))
    res = tc.count()
    assert isinstance(res.plan, TrussPlan)
    assert res.algorithm == "edge"
    assert res.plan is tc._edge_plan()  # no sidecar for the edge session
    assert res.meta["edges"] == g.m_undirected
    assert res.plan.executions >= 1
    # a non-edge session builds ONE memoized sidecar
    tc2 = TriangleCounter(g, CountOptions(algorithm="intersection"))
    assert tc2.k_truss(3) is not None
    assert tc2._edge_plan() is tc2._edge_plan()
    # plan_edge_support is the engine-level entry
    plan = plan_edge_support(g)
    assert plan.count() == triangle_count_scipy(g)
    assert plan.num_stages == len(plan.shape_keys)


def test_edge_lane_rejects_oversized_id_range():
    from repro.graphs import GraphTooLargeError

    # n past the int32 pair-key bound now resolves to the wide lane
    # instead of raising -- that was the capacity-bug class.
    assert prep_module.check_edge_key_range(1 << 20) == "wide"
    # Forcing int32 on an oversized graph still rejects, with the lane
    # named and the typed error (a ValueError subclass, so old callers
    # catching ValueError keep working).
    with pytest.raises(GraphTooLargeError, match="int32"):
        prep_module.check_edge_key_range(1 << 20, "int32")
    # Past even the int64 bound there is no mode left.
    with pytest.raises(GraphTooLargeError, match="int64"):
        prep_module.check_edge_key_range(1 << 40)


def test_listing_shims_warn_and_agree():
    g = rmat_graph(6, 6, seed=5)
    with pytest.warns(DeprecationWarning):
        su, sv, supp = listing.edge_support(g)
    np.testing.assert_array_equal(supp, listing._edge_support_host(g)[2])
    with pytest.warns(DeprecationWarning):
        t = listing.k_truss(g, 4)
    _assert_same_graph(t, listing._k_truss_host(g, 4), "shim")


def test_facade_edge_methods_do_not_warn():
    g = rmat_graph(6, 6, seed=5)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        tc = TriangleCounter(g, CountOptions(algorithm="edge"))
        tc.edge_support()
        tc.k_truss(4)
        tc.truss_decomposition()


# --- hypothesis sweep -------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: skip, don't error
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    def _graph_strategy(max_n=24, max_m=90):
        # raw edge lists: self loops and duplicates exercised on purpose
        return st.integers(2, max_n).flatmap(
            lambda n: st.tuples(
                st.just(n),
                st.lists(st.tuples(st.integers(0, n - 1),
                                   st.integers(0, n - 1)),
                         min_size=0, max_size=max_m),
            ))

    @given(_graph_strategy(),
           st.sampled_from(["auto", "broadcast", "probe", "bitmap"]),
           st.sampled_from(["device", "host"]),
           st.integers(3, 5))
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_truss_differential(spec, strategy, prep_backend, k):
        n, edges = spec
        src = np.array([e[0] for e in edges], dtype=np.int64)
        dst = np.array([e[1] for e in edges], dtype=np.int64)
        g = edges_to_csr(src, dst, n=n)
        tc = TriangleCounter(g, CountOptions(
            algorithm="edge", strategy=strategy, prep_backend=prep_backend))
        su, sv, supp = tc.edge_support()
        hsu, hsv, hsupp = listing._edge_support_host(g)
        np.testing.assert_array_equal(su, hsu)
        np.testing.assert_array_equal(supp, hsupp)
        _assert_same_graph(tc.k_truss(k), listing._k_truss_host(g, k),
                           (n, strategy, prep_backend, k))


# --- full-dataset agreement (slow tier) -------------------------------------

_SLOW = bool(int(os.environ.get("RUN_SLOW_TC", "0")))

# the host oracle re-enumerates every triangle per peel round, so the dense
# scale-free sets cost minutes of single-core time; tier-1 runs none of
# these — RUN_SLOW_TC=1 opts in (same policy as test_engine's fig5 gate)
_TRUSS_SLOW_SETS = ["coauthors-like", "road-like", "citpatents-like"]


@pytest.mark.parametrize("name", _TRUSS_SLOW_SETS)
def test_full_dataset_truss_agreement(name):
    if not _SLOW:
        pytest.skip("full-dataset truss peel exceeds tier-1 budget; "
                    "RUN_SLOW_TC=1")
    g = load_dataset(name)
    tc = TriangleCounter(g, CountOptions(algorithm="edge"))
    np.testing.assert_array_equal(tc.edge_support()[2],
                                  listing._edge_support_host(g)[2])
    for k in (4, 6):
        _assert_same_graph(tc.k_truss(k), listing._k_truss_host(g, k),
                           (name, k))
