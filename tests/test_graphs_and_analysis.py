"""Graph substrate + HLO cost analyzer unit tests."""

import numpy as np
import pytest

from repro.graphs.formats import (
    BlockSparse, bucket_edges_by_degree, csr_to_padded_neighbors,
    degree_order_permutation, edges_to_csr, induced_subgraph, orient_forward,
    to_block_sparse, apply_permutation,
)
from repro.graphs import rmat_graph, complete_graph


def test_edges_to_csr_cleans_input():
    # dirty: self loops, duplicates, both directions
    g = edges_to_csr(np.array([0, 0, 1, 1, 2]), np.array([0, 1, 0, 2, 1]), n=3)
    assert g.m_undirected == 2  # (0,1), (1,2)
    np.testing.assert_array_equal(g.neighbors(1), [0, 2])


def test_degree_order_permutation():
    g = edges_to_csr(np.array([0, 0, 0, 1]), np.array([1, 2, 3, 2]), n=4)
    perm = degree_order_permutation(g)
    d = g.degrees
    assert (np.diff(d[perm]) >= 0).all()
    g2 = apply_permutation(g, perm)
    assert g2.m_undirected == g.m_undirected


def test_padded_neighbors_sentinel_and_truncate():
    g = edges_to_csr(np.array([0, 0, 0]), np.array([1, 2, 3]), n=4)
    nb = csr_to_padded_neighbors(g, pad_to=2)
    assert nb.shape == (4, 2)
    np.testing.assert_array_equal(nb[1], [0, 4])  # padded with n
    np.testing.assert_array_equal(nb[0], [1, 2])  # truncated row


def test_block_sparse_roundtrip():
    g = rmat_graph(7, 6, seed=3)
    bsr = to_block_sparse(g, block=32, part="full")
    dense = bsr.to_dense()[:g.n, :g.n]
    ref = g.to_scipy().toarray()
    np.testing.assert_array_equal(dense.astype(bool), ref.astype(bool))
    low = to_block_sparse(g, block=32, part="lower").to_dense()[:g.n, :g.n]
    assert (np.triu(low) == 0).all()


def test_bucketing_covers_all_edges():
    g = rmat_graph(8, 8, seed=1)
    dag = orient_forward(g)
    src = np.repeat(np.arange(dag.n, dtype=np.int32), dag.degrees)
    buckets = bucket_edges_by_degree(src, dag.col_idx, dag.degrees)
    assert sum(b["src"].shape[0] for b in buckets) == dag.m_directed
    for b in buckets:
        w = np.maximum(dag.degrees[b["src"]], dag.degrees[b["dst"]])
        assert (w <= b["width"]).all()


def test_induced_subgraph_relabels():
    g = complete_graph(5)
    mask = np.array([True, False, True, True, False])
    sub, old = induced_subgraph(g, mask)
    assert sub.n == 3 and sub.m_undirected == 3
    np.testing.assert_array_equal(old, [0, 2, 3])


def test_hlo_cost_analyzer_known_flops():
    """Scan with known trip count: analyzer must multiply the body."""
    import jax, jax.numpy as jnp
    from repro.launch.hlo_cost import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c.sum()

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32))
    hc = analyze_hlo(lowered.compile().as_text())
    want = 7 * 2 * 64 * 32 * 32  # 7 iterations of (64,32)@(32,32)
    assert abs(hc.flops - want) / want < 0.05, (hc.flops, want)


def test_hlo_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[4,8]<=[32], to_apply=%sum
  %ag = bf16[2048]{0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == pytest.approx(2 * (7 / 8) * 4096)
    assert out["all-gather"] == pytest.approx((3 / 4) * 4096)
