"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error

from hypothesis import given, settings, strategies as st

from repro.graphs.formats import edges_to_csr, apply_permutation, orient_forward
from repro.core import (
    CountOptions, TriangleCounter,
    triangle_count_intersection, triangle_count_matrix,
    triangle_count_subgraph, triangle_count_scipy,
)


def _graph_strategy(max_n=40, max_m=160):
    return st.integers(4, max_n).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                     min_size=0, max_size=max_m),
        ))


@given(_graph_strategy())
@settings(max_examples=40, deadline=None)
def test_all_methods_agree(spec):
    n, edges = spec
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    g = edges_to_csr(src, dst, n=n)
    truth = triangle_count_scipy(g)
    assert triangle_count_intersection(g) == truth
    assert triangle_count_matrix(g, block=16) == truth
    assert triangle_count_subgraph(g) == truth


@given(_graph_strategy(), st.sampled_from(["hash", "bfs"]))
@settings(max_examples=40, deadline=None)
def test_new_lanes_agree_on_random_graphs(spec, lane):
    """PR 7 lanes: the TRUST-style hash lane and the BFS lane agree with
    the scipy oracle on arbitrary random graphs — including the edge lists
    ``edges_to_csr`` has to clean first (self-loops, duplicate/multi-edges,
    both orientations of the same pair all appear in the raw lists)."""
    n, edges = spec
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    g = edges_to_csr(src, dst, n=n)
    truth = triangle_count_scipy(g)
    got = TriangleCounter(g, CountOptions(algorithm=lane)).count()
    assert got == truth, (lane, n, int(got), truth)


def _adversarial_graphs():
    """Named deterministic shapes the random strategy rarely lands on."""
    cases = {}
    # empty: no edges at all
    z = np.array([], dtype=np.int64)
    cases["empty"] = edges_to_csr(z, z, n=8)
    # self-loop-dirty: every edge doubled by loops at both endpoints
    src = np.array([0, 1, 2, 0, 1, 2, 3, 3], dtype=np.int64)
    dst = np.array([1, 2, 0, 0, 1, 2, 3, 0], dtype=np.int64)
    cases["self-loop-dirty"] = edges_to_csr(src, dst, n=5)
    # multi-edge: each triangle edge repeated 3x in both orientations
    tri = [(0, 1), (1, 2), (2, 0), (2, 3)]
    src = np.array([a for a, b in tri for _ in range(3)]
                   + [b for a, b in tri for _ in range(3)], dtype=np.int64)
    dst = np.array([b for a, b in tri for _ in range(3)]
                   + [a for a, b in tri for _ in range(3)], dtype=np.int64)
    cases["multi-edge"] = edges_to_csr(src, dst, n=5)
    # star: max skew, zero triangles
    hub = np.zeros(24, dtype=np.int64)
    leaves = np.arange(1, 25, dtype=np.int64)
    cases["star"] = edges_to_csr(hub, leaves, n=25)
    # clique: max density, n-choose-3 triangles
    k = 12
    pairs = [(i, j) for i in range(k) for j in range(i + 1, k)]
    cases["clique"] = edges_to_csr(
        np.array([a for a, _ in pairs], dtype=np.int64),
        np.array([b for _, b in pairs], dtype=np.int64), n=k)
    return cases


@pytest.mark.parametrize("case", sorted(_adversarial_graphs()))
@pytest.mark.parametrize("lane", ["hash", "bfs"])
def test_new_lanes_agree_on_adversarial_shapes(case, lane):
    """The shapes that break naive orientations: empty graphs, self-loop
    and multi-edge dirt, the star (max skew), and the clique (max
    density)."""
    g = _adversarial_graphs()[case]
    truth = triangle_count_scipy(g)
    got = TriangleCounter(g, CountOptions(algorithm=lane)).count()
    assert got == truth, (case, lane, int(got), truth)


@given(_graph_strategy(), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_permutation_invariance(spec, seed):
    """Relabeling vertices never changes the triangle count."""
    n, edges = spec
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    g = edges_to_csr(src, dst, n=n)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n).astype(np.int32)
    g2 = apply_permutation(g, perm)
    assert triangle_count_intersection(g2) == triangle_count_intersection(g)


@given(_graph_strategy())
@settings(max_examples=25, deadline=None)
def test_isolated_vertices_invariance(spec):
    """Padding the vertex set with isolated vertices changes nothing."""
    n, edges = spec
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    g = edges_to_csr(src, dst, n=n)
    g_pad = edges_to_csr(src, dst, n=n + 17)
    assert triangle_count_matrix(g_pad, block=16) == \
        triangle_count_matrix(g, block=16)


@given(_graph_strategy())
@settings(max_examples=25, deadline=None)
def test_forward_orientation_halves_edges(spec):
    """The DAG orientation keeps exactly one direction per undirected edge
    (the paper's '[filter] removes half of the workload')."""
    n, edges = spec
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    g = edges_to_csr(src, dst, n=n)
    dag = orient_forward(g)
    assert dag.m_directed == g.m_undirected
    # acyclic by (degree, id) rank: every edge increases the rank
    d = g.degrees
    s2 = np.repeat(np.arange(dag.n), dag.degrees)
    rank_src = d[s2] * (g.n + 1) + s2
    rank_dst = d[dag.col_idx] * (g.n + 1) + dag.col_idx
    assert (rank_src < rank_dst).all()
