"""Multi-device semantics on 8 placeholder CPU devices.

Runs in a SUBPROCESS so the XLA device-count flag never leaks into the other
tests (jax locks device count at first init)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_mesh
from repro.graphs import rmat_graph, grid_graph
from repro.core import (triangle_count_matrix_distributed,
                        triangle_count_intersection_distributed,
                        triangle_count_scipy)

import warnings
from repro.core import TriangleCounter, CountOptions
from repro.core.engine import (plan_triangle_count, plan_edge_support,
                               executable_cache_info)
from repro.core.registry import choose_algorithm
from repro.core.calibrate import choose_measured

out = {}
mesh = make_mesh((4, 2), ("data", "model"))
mesh1 = make_mesh((8,), ("data",))
g = rmat_graph(9, 8, seed=5)
truth = triangle_count_scipy(g)
g2 = grid_graph(12, seed=2)
t2 = triangle_count_scipy(g2)

# --- parity sweep: lane x strategy x prep_backend vs the scipy oracle -----
for lane in ("intersection_distributed", "matrix_distributed"):
    for strat in ("auto", "probe", "broadcast"):
        for prep in ("device", "host"):
            opts = CountOptions(algorithm=lane, strategy=strat,
                                prep_backend=prep, block=32)
            r = TriangleCounter(g, opts, mesh=mesh1).count()
            out["%s_%s_%s" % (lane, strat, prep)] = r.count == truth

# 2D mesh + the deprecated shims (one DeprecationWarning, bit-identical)
out["matrix_2d"] = (plan_triangle_count(g, "matrix_distributed", mesh=mesh,
                                        block=32).count() == truth)
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    a = triangle_count_matrix_distributed(g2, mesh1, block=16)
    b = triangle_count_intersection_distributed(g2, mesh)
deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
out["shim_warns"] = len(deps) == 2
out["shim_parity"] = a == t2 and b == t2

# --- zero-recompile steady state ------------------------------------------
p = plan_triangle_count(g, "intersection_distributed", mesh=mesh1)
p.count()
m0 = executable_cache_info()["misses"]
for _ in range(3):
    p.count()
p_again = plan_triangle_count(g, "intersection_distributed", mesh=mesh1)
out["warm_parity"] = p_again.count() == truth
out["steady_recompiles"] = executable_cache_info()["misses"] - m0 == 0

# --- exactly one compile on a shard-shape change --------------------------
# matrix lane = single stage; (8,) and (4,2) have equal device counts so the
# per-shard shapes match and ONLY the mesh cache-key component differs.
# block=64 keeps this pair's cache keys disjoint from every earlier check
# AND makes the tile deal non-divisible (8 shards over a tile count that is
# not a multiple of 8) for the padding regression below.
pm1 = plan_triangle_count(g, "matrix_distributed", mesh=mesh1, block=64)
pm1.count()
m0 = executable_cache_info()["misses"]
pm2 = plan_triangle_count(g, "matrix_distributed", mesh=mesh, block=64)
out["reshard_parity"] = pm2.count() == truth
out["reshard_compiles"] = executable_cache_info()["misses"] - m0 == 1

# --- shard balance: max/min per-shard padded work <= 2x -------------------
work = p.meta["shard_work"]
out["balance"] = (min(work) > 0 and max(work) / min(work) <= 2.0
                  and len(work) == 8)

# --- padding is length-gated: non-divisible deals + poisoned padding ------
# the deal is non-divisible (some shard has fewer real rows than dealt),
# and overwriting the padding with adversarial values must not change the
# count: the executables gate on the per-shard valid length, they do not
# rely on sentinel fill values surviving.
import jax.numpy as jnp
st = next(s for s in p.stages
          if (np.asarray(s.args[2]) < s.args[0].shape[1]).any())
u, v, valid = (np.asarray(x).copy() for x in st.args)
base = int(st.executable(*st.args))
for s in range(u.shape[0]):
    u[s, valid[s]:, :] = 7    # real vertex ids: u n v would "match"
    v[s, valid[s]:, :] = 7
poisoned = int(st.executable(jnp.asarray(u), jnp.asarray(v), st.args[2]))
out["poison_intersect"] = poisoned == base

stm = pm1.stages[0]
l, uu, aa, vv = (np.asarray(x).copy() for x in stm.args)
basem = float(stm.executable(*stm.args))
out["matrix_nondivisible"] = (np.asarray(vv) < l.shape[1]).any()
for s in range(l.shape[0]):
    l[s, vv[s]:] = np.nan     # NaN-poison: any touch would propagate
    uu[s, vv[s]:] = np.nan
    aa[s, vv[s]:] = np.nan
poim = float(stm.executable(jnp.asarray(l), jnp.asarray(uu),
                            jnp.asarray(aa), stm.args[3]))
out["poison_matrix"] = poim == basem

# --- chooser promotion: auto lands on a distributed lane under a mesh -----
out["auto_promote"] = choose_algorithm(g, mesh=mesh1).endswith("_distributed")
out["auto_measured"] = choose_measured(g, mesh=mesh1).endswith("_distributed")
out["auto_single"] = not choose_algorithm(g).endswith("_distributed")
ra = TriangleCounter(g, CountOptions(algorithm="auto", chooser="measured"),
                     mesh=mesh1).count()
out["auto_parity"] = (ra.count == truth
                      and ra.algorithm.endswith("_distributed"))

# --- distributed edge-support parity --------------------------------------
sup_d = np.asarray(plan_edge_support(g2, mesh=mesh1).support())
sup_1 = np.asarray(plan_edge_support(g2).support())
out["edge_parity"] = sup_d.shape == sup_1.shape and (sup_d == sup_1).all()

# gradient parity: sharded train step == single-device reference
from repro.models.registry import get_model, get_reduced_config
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.train.sharding import param_shardings, batch_sharding
from repro.train.data import SyntheticDataConfig, make_batch
from repro.models.meshctx import activation_mesh

cfg = get_reduced_config("gemma2-2b")
model = get_model(cfg)
opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, moment_dtype=jnp.float32)
params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                               dtype=jnp.float32)
batch = {k: jnp.asarray(v) for k, v in make_batch(
    cfg, SyntheticDataConfig(8, 17), 0).items()}
step = make_train_step(model, cfg, opt_cfg, microbatches=2)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

p_sh = param_shardings(params, mesh)
b_sh = {k: batch_sharding(mesh, v) for k, v in batch.items()}
with activation_mesh(mesh):
    sharded = jax.jit(step, in_shardings=(p_sh, None, b_sh)).lower(
        params, opt, batch).compile()
p_dist, _, m_dist = sharded(jax.device_put(params, p_sh), opt,
                            jax.tree.map(lambda x, s: jax.device_put(x, s),
                                         batch, b_sh))
out["loss_parity"] = bool(np.isclose(float(m_ref["loss"]),
                                     float(m_dist["loss"]), rtol=1e-4))
flat_r = jax.tree.leaves(p_ref)
flat_d = jax.tree.leaves(p_dist)
out["param_parity"] = all(
    np.allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)
    for a, b in zip(flat_r, flat_d))

# compressed psum on a real mesh axis
from repro.train.compression import ef_psum, ef_init
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax ships it under experimental
    from jax.experimental.shard_map import shard_map

def worker(g):
    deq, _ = ef_psum({"w": g}, ef_init({"w": g}), "data")
    return deq["w"]

gs = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) * 1e-3
got = jax.jit(shard_map(worker, mesh=mesh1, in_specs=P("data"),
                        out_specs=P("data")))(gs)
want = gs.sum(axis=0, keepdims=True)
out["ef_psum"] = bool(np.allclose(np.asarray(got[0]), np.asarray(want[0]),
                                  atol=2e-3))
print("RESULT:" + json.dumps({k: bool(v) for k, v in out.items()}))
"""


def test_distributed_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert all(out.values()), out
