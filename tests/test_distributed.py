"""Multi-device semantics on 8 placeholder CPU devices.

Runs in a SUBPROCESS so the XLA device-count flag never leaks into the other
tests (jax locks device count at first init)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_mesh
from repro.graphs import rmat_graph, grid_graph
from repro.core import (triangle_count_matrix_distributed,
                        triangle_count_intersection_distributed,
                        triangle_count_scipy)

out = {}
mesh = make_mesh((4, 2), ("data", "model"))
g = rmat_graph(9, 8, seed=5)
truth = triangle_count_scipy(g)
out["matrix_2d"] = triangle_count_matrix_distributed(g, mesh, block=32) == truth
out["intersect_2d"] = triangle_count_intersection_distributed(g, mesh) == truth
g2 = grid_graph(12, seed=2)
t2 = triangle_count_scipy(g2)
mesh1 = make_mesh((8,), ("data",))
out["matrix_1d"] = triangle_count_matrix_distributed(g2, mesh1, block=16) == t2

# gradient parity: sharded train step == single-device reference
from repro.models.registry import get_model, get_reduced_config
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.train.sharding import param_shardings, batch_sharding
from repro.train.data import SyntheticDataConfig, make_batch
from repro.models.meshctx import activation_mesh

cfg = get_reduced_config("gemma2-2b")
model = get_model(cfg)
opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, moment_dtype=jnp.float32)
params, opt = init_train_state(model, cfg, opt_cfg, jax.random.key(0),
                               dtype=jnp.float32)
batch = {k: jnp.asarray(v) for k, v in make_batch(
    cfg, SyntheticDataConfig(8, 17), 0).items()}
step = make_train_step(model, cfg, opt_cfg, microbatches=2)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

p_sh = param_shardings(params, mesh)
b_sh = {k: batch_sharding(mesh, v) for k, v in batch.items()}
with activation_mesh(mesh):
    sharded = jax.jit(step, in_shardings=(p_sh, None, b_sh)).lower(
        params, opt, batch).compile()
p_dist, _, m_dist = sharded(jax.device_put(params, p_sh), opt,
                            jax.tree.map(lambda x, s: jax.device_put(x, s),
                                         batch, b_sh))
out["loss_parity"] = bool(np.isclose(float(m_ref["loss"]),
                                     float(m_dist["loss"]), rtol=1e-4))
flat_r = jax.tree.leaves(p_ref)
flat_d = jax.tree.leaves(p_dist)
out["param_parity"] = all(
    np.allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)
    for a, b in zip(flat_r, flat_d))

# compressed psum on a real mesh axis
from repro.train.compression import ef_psum, ef_init
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax ships it under experimental
    from jax.experimental.shard_map import shard_map

def worker(g):
    deq, _ = ef_psum({"w": g}, ef_init({"w": g}), "data")
    return deq["w"]

gs = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) * 1e-3
got = jax.jit(shard_map(worker, mesh=mesh1, in_specs=P("data"),
                        out_specs=P("data")))(gs)
want = gs.sum(axis=0, keepdims=True)
out["ef_psum"] = bool(np.allclose(np.asarray(got[0]), np.asarray(want[0]),
                                  atol=2e-3))
print("RESULT:" + json.dumps(out))
"""


def test_distributed_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert all(out.values()), out
