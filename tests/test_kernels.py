"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.intersect import (
    intersect_counts, intersect_counts_bitmap_pallas, intersect_counts_pallas,
    intersect_counts_probe_pallas, intersect_counts_ref,
)
from repro.kernels.masked_spgemm import masked_spgemm_pallas, masked_spgemm_ref
from repro.kernels.flash_attention import (
    flash_attention_pallas, flash_attention_ref,
)


# ------------------------------------------------------------- intersect

@pytest.mark.parametrize("e,w,dtype", [
    (64, 8, jnp.int32), (256, 32, jnp.int32), (512, 16, jnp.int32),
    (128, 128, jnp.int32),
])
def test_intersect_pallas_matches_ref(e, w, dtype):
    rng = np.random.default_rng(e * w)
    n = 1000
    u = np.sort(rng.integers(0, n, size=(e, w)), axis=1).astype(np.int32)
    v = np.sort(rng.integers(0, n, size=(e, w)), axis=1).astype(np.int32)
    # dedup within rows (sorted lists must be strictly increasing to model
    # neighbor lists); replace dups with unique sentinels
    for arr, base in ((u, n), (v, 2 * n)):
        dup = np.zeros_like(arr, dtype=bool)
        dup[:, 1:] = arr[:, 1:] == arr[:, :-1]
        arr[dup] = base + np.arange(dup.sum())
        arr.sort(axis=1)
    ref = intersect_counts_ref(jnp.asarray(u), jnp.asarray(v))
    pal = intersect_counts_pallas(jnp.asarray(u), jnp.asarray(v),
                                  tile_edges=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    probe = intersect_counts(jnp.asarray(u), jnp.asarray(v), backend="jnp")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(probe))
    # the other two strategy kernels compute the same counts
    probe_pal = intersect_counts_probe_pallas(
        jnp.asarray(u), jnp.asarray(v), tile_edges=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(probe_pal))
    bits = ((2 * n + e * w) + 31) // 32 * 32  # cover the dedup sentinels too
    bm_pal = intersect_counts_bitmap_pallas(
        jnp.asarray(u), jnp.asarray(v), num_bits=bits, tile_edges=64,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(bm_pal))


def test_intersect_padding_rows():
    """Non-multiple edge counts pad with disjoint sentinels — zero matches."""
    u = jnp.asarray(np.arange(10 * 4).reshape(10, 4), dtype=jnp.int32)
    v = jnp.asarray(np.arange(10 * 4).reshape(10, 4), dtype=jnp.int32)
    out = intersect_counts(u, v, backend="pallas", tile_edges=8)
    np.testing.assert_array_equal(np.asarray(out), np.full(10, 4))


# ---------------------------------------------------------- masked spgemm

@pytest.mark.parametrize("t,b,dtype", [
    (8, 16, jnp.float32), (16, 32, jnp.float32), (24, 8, jnp.float32),
    (8, 128, jnp.bfloat16),
])
def test_masked_spgemm_pallas_matches_ref(t, b, dtype):
    rng = np.random.default_rng(t * b)
    mk = lambda: (rng.random((t, b, b)) < 0.2).astype(np.float32)
    l, u, a = mk(), mk(), mk()
    ref = masked_spgemm_ref(jnp.asarray(l), jnp.asarray(u), jnp.asarray(a))
    pal = masked_spgemm_pallas(
        jnp.asarray(l, dtype), jnp.asarray(u, dtype), jnp.asarray(a, dtype),
        tile_triples=8, interpret=True)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), rtol=rtol,
                               atol=1e-3)


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,s,hq,hkv,hd,causal,window,cap", [
    (2, 64, 4, 2, 16, True, None, None),
    (1, 128, 8, 1, 32, True, 32, 50.0),
    (2, 64, 4, 4, 16, False, None, None),
    (1, 256, 2, 1, 64, True, None, None),
    (1, 64, 4, 2, 16, True, 16, None),
])
def test_flash_pallas_matches_ref(b, s, hq, hkv, hd, causal, window, cap):
    ks = jax.random.split(jax.random.key(s + hq + hd), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    pal = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 cap=cap, block_q=32, block_k=32,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), rtol=2e-3,
                               atol=2e-3)


def test_flash_bf16():
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.bfloat16)
    ref = flash_attention_ref(q, k, v)
    pal = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(pal, np.float32),
        rtol=5e-2, atol=5e-2)
