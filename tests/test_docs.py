"""Tier-1 half of the docs gate: every relative link in README.md and
docs/*.md resolves. The README quickstart doctest — the slow, jax-importing
half — runs only in the CI `docs` job (tools/check_docs.py does both), so
the link check is not paid for twice per push."""

import importlib.util
import pathlib

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", _ROOT / "tools" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_relative_links_resolve():
    errors = _load_check_docs().check_links()
    assert not errors, "\n".join(errors)
