"""Plan/execute engine: plan reuse, executable caching, backend agreement,
and fig5-dataset agreement with the oracle."""

import os

import numpy as np
import pytest

import repro.core.engine as engine
from repro.graphs import grid_graph, load_dataset, rmat_graph
from repro.core import (
    plan_triangle_count,
    triangle_count_intersection,
    triangle_count_matrix,
    triangle_count_subgraph,
    triangle_count_scipy,
    executable_cache_info,
)
from repro.configs.paper import DATASETS_FIG5

G_RMAT = rmat_graph(8, 8, seed=21)
G_GRID = grid_graph(10, seed=22)

_ONE_SHOT = {
    "intersection": lambda g: triangle_count_intersection(g),
    "matrix": lambda g: triangle_count_matrix(g, block="auto"),
    "subgraph": lambda g: triangle_count_subgraph(g),
}


@pytest.mark.parametrize("g", [G_RMAT, G_GRID], ids=lambda g: g.name)
@pytest.mark.parametrize("algorithm", sorted(_ONE_SHOT))
def test_plan_matches_one_shot_and_is_repeatable(g, algorithm):
    truth = triangle_count_scipy(g)
    assert _ONE_SHOT[algorithm](g) == truth
    plan = plan_triangle_count(g, algorithm)
    assert plan.count() == truth
    assert plan.count() == truth  # replay: same plan, same result
    assert plan.executions == 2
    assert plan.prep_seconds > 0.0


def test_cached_count_runs_no_host_prep(monkeypatch):
    """A cached plan's count() is a pure device replay: poison every host
    prep entry point after plan construction and counting must still work."""
    truth = triangle_count_scipy(G_RMAT)
    plans = [plan_triangle_count(G_RMAT, a) for a in sorted(_ONE_SHOT)]

    def _boom(*args, **kwargs):
        raise AssertionError("host-side prep ran on a cached TrianglePlan")

    for name in ("prepare_intersection_buckets", "build_tile_schedule",
                 "peel_to_two_core", "orient_forward", "bucket_edges_by_degree",
                 "csr_to_padded_neighbors", "to_block_sparse",
                 "induced_subgraph", "degree_order_permutation",
                 "apply_permutation"):
        monkeypatch.setattr(engine, name, _boom)
    for plan in plans:
        assert plan.count() == truth


def test_executable_cache_shared_across_plans():
    g = rmat_graph(8, 6, seed=33)
    p1 = plan_triangle_count(g, "intersection")
    info1 = executable_cache_info()
    p2 = plan_triangle_count(g, "intersection")
    info2 = executable_cache_info()
    # identical bucket shapes ⇒ no new executables, only hits
    assert p1.shape_keys == p2.shape_keys
    assert info2["size"] == info1["size"]
    assert info2["misses"] == info1["misses"]
    assert info2["hits"] >= info1["hits"] + p2.num_stages
    assert p1.count() == p2.count() == triangle_count_scipy(g)


def test_subgraph_plan_shares_intersection_executables():
    """The SM join runs on the same cached intersection executables."""
    g = grid_graph(12, spur_fraction=0.3, seed=35)
    p_sub = plan_triangle_count(g, "subgraph")
    for st in p_sub.stages:
        key = ("intersection", st.strategy, "jnp", True, st.bitmap_bits,
               st.shape_key)
        assert engine._EXECUTABLE_CACHE[key] is st.executable


def test_strategy_override_and_auto_selection():
    """strategy="auto" (the default) resolves per bucket via choose_strategy;
    forced overrides apply to every bucket and still match the oracle."""
    from repro.core import STRATEGIES, choose_strategy

    g = rmat_graph(9, 10, seed=34)
    truth = triangle_count_scipy(g)
    auto = plan_triangle_count(g, "intersection")
    _, stats = auto.count_with_stats()
    assert stats["strategy"] == "auto"
    assert stats["bucket_strategies"] == [
        (w, choose_strategy(w, g.n + 2)) for w, _ in stats["bucket_strategies"]
    ]
    for forced in STRATEGIES:
        plan = plan_triangle_count(g, "intersection", strategy=forced)
        assert all(st.strategy == forced for st in plan.stages)
        assert plan.count() == truth, forced
        if forced == "bitmap":  # forced beyond the packed width still works
            assert all(st.bitmap_bits >= g.n + 2 for st in plan.stages)


def test_auto_selects_bitmap_when_id_range_fits():
    """Dense small graph: every id fits the top bucket's packed width, so the
    cost model hands that bucket to the bitmap core."""
    from repro.graphs import complete_graph

    g = complete_graph(100)  # forward lists are 128-wide; 102 ids < 128 bits
    plan = plan_triangle_count(g, "intersection")
    assert ("bitmap" in {s for _, s in plan.meta["bucket_strategies"]}), \
        plan.meta["bucket_strategies"]
    assert plan.count() == triangle_count_scipy(g)


def test_cache_keys_distinguish_strategies():
    """Same bucket shapes, different strategy ⇒ different cache entries."""
    g = rmat_graph(8, 6, seed=38)
    p1 = plan_triangle_count(g, "intersection", strategy="probe")
    p2 = plan_triangle_count(g, "intersection", strategy="broadcast")
    assert p1.shape_keys == p2.shape_keys
    for s1, s2 in zip(p1.stages, p2.stages):
        assert s1.executable is not s2.executable
    assert p1.count() == p2.count() == triangle_count_scipy(g)


_WIDTHS = (4, 8, 16, 64)


def test_pallas_interpret_vs_jnp_agree_on_every_bucket_width():
    g = rmat_graph(9, 10, seed=34)
    pj = plan_triangle_count(g, "intersection", backend="jnp", widths=_WIDTHS)
    pp = plan_triangle_count(g, "intersection", backend="pallas",
                             interpret=True, widths=_WIDTHS)
    assert pj.shape_keys == pp.shape_keys
    assert pj.num_stages >= 3  # several degree classes actually exercised
    for sj, sp in zip(pj.stages, pp.stages):
        # per-bucket agreement, not just the final sum
        assert int(sj.executable(*sj.args)) == int(sp.executable(*sp.args)), \
            sj.shape_key
    assert pj.count() == pp.count() == triangle_count_scipy(g)


def test_pallas_interpret_vs_jnp_matrix():
    g = rmat_graph(8, 6, seed=36)
    truth = triangle_count_scipy(g)
    for block in (16, 32):
        pj = plan_triangle_count(g, "matrix", block=block, backend="jnp")
        pp = plan_triangle_count(g, "matrix", block=block, backend="pallas",
                                 interpret=True)
        assert pj.count() == pp.count() == truth


def test_full_variant_divisor():
    g = rmat_graph(8, 8, seed=37)
    plan = plan_triangle_count(g, "intersection", variant="full")
    assert plan.divisor == 6
    assert plan.count() == triangle_count_scipy(g)


def test_empty_and_triangle_free_graphs():
    from repro.graphs import path_graph, star_graph
    for g in (path_graph(30), star_graph(30)):
        for algorithm in sorted(_ONE_SHOT):
            plan = plan_triangle_count(g, algorithm)
            assert plan.count() == 0, (g.name, algorithm)


# --- fig5 dataset agreement -------------------------------------------------
# Matrix on the dense scale-free sets costs minutes of single-core einsum
# (citpatents-like alone is ~1 min; copapers-like is ~10 min), so tier-1
# covers the benchmark's budget subset and RUN_SLOW_TC=1 opts into the rest —
# the same budget policy benchmarks/run.py applies to fig5 cells.
_MATRIX_TIER1 = {"coauthors-like", "road-like"}
_SLOW = bool(int(os.environ.get("RUN_SLOW_TC", "0")))

_DATASET_CACHE: dict = {}


def _dataset(name):
    if name not in _DATASET_CACHE:
        g = load_dataset(name)
        _DATASET_CACHE[name] = (g, triangle_count_scipy(g))
    return _DATASET_CACHE[name]


@pytest.mark.parametrize("name", DATASETS_FIG5)
def test_fig5_intersection_and_subgraph_match_oracle(name):
    g, truth = _dataset(name)
    assert plan_triangle_count(g, "intersection").count() == truth
    assert plan_triangle_count(g, "subgraph").count() == truth


@pytest.mark.parametrize("name", DATASETS_FIG5)
def test_fig5_matrix_matches_oracle(name):
    if name not in _MATRIX_TIER1 and not _SLOW:
        pytest.skip("dense tile schedule exceeds tier-1 budget; RUN_SLOW_TC=1")
    g, truth = _dataset(name)
    assert plan_triangle_count(g, "matrix", block="auto").count() == truth


# --- the bounded, thread-safe executable cache (PR 8) ------------------------


def test_cache_info_and_clear_caches_helpers():
    """The public introspection pair: cache_info() = counters + live keys
    (so tests stop poking the private dict), clear_caches() resets both."""
    from repro.core import cache_info, clear_caches

    g = rmat_graph(8, 6, seed=41)
    plan = plan_triangle_count(g, "intersection")
    info = cache_info()
    assert {"size", "hits", "misses", "maxsize", "evictions",
            "keys"} <= set(info)
    assert info["size"] == len(info["keys"])
    for st in plan.stages:  # every stage's key is visible in the snapshot
        key = ("intersection", st.strategy, "jnp", True, st.bitmap_bits,
               st.shape_key)
        assert key in info["keys"]
    # executable_cache_info is the same counters minus the keys
    assert executable_cache_info() == {k: v for k, v in cache_info().items()
                                       if k != "keys"}
    clear_caches()
    info = cache_info()
    assert info["size"] == info["hits"] == info["misses"] == 0
    assert info["evictions"] == 0
    assert plan.count() == triangle_count_scipy(g)  # live plans survive


def test_set_cache_limit_bounds_and_evicts_lru():
    from repro.core import cache_info, clear_caches, set_cache_limit

    clear_caches()
    g = rmat_graph(8, 6, seed=42)
    plan = plan_triangle_count(g, "intersection")
    assert plan.num_stages >= 2
    truth = triangle_count_scipy(g)
    size = cache_info()["size"]
    old = set_cache_limit(1)
    try:
        info = cache_info()
        assert info["maxsize"] == 1
        assert info["size"] == 1  # shrunk immediately...
        assert info["evictions"] == size - 1  # ...evicting LRU entries
        # the evicted stages still run (plans hold direct references) and
        # a re-fetch rebuilds them as cache misses, not errors
        assert plan.count() == truth
        before = cache_info()["misses"]
        plan2 = plan_triangle_count(g, "intersection")
        assert plan2.count() == truth
        assert cache_info()["misses"] > before  # bound forced recompiles
        with pytest.raises(ValueError, match="maxsize"):
            set_cache_limit(0)
    finally:
        assert set_cache_limit(old) == 1
    clear_caches()


def test_racing_same_key_requests_compile_once():
    """The get-or-compile lock: N threads racing one cold key produce ONE
    miss and all receive the identical executable object."""
    import threading

    from repro.core import clear_caches

    clear_caches()
    shape = (64, 32)
    barrier = threading.Barrier(8)
    got, errors = [], []

    def fetch():
        try:
            barrier.wait(timeout=30)
            fn = engine.get_executable("intersection", "jnp", True, shape,
                                       strategy="probe")
            got.append(fn)
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=fetch) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors, errors
    assert len(got) == 8
    assert all(fn is got[0] for fn in got)
    info = executable_cache_info()
    assert info["misses"] == 1
    assert info["hits"] == 7
    clear_caches()


def test_builder_failure_releases_the_pending_claim():
    """A builder that raises must not wedge later requests for the key."""
    from repro.core import clear_caches

    clear_caches()
    with pytest.raises(ValueError, match="unresolved strategy"):
        engine.get_executable("intersection", "jnp", True, (8, 8),
                              strategy="nope")
    key = ("k", "broken")
    with pytest.raises(RuntimeError, match="boom"):
        engine._EXECUTABLE_CACHE.get_or_build(
            key, lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    # the key is claimable again, not deadlocked on the failed attempt
    assert engine._EXECUTABLE_CACHE.get_or_build(key, lambda: "ok") == "ok"
    clear_caches()
