"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  table1_*   — dataset statistics (derived = exact triangle count)
  fig5_*     — wall-clock per TC method per dataset, normalized to the
               sequential CPU baseline (derived = speedup ×; the paper's
               Fig. 5 bar chart)
  fig6_*     — runtime vs Σd² scaling for intersection- and matrix-based TC
               (derived = fitted log-log slope; the paper's Fig. 6 shows
               slope ≈ 1) plus the leading-constant ratio matrix/intersection
               (paper: ~20×)

CPU-only proxy: all methods run their jnp backends on the host; relative
orderings (intersection-filtered fastest, matrix slowest with a large
constant, SM wins from pruning on mesh-like graphs) are the reproducible
claims — see EXPERIMENTS.md §Paper-validation.
"""

from __future__ import annotations

import time

import numpy as np

from repro.graphs import DATASETS, load_dataset
from repro.core import (
    triangle_count_intersection, triangle_count_matrix,
    triangle_count_subgraph, triangle_count_scipy,
)
from repro.graphs.generators import rmat_graph
from repro.configs.paper import DATASETS_FIG5, FIG6_SCALES, FIG6_EDGE_FACTOR

_ROWS = []


def _emit(name: str, us: float, derived) -> None:
    row = f"{name},{us:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def _time(fn, *, warmup: int = 1, iters: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def table1() -> None:
    for name in DATASETS_FIG5:
        g = load_dataset(name)
        t0 = time.perf_counter()
        tri = triangle_count_scipy(g)
        us = (time.perf_counter() - t0) * 1e6
        _emit(f"table1_{name}_v{g.n}_e{g.m_undirected}_d{g.max_degree}"
              f"_{DATASETS[name]['type']}", us, tri)


_METHODS = {
    "tc-intersection-filtered": lambda g: triangle_count_intersection(
        g, variant="filtered"),
    "tc-intersection-full": lambda g: triangle_count_intersection(
        g, variant="full"),
    "tc-matrix": lambda g: triangle_count_matrix(g, block="auto"),
    "tc-SM": lambda g: triangle_count_subgraph(g),
    "cpu-baseline": triangle_count_scipy,
}


# single-core budget policy: the filtered method and SM run everywhere;
# the quadratic full-list ablation runs under 150k edges; the matrix method
# runs on the datasets whose tile schedules fit the budget (measured) —
# skips are explicit rows.
_FULL_LIMIT = 150_000  # undirected edges
_MATRIX_SETS = {"coauthors-like", "road-like"}


def fig5() -> None:
    for name in DATASETS_FIG5:
        g = load_dataset(name)
        truth = triangle_count_scipy(g)
        base_us = _time(lambda: triangle_count_scipy(g))
        _emit(f"fig5_{name}_cpu-baseline", base_us, "1.00x")
        for meth in ("tc-intersection-filtered", "tc-intersection-full",
                     "tc-matrix", "tc-SM"):
            if (meth == "tc-intersection-full"
                    and g.m_undirected > _FULL_LIMIT):
                _emit(f"fig5_{name}_{meth}", 0.0, "skipped(budget)")
                continue
            if meth == "tc-matrix" and name not in _MATRIX_SETS:
                _emit(f"fig5_{name}_{meth}", 0.0, "skipped(budget)")
                continue
            fn = _METHODS[meth]
            assert fn(g) == truth, (name, meth)
            us = _time(lambda: fn(g))
            _emit(f"fig5_{name}_{meth}", us, f"{base_us / us:.2f}x")


def fig6() -> None:
    ssds, t_int, t_mat = [], [], []
    for scale in FIG6_SCALES:
        g = rmat_graph(scale, FIG6_EDGE_FACTOR, seed=scale)
        ssd = g.sum_square_degrees
        us_i = _time(lambda: triangle_count_intersection(g))
        us_m = _time(lambda: triangle_count_matrix(g, block=128))
        ssds.append(ssd)
        t_int.append(us_i)
        t_mat.append(us_m)
        _emit(f"fig6_rmat{scale}_ssd{ssd}_intersection", us_i,
              f"ssd={ssd}")
        _emit(f"fig6_rmat{scale}_ssd{ssd}_matrix", us_m, f"ssd={ssd}")
    # log-log slope fits (paper: slope ≈ 1 for both)
    lx = np.log(np.asarray(ssds, dtype=np.float64))
    for label, ts in (("intersection", t_int), ("matrix", t_mat)):
        ly = np.log(np.asarray(ts, dtype=np.float64))
        slope, intercept = np.polyfit(lx, ly, 1)
        _emit(f"fig6_slope_{label}", float(np.mean(ts)),
              f"slope={slope:.3f}")
    # leading-constant ratio at the largest size (paper: ~20x)
    _emit("fig6_constant_ratio_matrix_over_intersection",
          t_mat[-1], f"{t_mat[-1] / t_int[-1]:.1f}x")


def main() -> None:
    print("name,us_per_call,derived")
    table1()
    fig5()
    fig6()


if __name__ == "__main__":
    main()
