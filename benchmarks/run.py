"""Benchmark harness — one function per paper table/figure.

Prints ``name,prep_us,count_us,derived`` CSV rows:

  prep_us    — one-time cost per cell: host plan construction (orientation,
               bucketing, tile scheduling), device upload, and the first
               count (which traces + compiles); what the engine amortizes
  count_us   — device replay of a cached ``TrianglePlan`` (best of N); the
               kernel time the paper's figures compare
  table1_*   — dataset statistics (derived = exact triangle count)
  fig5_*     — per-method wall clock per dataset, normalized to the
               sequential CPU baseline (derived = count-time speedup ×; the
               paper's Fig. 5 bar chart). Includes a beyond-paper ``tc-auto``
               row per dataset: the facade's ``algorithm="auto"`` cost model,
               derived = ``<speedup>x;auto=<lane chosen>``
  fig6_*     — runtime vs Σd² scaling for intersection- and matrix-based TC
               (derived = fitted log-log slope of count time; the paper's
               Fig. 6 shows slope ≈ 1) plus the leading-constant ratio
               matrix/intersection (paper: ~20×)
  strat_*    — beyond-paper: per-degree-bucket set-intersection strategy ×
               width sweep (broadcast / probe / bitmap; see
               repro.kernels.intersect.ops). Every cell asserts exact
               agreement with the per-bucket oracle, and each bucket's rows
               record which strategy ``strategy="auto"`` would pick
               (derived = ``edges=E;auto=<choice>``). Cells outside the
               single-core budget emit explicit skipped rows.
  fig_batch_* — beyond-paper: ``count_many`` batch-size sweep — the Python
               loop of per-graph cached plans vs ONE vmapped ``GraphBatch``
               dispatch over the same graphs (derived records the
               loop/vmapped speedup). Tracks the batching win across PRs.
  fig_truss_* — beyond-paper: k-truss peel sweep — the host path (listing's
               numpy enumeration per round) vs the device edge lane
               (cached per-edge support executables + the device peel
               loop), one ``_host``/``_device`` row pair per graph plus a
               clique-heavy fixture. Every pair asserts bit-identical
               surviving edge sets; the device row's derived field records
               the host/device speedup and the peel round count.
  fig_auto_*  — beyond-paper: the measured ``algorithm="auto"`` chooser —
               calibrates a per-device ``CalibrationTable`` from timed
               micro-runs over the datasets (written as a
               ``CALIB_<device>.json`` sidecar into ``--json-dir``), then
               re-resolves every dataset through the facade with
               ``chooser="measured"``: one row per (dataset, lane) records
               that lane's measured count time, and the ``_auto`` row
               records the table's pick, the true fastest lane, and the
               pick/best time ratio (derived =
               ``auto=<lane>;best=<lane>;ratio=<x>``; 1.00 = perfect —
               ``tests/test_auto_dominance.py`` gates this at its
               tolerance). Every auto count asserts the scipy oracle.
  fig_stream_* — beyond-paper: dynamic-session streaming — identical random
               insert/delete batches applied two ways: the incremental lane
               (``DynamicTriangleCounter``: cached step + delta executables,
               zero recompiles asserted across the timed stream) vs a
               from-scratch intersection plan + count per batch. Per-batch
               counts must agree and the final count is anchored against
               the scipy oracle; derived records update throughput and the
               recount/incremental speedup (gated ≥3× in smoke).

  fig_dist_*  — beyond-paper: the sharded plan/execute engine — run in a
               SUBPROCESS with ``--xla_force_host_platform_device_count=8``
               so the deal is real (the parent keeps its single device). Per
               graph: the warm single-device intersection plan, the
               pre-engine one-shot ``shard_map`` lane reconstructed honestly
               (full prep + a fresh jitted closure on every call, nothing
               cached), and the warm planned ``intersection_distributed`` /
               ``matrix_distributed`` lanes. Every row asserts the scipy
               oracle; the planned rows assert ZERO executable-cache misses
               across the timed replays and record the measured speedup
               over the one-shot baseline (smoke gate: planned beats
               one-shot) plus the per-shard dealt work.

  fig_tile_*  — beyond-paper: tiled out-of-core streaming — the same graph
               counted by the monolithic intersection plan and by a plan
               whose ``max_device_bytes`` budget is forced to a quarter of
               the largest bucket, so the big buckets stream through
               chunk-shaped cached executables in ≥2 chunks (``_mono`` /
               ``_tiled`` row pair). The tiled count must equal the
               monolithic count AND the scipy oracle bit-exactly, the timed
               replays assert ZERO executable-cache misses (steady-state
               streaming never recompiles), and the tiled row's derived
               field records ``chunks=K;recompiles=0;overhead=<x>`` — the
               streaming overhead relative to monolithic, gated ≤2× in
               smoke.

  fig_serve_* — beyond-paper: the ``repro.serve`` front end under load — a
               multi-tenant pool of same-policy R-MAT graphs played through
               ``TriangleService`` as (a) the sequential per-request facade
               baseline, (b) a coalescible burst (derived records
               throughput, coalesce factor, and the speedup over
               sequential — the smoke gate is ≥2×), and (c) an offered-QPS
               sweep: a paced below-knee step (shed rate asserted exactly
               0), a deadline burst above the knee (sheds asserted > 0 and
               p99 asserted bounded — requests shed, never queued
               unboundedly), and a queue-full burst against a small-depth
               service. Every completed count asserts the scipy oracle and
               the whole serving phase asserts ZERO executable-cache
               misses (both services are pool-warmed first).

Alongside the CSV, every executed figure is written as machine-readable
``BENCH_<figure>.json`` (rows + env + device + the exact argv) into
``--json-dir`` (default: the working directory), so the perf trajectory can
be compared across PRs without re-parsing stdout.

CPU-only proxy: all methods run their jnp backends on the host; relative
orderings (intersection-filtered fastest, matrix slowest with a large
constant, SM wins from pruning on mesh-like graphs) are the reproducible
claims — see README.md §Experiments.

``--smoke`` swaps the dataset list for the tiny fixtures and drops the budget
gates (the CI smoke job runs the default table1+fig5 subset; any
``--figures`` selection, e.g. ``--figures strat --smoke`` or ``--figures
fig_batch --smoke``, honors it). Every fig5, strat, and fig_batch cell
asserts exact agreement with its oracle, so a correctness regression fails
the process. See docs/BENCHMARKS.md for the full contract.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import re
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import (
    DATASETS, edges_to_csr, load_dataset, normalize_edge_updates,
)
from repro.core import (
    CountOptions, DynamicTriangleCounter, GraphBatch, TriangleCounter,
    calibrate, executable_cache_info, save_table, set_default_table,
    triangle_count_scipy,
)
from repro.core.calibrate import calib_path
from repro.core.engine import get_executable, prepare_intersection_buckets
from repro.core.listing import _k_truss_host
from repro.kernels.intersect import (
    STRATEGIES, intersect_counts_probe, intersect_counts_ref, resolve_strategy,
)
from repro.graphs.generators import complete_graph, rmat_graph
from repro.configs.paper import DATASETS_FIG5, FIG6_SCALES, FIG6_EDGE_FACTOR

_ROWS = []


def _emit(name: str, prep_us: float, count_us: float, derived) -> None:
    row = f"{name},{prep_us:.1f},{count_us:.1f},{derived}"
    _ROWS.append(dict(name=name, prep_us=round(prep_us, 1),
                      count_us=round(count_us, 1), derived=str(derived)))
    print(row, flush=True)


def _write_json(figures, json_dir: str, smoke: bool) -> None:
    """One ``BENCH_<figure>.json`` per executed figure: its CSV rows plus
    enough environment to compare runs across PRs/machines."""
    env = dict(
        python=platform.python_version(),
        jax=jax.__version__,
        numpy=np.__version__,
        platform=platform.platform(),
    )
    device = str(jax.devices()[0])
    os.makedirs(json_dir, exist_ok=True)
    for fig in figures:
        rows = [r for r in _ROWS if r["name"].startswith(fig + "_")]
        path = os.path.join(json_dir, f"BENCH_{fig}.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump(
                dict(figure=fig, smoke=smoke, argv=sys.argv[1:],
                     env=env, device=device, rows=rows),
                f, indent=2,
            )
            f.write("\n")
        print(f"# wrote {path} ({len(rows)} rows)", flush=True)


def _time(fn, *, warmup: int = 1, iters: int = 2) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# method -> the facade's typed options (benchmarks go through the same
# front door users do; "tc-auto" exercises the cross-lane cost model)
_METHOD_OPTIONS = {
    "tc-intersection-filtered": CountOptions(algorithm="intersection"),
    "tc-intersection-full": CountOptions(algorithm="intersection",
                                         variant="full"),
    "tc-matrix": CountOptions(algorithm="matrix"),  # block="auto"
    "tc-SM": CountOptions(algorithm="subgraph"),
    "tc-auto": CountOptions(),  # algorithm="auto"
}


def _timed_plan(g, meth: str, **overrides):
    """Build the session AND run its first count for one fig5/fig6 cell, so
    prep_us covers the whole one-time cost: host prep, device upload, and
    the first trace+compile. Returns (result, prep_us); ``result.plan.count``
    is the replay to time."""
    opts = _METHOD_OPTIONS[meth]
    if overrides:
        opts = opts.replace(**overrides)
    t0 = time.perf_counter()
    result = TriangleCounter(g, opts).count()
    prep_us = (time.perf_counter() - t0) * 1e6
    return result, prep_us


def table1(datasets) -> None:
    for name in datasets:
        g = load_dataset(name)
        t0 = time.perf_counter()
        tri = triangle_count_scipy(g)
        us = (time.perf_counter() - t0) * 1e6
        _emit(f"table1_{name}_v{g.n}_e{g.m_undirected}_d{g.max_degree}"
              f"_{DATASETS[name]['type']}", 0.0, us, tri)


# single-core budget policy: the filtered method and SM run everywhere;
# the quadratic full-list ablation runs under 150k edges; the matrix method
# runs on the datasets whose tile schedules fit the budget (measured) —
# skips are explicit rows. The smoke subset lifts both limits (tiny graphs).
_FULL_LIMIT = 150_000  # undirected edges
_MATRIX_SETS = {"coauthors-like", "road-like"}


def fig5(datasets, *, budget: bool = True, iters: int = 2) -> None:
    for name in datasets:
        g = load_dataset(name)
        truth = triangle_count_scipy(g)
        base_us = _time(lambda: triangle_count_scipy(g), iters=iters)
        _emit(f"fig5_{name}_cpu-baseline", 0.0, base_us, "1.00x")
        for meth in ("tc-intersection-filtered", "tc-intersection-full",
                     "tc-matrix", "tc-SM", "tc-auto"):
            if (budget and meth == "tc-intersection-full"
                    and g.m_undirected > _FULL_LIMIT):
                _emit(f"fig5_{name}_{meth}", 0.0, 0.0, "skipped(budget)")
                continue
            if budget and meth == "tc-matrix" and name not in _MATRIX_SETS:
                _emit(f"fig5_{name}_{meth}", 0.0, 0.0, "skipped(budget)")
                continue
            result, prep_us = _timed_plan(g, meth)
            assert result == truth, (name, meth)
            count_us = _time(result.plan.count, iters=iters)
            derived = f"{base_us / count_us:.2f}x"
            if meth == "tc-auto":  # surface the cost model's lane choice
                derived += f";auto={result.algorithm}"
            _emit(f"fig5_{name}_{meth}", prep_us, count_us, derived)


def fig6(scales, *, iters: int = 2) -> None:
    ssds, t_int, t_mat = [], [], []
    for scale in scales:
        g = rmat_graph(scale, FIG6_EDGE_FACTOR, seed=scale)
        ssd = g.sum_square_degrees
        # fixed block=128 so every scale times the same tile size and the
        # slope fit stays comparable (choose_block could flip mid-sweep)
        res_i, prep_i = _timed_plan(g, "tc-intersection-filtered")
        res_m, prep_m = _timed_plan(g, "tc-matrix", block=128)
        us_i = _time(res_i.plan.count, iters=iters)
        us_m = _time(res_m.plan.count, iters=iters)
        ssds.append(ssd)
        t_int.append(us_i)
        t_mat.append(us_m)
        _emit(f"fig6_rmat{scale}_ssd{ssd}_intersection", prep_i, us_i,
              f"ssd={ssd}")
        _emit(f"fig6_rmat{scale}_ssd{ssd}_matrix", prep_m, us_m, f"ssd={ssd}")
    # log-log slope fits on count time (paper: slope ≈ 1 for both)
    lx = np.log(np.asarray(ssds, dtype=np.float64))
    for label, ts in (("intersection", t_int), ("matrix", t_mat)):
        ly = np.log(np.asarray(ts, dtype=np.float64))
        slope, intercept = np.polyfit(lx, ly, 1)
        _emit(f"fig6_slope_{label}", 0.0, float(np.mean(ts)),
              f"slope={slope:.3f}")
    # leading-constant ratio at the largest size (paper: ~20x)
    _emit("fig6_constant_ratio_matrix_over_intersection", 0.0,
          t_mat[-1], f"{t_mat[-1] / t_int[-1]:.1f}x")


# strat sweep budget policy (single-core): the O(E·W²) broadcast core only
# runs on buckets under the compare budget, and bitmap only when the packed
# bitmap stays small; skips are explicit rows, mirroring the fig5 policy
_STRAT_BROADCAST_BUDGET = 1 << 30  # E·W² compares per bucket
_STRAT_BITMAP_MAX_BITS = 4096


def _bucket_oracle(u: np.ndarray, v: np.ndarray) -> int:
    """Per-bucket reference total: the chunked broadcast-compare oracle when
    the bucket fits the compare budget, else the probe path (whose global sum
    the caller anchors against the scipy oracle)."""
    e, w = u.shape
    uj, vj = jnp.asarray(u), jnp.asarray(v)
    if e * w * w <= _STRAT_BROADCAST_BUDGET:
        total, chunk = 0, max(1, (1 << 24) // (w * w))
        for s in range(0, e, chunk):
            total += int(jnp.sum(intersect_counts_ref(uj[s:s + chunk],
                                                      vj[s:s + chunk])))
        return total
    return int(jnp.sum(intersect_counts_probe(uj, vj)))


def strat(datasets, *, iters: int = 2) -> None:
    """Per-bucket strategy × width sweep on the filtered intersection lane.

    One row per (dataset, bucket width, strategy) timing the engine's cached
    jnp executable for that (strategy, shape); every executed cell asserts
    exact agreement with the per-bucket oracle, and the per-dataset bucket
    totals are anchored against the scipy oracle.
    """
    for name in datasets:
        g = load_dataset(name)
        truth = triangle_count_scipy(g)
        buckets = prepare_intersection_buckets(g, variant="filtered")
        id_range = g.n + 2  # real ids + the n / n+1 in-row sentinels
        refs = [_bucket_oracle(b["u_lists"], b["v_lists"]) for b in buckets]
        assert sum(refs) == truth, (name, sum(refs), truth)
        for b, ref_total in zip(buckets, refs):
            w = b["width"]
            e = b["u_lists"].shape[0]
            auto_choice, _ = resolve_strategy(w, id_range)
            u, v = jnp.asarray(b["u_lists"]), jnp.asarray(b["v_lists"])
            derived = f"edges={e};auto={auto_choice}"
            for s in STRATEGIES:
                row = f"strat_{name}_w{w}_{s}"
                if s == "broadcast" and e * w * w > _STRAT_BROADCAST_BUDGET:
                    _emit(row, 0.0, 0.0, "skipped(budget)")
                    continue
                if s == "bitmap":
                    _, bits = resolve_strategy(w, id_range, strategy="bitmap")
                    if bits > _STRAT_BITMAP_MAX_BITS:
                        _emit(row, 0.0, 0.0, "skipped(id-range)")
                        continue
                else:
                    bits = None
                t0 = time.perf_counter()
                fn = get_executable("intersection", "jnp", True, u.shape,
                                    strategy=s, bitmap_bits=bits)
                first = int(fn(u, v))
                prep_us = (time.perf_counter() - t0) * 1e6
                assert first == ref_total, (name, w, s, first, ref_total)
                count_us = _time(lambda: int(fn(u, v)), iters=iters)
                _emit(row, prep_us, count_us, derived)


def fig_batch(sizes, *, iters: int = 2, scale: int = 7,
              edge_factor: int = 6) -> None:
    """``count_many`` batching sweep: per-graph loop vs one vmapped dispatch.

    For each batch size B, generates B same-policy R-MAT graphs, then times
    (a) a Python loop replaying B cached per-graph plans and (b) one
    ``GraphBatch.counts()`` device dispatch over the stacked buckets. Both
    lanes assert exact agreement with the scipy oracle; derived records the
    loop/vmapped speedup.
    """
    opts = CountOptions(algorithm="intersection")
    for B in sizes:
        graphs = [rmat_graph(scale, edge_factor, seed=200 + i,
                             name=f"rmat{scale}b{i}") for i in range(B)]
        truth = [triangle_count_scipy(g) for g in graphs]

        t0 = time.perf_counter()
        sessions = [TriangleCounter(g, opts) for g in graphs]
        loop_counts = [int(s.count()) for s in sessions]
        loop_prep_us = (time.perf_counter() - t0) * 1e6
        assert loop_counts == truth, ("fig_batch loop", B)
        loop_us = _time(lambda: [s.plan.count() for s in sessions],
                        iters=iters)
        _emit(f"fig_batch_rmat{scale}_B{B}_loop", loop_prep_us, loop_us,
              f"graphs={B}")

        t0 = time.perf_counter()
        batch = GraphBatch.from_graphs(graphs, opts)
        batch_counts = [int(c) for c in batch.counts()]
        batch_prep_us = (time.perf_counter() - t0) * 1e6
        assert batch_counts == truth, ("fig_batch vmapped", B)
        batch_us = _time(batch.counts, iters=iters)
        _emit(f"fig_batch_rmat{scale}_B{B}_vmapped", batch_prep_us, batch_us,
              f"graphs={B};speedup={loop_us / max(batch_us, 1e-9):.2f}x")


# fig_truss budget policy (single-core): the host path re-enumerates every
# triangle per peel round, so under budget it only runs on graphs below this
# edge count; skips are explicit rows (the device row still runs)
_TRUSS_HOST_LIMIT = 150_000  # undirected edges
_TRUSS_K = 4


def _clique_heavy_graph(n_clique: int = 96, n_spurs: int = 64):
    """The fig_truss fixture: one K_{n_clique} plus pendant spur edges off
    vertex 0 — the regime the device peel wins hardest (wide dense
    neighbor lists make the host path's per-round O(E·W²) eq tensors
    expensive) while still peeling >1 round (the spurs go first)."""
    base = complete_graph(n_clique)
    src, dst = base.edge_list_unique()
    spur_src = np.zeros(n_spurs, dtype=np.int64)
    spur_dst = np.arange(n_clique, n_clique + n_spurs, dtype=np.int64)
    return edges_to_csr(np.concatenate([src.astype(np.int64), spur_src]),
                        np.concatenate([dst.astype(np.int64), spur_dst]),
                        n=n_clique + n_spurs, name="clique-heavy")


def fig_truss(datasets, *, budget: bool = True, iters: int = 2,
              k: int = _TRUSS_K) -> None:
    """k-truss peel: host enumeration (listing oracle) vs the device edge
    lane.

    One row pair per graph (the given datasets plus the clique-heavy
    fixture): ``_host`` times ``listing._k_truss_host`` (full numpy peel,
    re-enumerating triangles each round) and ``_device`` times
    ``TriangleCounter.k_truss`` (cached edge executables + the device peel
    loop). Every pair asserts the surviving edge sets are bit-identical;
    the device row's derived field records the host/device speedup and the
    peel round count.
    """
    graphs = [load_dataset(name) for name in datasets]
    graphs.append(_clique_heavy_graph())
    for g in graphs:
        if budget and g.m_undirected > _TRUSS_HOST_LIMIT:
            _emit(f"fig_truss_{g.name}_k{k}_host", 0.0, 0.0,
                  "skipped(budget)")
            host_us = None
        else:
            truth = _k_truss_host(g, k)
            host_us = _time(lambda: _k_truss_host(g, k), iters=iters)
            _emit(f"fig_truss_{g.name}_k{k}_host", 0.0, host_us,
                  f"edges={truth.m_undirected}")
        t0 = time.perf_counter()
        tc = TriangleCounter(g, CountOptions(algorithm="edge"))
        dev = tc.k_truss(k)  # builds the plan + compiles the peel stages
        prep_us = (time.perf_counter() - t0) * 1e6
        if host_us is not None:
            assert dev.n == truth.n, g.name
            assert np.array_equal(dev.row_ptr, truth.row_ptr), g.name
            assert np.array_equal(dev.col_idx, truth.col_idx), g.name
        dev_us = _time(lambda: tc.k_truss(k), iters=iters)
        rounds = tc.plan.meta.get("peel_rounds")
        derived = f"edges={dev.m_undirected};rounds={rounds}"
        if host_us is not None:
            derived += f";speedup={host_us / max(dev_us, 1e-9):.2f}x"
        _emit(f"fig_truss_{g.name}_k{k}_device", prep_us, dev_us, derived)


def fig_auto(datasets, *, iters: int = 2, json_dir: str = ".") -> None:
    """Measured auto chooser: calibrate, persist the sidecar, audit picks.

    Builds a per-device ``CalibrationTable`` by timing every chooser lane
    on every dataset (warm best-of micro-runs, same policy as ``_time``),
    writes it as ``CALIB_<device>.json`` into ``json_dir``, then installs
    it and re-resolves each dataset through the facade with
    ``chooser="measured"``. Per dataset: one row per lane with its
    measured count time, plus the ``_auto`` row whose derived field
    records the table's pick, the true fastest lane, and the pick/best
    measured-time ratio. Every auto count asserts the scipy oracle; the
    previously installed table is always restored.
    """
    graphs = [load_dataset(name) for name in datasets]
    t0 = time.perf_counter()
    table = calibrate(graphs, iters=iters, warmup=1)
    calib_us = (time.perf_counter() - t0) * 1e6
    os.makedirs(json_dir, exist_ok=True)
    path = save_table(table, calib_path(json_dir))
    print(f"# wrote {path} ({len(table.entries)} bins, "
          f"calibrated in {calib_us / 1e6:.2f}s)", flush=True)
    prev = set_default_table(table)
    try:
        for name, g in zip(datasets, graphs):
            truth = triangle_count_scipy(g)
            timings = table.lookup(g) or {}
            for lane in sorted(timings):
                _emit(f"fig_auto_{name}_{lane}", 0.0, timings[lane] * 1e6,
                      "measured")
            t0 = time.perf_counter()
            result = TriangleCounter(g, CountOptions(chooser="measured")
                                     ).count()
            prep_us = (time.perf_counter() - t0) * 1e6
            assert result == truth, (name, result.algorithm)
            count_us = _time(result.plan.count, iters=iters)
            best = min(sorted(timings), key=lambda l: timings[l])
            ratio = (timings[result.algorithm]
                     / max(timings[best], 1e-12))
            _emit(f"fig_auto_{name}_auto", prep_us, count_us,
                  f"auto={result.algorithm};best={best};ratio={ratio:.2f}")
    finally:
        set_default_table(prev)


def fig_stream(*, num_batches: int = 12, batch_edges: int = 64,
               scale: int = 12, edge_factor: int = 6, seed: int = 17,
               min_speedup: float = 0.0) -> None:
    """Dynamic-session streaming: incremental deltas vs per-batch recounts.

    One R-MAT graph takes ``num_batches`` random insert/delete batches two
    ways over identical update streams: the ``_incremental`` row times a
    ``DynamicTriangleCounter`` applying every batch through its cached
    step + delta executables (asserting ZERO executable-cache misses across
    the timed stream — the shape-class contract), and the ``_full-recount``
    row times the static alternative, a from-scratch
    ``TriangleCounter(..., algorithm="intersection")`` plan + count per
    batch (the host edge set is maintained outside the timing). Both lanes
    must produce identical per-batch counts, and the final count is
    anchored against the scipy oracle. The incremental row's derived field
    records batches/updates-per-second/recompiles; the full-recount row
    records the recount/incremental speedup, gated at ``min_speedup`` when
    non-zero (the smoke CI gate).
    """
    g = rmat_graph(scale, edge_factor, seed=seed)
    n = g.n
    rng = np.random.default_rng(seed)
    # steady-state stream: per batch, half deletes sampled from the LIVE
    # edge set and half random-pair inserts, so the edge count stays inside
    # its capacity class (the zero-recompile contract under test — growing
    # past the class is covered by tests/test_dynamic.py, not timed here).
    # The same host walk records the post-batch snapshots the full-recount
    # lane counts, all before any timing starts.
    edges = set(zip(*(a.tolist() for a in g.edge_list_unique())))
    batches, snapshots = [], []
    for i in range(num_batches + 1):  # +1 warmup batch
        k = batch_edges // 2
        live = sorted(edges)
        dels = [live[j] for j in
                rng.choice(len(live), size=min(k, len(live)), replace=False)]
        u = rng.integers(0, n, size=batch_edges - len(dels))
        v = rng.integers(0, n, size=batch_edges - len(dels))
        ups = [(a, b, False) for a, b in dels]
        ups += [(int(a), int(b), True) for a, b in zip(u, v)]
        batch = normalize_edge_updates(ups, n)
        batches.append(batch)
        for a, b, f in zip(*(x.tolist() for x in batch)):
            (edges.add if f else edges.discard)((a, b))
        if i > 0:  # snapshots for the full-recount lane (warmup excluded)
            src = np.array([e[0] for e in sorted(edges)], dtype=np.int64)
            dst = np.array([e[1] for e in sorted(edges)], dtype=np.int64)
            snapshots.append(edges_to_csr(src, dst, n=n, name=f"stream{i}"))
    warm_batch, stream = batches[0], batches[1:]

    # incremental lane: prep covers session construction + the warmup batch
    # (which compiles the step/delta executables for this shape class)
    t0 = time.perf_counter()
    dc = DynamicTriangleCounter(
        g, CountOptions(algorithm="dynamic", update_batch_size=batch_edges,
                        recount_interval=0))
    dc.count()
    dc.plan.apply_updates(*warm_batch)
    inc_prep_us = (time.perf_counter() - t0) * 1e6
    cache_before = dc.cache_stats()
    inc_counts = []
    t0 = time.perf_counter()
    for lo, hi, ins in stream:
        dc.plan.apply_updates(lo, hi, ins)
        inc_counts.append(int(dc.count()))
    inc_us = (time.perf_counter() - t0) * 1e6
    recompiles = dc.cache_stats()["misses"] - cache_before["misses"]
    assert recompiles == 0, f"fig_stream recompiled {recompiles}x mid-stream"
    assert int(dc.count()) == triangle_count_scipy(dc.snapshot())
    dc.recount()
    upd_per_s = num_batches * batch_edges / (inc_us / 1e6)
    _emit(f"fig_stream_rmat{scale}_incremental", inc_prep_us,
          inc_us / num_batches,
          f"batches={num_batches};upd_per_s={upd_per_s:.0f};"
          f"recompiles={recompiles}")

    # full-recount lane: the same stream counted from scratch per batch
    # (the host snapshots were materialized before any timing)
    opts = CountOptions(algorithm="intersection")
    t0 = time.perf_counter()
    # compile warmup over EVERY snapshot: per-snapshot bucket layouts can
    # land in different shape classes, and leaving any compile inside the
    # timed loop would inflate the speedup (and make it depend on what the
    # process compiled earlier)
    for s in snapshots:
        int(TriangleCounter(s, opts).count())
    full_prep_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    full_counts = [int(TriangleCounter(s, opts).count()) for s in snapshots]
    full_us = (time.perf_counter() - t0) * 1e6
    assert full_counts == inc_counts, "fig_stream lanes disagree"
    speedup = full_us / max(inc_us, 1e-9)
    if min_speedup:
        assert speedup >= min_speedup, \
            f"fig_stream speedup {speedup:.2f}x below gate {min_speedup}x"
    _emit(f"fig_stream_rmat{scale}_full-recount", full_prep_us,
          full_us / num_batches,
          f"batches={num_batches};speedup={speedup:.2f}x")


def fig_serve(*, pool_size: int = 8, scale: int = 7, edge_factor: int = 6,
              requests: int = 32, sweep_requests: int = 24,
              burst_requests: int = 48, min_speedup: float = 0.0) -> None:
    """``repro.serve`` under load: coalescing throughput + the shed knee.

    One pool of same-policy R-MAT graphs plays a multi-tenant request mix
    through ``TriangleService`` in four phases, every completed count
    asserted bit-identical to the scipy oracle and ZERO executable-cache
    misses asserted across all serving phases (both services are warmed
    over the pool first, so steady state compiles nothing):

      _sequential     — the per-request facade loop (fresh ``TriangleCounter``
                        per request): the baseline the service must beat.
      _service-batch  — the same requests burst through the service; derived
                        records throughput, the coalesce factor, and the
                        speedup over sequential (gated at ``min_speedup``
                        when non-zero — the smoke CI gate is 2x).
      _qps<r>         — the offered-QPS sweep: a below-knee paced step
                        (asserts shed rate exactly 0), an above-knee
                        deadline burst (asserts sheds > 0 AND p99 stays
                        bounded by deadline + window + execution — shed,
                        not queued unboundedly), and a queue-full burst
                        against a small-depth service (asserts depth-based
                        sheds). Each row records p50/p99 latency,
                        throughput, coalesce factor, and shed rate.
    """
    from repro.serve import RequestShed, ServeConfig, TriangleService
    from repro.core import executable_cache_info

    opts = CountOptions(algorithm="intersection")
    pool = [rmat_graph(scale, edge_factor, seed=300 + i,
                       name=f"serve{scale}p{i}") for i in range(pool_size)]
    oracle = [int(triangle_count_scipy(g)) for g in pool]
    base = f"fig_serve_rmat{scale}"

    def pick(i):  # the synthetic multi-tenant mix: tenants cycle the pool
        return i % pool_size, f"tenant{i % 4}"

    def run_burst(svc, n, *, deadline_ms=None, pace_s=None):
        """Submit n pool requests (burst, or paced at ``pace_s``); returns
        (results keyed by graph index, shed reasons, wall seconds)."""
        futs = []
        t0 = time.perf_counter()
        for i in range(n):
            gi, tenant = pick(i)
            futs.append((gi, svc.submit("count", pool[gi], tenant=tenant,
                                        deadline_ms=deadline_ms)))
            if pace_s:
                time.sleep(pace_s)
        done, shed = [], []
        for gi, f in futs:
            try:
                done.append((gi, f.result(timeout=120)))
            except RequestShed as e:
                shed.append(e.reason)
        wall = time.perf_counter() - t0
        for gi, r in done:
            assert r.count == oracle[gi], (pool[gi].name, r.count, oracle[gi])
        return done, shed, wall

    def stats(done, shed, wall):
        n = len(done) + len(shed)
        lat = sorted(r.total_s for _, r in done)
        p50 = 1e3 * lat[len(lat) // 2] if lat else 0.0
        p99 = 1e3 * lat[min(len(lat) - 1, int(0.99 * len(lat)))] if lat \
            else 0.0
        dispatches = sum(1.0 / r.batch_size for _, r in done)
        coalesce = len(done) / dispatches if dispatches else 1.0
        thr = len(done) / wall if wall else 0.0
        return dict(p50=p50, p99=p99, coalesce=coalesce, thr=thr,
                    shed_rate=len(shed) / n if n else 0.0)

    # sequential facade baseline: fresh session per request (re-prep every
    # time — exactly what a per-request front end without the serve layer
    # would do). Warm one session per graph first so the timed loop measures
    # steady-state per-request cost, not compilation.
    t0 = time.perf_counter()
    for gi, g in enumerate(pool):
        assert int(TriangleCounter(g, opts).count()) == oracle[gi], g.name
    seq_prep_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for i in range(requests):
        gi, _ = pick(i)
        c = int(TriangleCounter(pool[gi], opts).count())
        assert c == oracle[gi], pool[gi].name
    seq_wall = time.perf_counter() - t0
    seq_thr = requests / seq_wall
    _emit(f"{base}_sequential", seq_prep_us, 1e6 * seq_wall / requests,
          f"requests={requests};throughput={seq_thr:.0f}")

    # both services warm over the whole pool BEFORE the zero-recompile
    # watch starts: prep caches filled, monotone layouts fixed, every pow-2
    # batch executable + single pass-through compiled
    svc = TriangleService(opts, config=ServeConfig(
        max_queue_depth=max(256, requests + burst_requests),
        batch_window_ms=5.0, max_batch=8,
        plan_cache_size=max(128, 2 * pool_size)))
    svc.warmup(pool)
    small_depth = 12
    svc_small = TriangleService(opts, config=ServeConfig(
        max_queue_depth=small_depth, batch_window_ms=2.0, max_batch=8,
        plan_cache_size=max(128, 2 * pool_size)))
    svc_small.warmup(pool)
    misses0 = executable_cache_info()["misses"]

    with svc:
        # coalescible burst: the throughput head-to-head vs sequential
        done, shed, wall = run_burst(svc, requests)
        assert not shed, f"ample-depth burst shed {len(shed)} requests"
        st = stats(done, shed, wall)
        speedup = st["thr"] / seq_thr
        if min_speedup:
            assert speedup >= min_speedup, \
                f"service throughput {speedup:.2f}x sequential is below " \
                f"the {min_speedup}x gate"
        _emit(f"{base}_service-batch", 0.0, 1e6 * wall / requests,
              f"requests={requests};throughput={st['thr']:.0f};"
              f"coalesce={st['coalesce']:.2f};speedup={speedup:.2f}x")

        # below the knee: paced at ~40% of measured service capacity —
        # nothing sheds, latency is queue-window dominated
        offered = 0.4 * st["thr"]
        done, shed, wall = run_burst(svc, sweep_requests,
                                     pace_s=1.0 / offered)
        assert not shed, f"below-knee step shed {len(shed)} requests"
        st_lo = stats(done, shed, wall)
        _emit(f"{base}_qps{offered:.0f}", 0.0, 1e6 * wall / sweep_requests,
              f"offered_qps={offered:.0f};p50_ms={st_lo['p50']:.1f};"
              f"p99_ms={st_lo['p99']:.1f};throughput={st_lo['thr']:.0f};"
              f"coalesce={st_lo['coalesce']:.2f};shed_rate=0.000")

        # above the knee: a burst whose deadline budget covers only part of
        # the backlog — late requests shed with reason "deadline", and p99
        # of what completes stays bounded by deadline + window + execution
        # (requests are rejected, never queued unboundedly)
        drain_s = burst_requests / st["thr"]
        deadline_ms = max(15.0, 1e3 * 0.35 * drain_s)
        # the knee is measured, not known: a fully-warm process can drain
        # the whole burst inside the first deadline guess, so halve the
        # budget until it really covers only part of the backlog (halving
        # from a deadline the service just beat keeps the head servable)
        for _ in range(16):
            done, shed, wall = run_burst(svc, burst_requests,
                                         deadline_ms=deadline_ms)
            if shed:
                break
            deadline_ms /= 2.0
        assert shed, "above-knee burst must shed"
        assert done, "above-knee burst must still serve the head"
        assert all(r == "deadline" for r in shed), sorted(set(shed))
        st_hi = stats(done, shed, wall)
        max_exec_ms = 1e3 * max(r.exec_s for _, r in done)
        bound_ms = deadline_ms + 5.0 + 2.0 * max_exec_ms + 100.0
        assert st_hi["p99"] <= bound_ms, \
            f"p99 {st_hi['p99']:.1f}ms exceeds shed bound {bound_ms:.1f}ms"
        offered_hi = burst_requests / wall
        _emit(f"{base}_qps{offered_hi:.0f}", 0.0,
              1e6 * wall / burst_requests,
              f"offered_qps={offered_hi:.0f};p50_ms={st_hi['p50']:.1f};"
              f"p99_ms={st_hi['p99']:.1f};throughput={st_hi['thr']:.0f};"
              f"coalesce={st_hi['coalesce']:.2f};"
              f"shed_rate={st_hi['shed_rate']:.3f};"
              f"deadline_ms={deadline_ms:.0f}")

    # depth-based shedding: the same burst against a small admission queue —
    # request max_queue_depth+1 is rejected at the door, not buffered
    with svc_small:
        done, shed, wall = run_burst(svc_small, burst_requests)
        assert shed, "small-depth burst must shed on queue-full"
        assert done, "small-depth burst must still serve the backlog"
        assert all(r == "queue-full" for r in shed), sorted(set(shed))
        st_q = stats(done, shed, wall)
        _emit(f"{base}_qps-burst-depth{small_depth}", 0.0,
              1e6 * wall / burst_requests,
              f"offered_qps=burst;p50_ms={st_q['p50']:.1f};"
              f"p99_ms={st_q['p99']:.1f};throughput={st_q['thr']:.0f};"
              f"coalesce={st_q['coalesce']:.2f};"
              f"shed_rate={st_q['shed_rate']:.3f};depth={small_depth}")

    recompiles = executable_cache_info()["misses"] - misses0
    assert recompiles == 0, \
        f"fig_serve recompiled {recompiles}x in steady state"
    snap = svc.snapshot()
    _emit(f"{base}_steady-state", 0.0, 0.0,
          f"recompiles={recompiles};plan_cache_hits={snap['plan_cache']['hits']};"
          f"plan_cache_misses={snap['plan_cache']['misses']};"
          f"coalesce={snap['coalesce_factor']:.2f};"
          f"shed={snap['counters'].get('shed', 0)}")


# Runs under forced host devices in a subprocess (jax locks the device count
# at first init, so the parent cannot shard itself). argv: ndev scale
# edge_factor iters. Prints one ``ROWS:<json>`` line.
_DIST_SCRIPT = r"""
import os, sys
ndev, scale, ef, iters = (int(a) for a in sys.argv[1:5])
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%d" % ndev)
import json, time
import jax
from repro.graphs.generators import rmat_graph
from repro.launch.mesh import make_mesh
from repro.core import triangle_count_scipy
from repro.core import engine
from repro.core.engine import plan_triangle_count, executable_cache_info
from repro.graphs.device import ShardedDeviceCSR

assert jax.device_count() == ndev, jax.device_count()
g = rmat_graph(scale, ef, seed=11, name="dist%d" % scale)
want = int(triangle_count_scipy(g))
mesh = make_mesh((ndev,), ("data",))
rows = []


def best(fn):
    b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        b = min(b, time.perf_counter() - t0)
    return b * 1e6


# single-device reference: the warm planned intersection lane
t0 = time.perf_counter()
p1 = plan_triangle_count(g, "intersection")
assert p1.count() == want
prep1 = (time.perf_counter() - t0) * 1e6
rows.append(dict(name="rmat%d_single" % scale, prep_us=prep1,
                 count_us=best(p1.count), derived="devices=1;oracle=ok"))


# the pre-engine one-shot shard_map lane, reconstructed honestly: full
# prep, the deal, and a FRESH jitted closure on EVERY call — nothing
# shared with the executable cache (what core/distributed.py did before
# the planned lanes)
def one_shot():
    sh = ShardedDeviceCSR.from_graph(g, mesh)
    total = 0
    for b in sh.buckets:
        strat, bits = engine._resolve_bucket_strategy(
            b.width, g.n + 2, "auto", None)
        fn = engine._build_dist_intersect_executable(
            strat, bits, b.shape + (b.chunk,), mesh)
        total += int(fn(b.u_lists, b.v_lists, b.valid))
    return total


us_os = float("inf")
for _ in range(max(2, iters)):  # every call pays prep + trace + compile
    t0 = time.perf_counter()
    assert one_shot() == want
    us_os = min(us_os, (time.perf_counter() - t0) * 1e6)
rows.append(dict(name="rmat%d_oneshot%d" % (scale, ndev), prep_us=0.0,
                 count_us=us_os,
                 derived="devices=%d;oracle=ok;cached=no" % ndev))

# the planned distributed lanes: prep once, cached per-shard executables,
# zero recompiles across the timed replays
for lane, tag in (("intersection_distributed", "planned"),
                  ("matrix_distributed", "matrix")):
    t0 = time.perf_counter()
    p = plan_triangle_count(g, lane, mesh=mesh)
    assert p.count() == want
    prep_us = (time.perf_counter() - t0) * 1e6
    m0 = executable_cache_info()["misses"]
    us = best(p.count)
    rec = executable_cache_info()["misses"] - m0
    assert rec == 0, (lane, rec)
    work = p.meta["shard_work"]
    balance = max(work) / max(min(work), 1)
    rows.append(dict(
        name="rmat%d_%s%d" % (scale, tag, ndev), prep_us=prep_us,
        count_us=us,
        derived="devices=%d;oracle=ok;recompiles=%d;speedup=%.2fx;"
                "balance=%.2f" % (ndev, rec, us_os / us, balance)))

print("ROWS:" + json.dumps(rows), flush=True)
"""


def fig_dist(*, ndev: int = 8, scale: int = 8, edge_factor: int = 8,
             iters: int = 3, min_speedup: float = 0.0) -> None:
    """Single device vs ``ndev`` forced host devices (see ``_DIST_SCRIPT``).

    The subprocess asserts every row against the scipy oracle and asserts
    zero recompiles across the planned lanes' timed replays; the parent
    re-emits its rows and gates the planned-vs-one-shot speedup at
    ``min_speedup`` when non-zero.
    """
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the script forces its own device count
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT, str(ndev), str(scale),
         str(edge_factor), str(iters)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    lines = [l for l in proc.stdout.splitlines() if l.startswith("ROWS:")]
    assert lines, proc.stdout
    speedup = None
    for r in json.loads(lines[0][len("ROWS:"):]):
        assert "oracle=ok" in r["derived"], r
        _emit("fig_dist_" + r["name"], r["prep_us"], r["count_us"],
              r["derived"])
        m = re.search(r"speedup=([0-9.]+)x", r["derived"])
        if m and f"_planned{ndev}" in r["name"]:
            speedup = float(m.group(1))
    assert speedup is not None, "planned row missing from subprocess output"
    if min_speedup:
        assert speedup >= min_speedup, \
            f"fig_dist planned lane {speedup:.2f}x one-shot is below the " \
            f"{min_speedup}x gate"


def fig_tile(*, scale: int = 9, edge_factor: int = 8, iters: int = 3,
             max_overhead: float = 0.0) -> None:
    """Monolithic vs tiled out-of-core intersection on one R-MAT graph.

    The tiled plan's ``max_device_bytes`` is forced to a quarter of the
    largest monolithic bucket's resident bytes, so the big buckets stream
    in ≥2 (typically ≥4) chunks through chunk-shaped cached executables.
    Asserts bit-identical counts (tiled == monolithic == scipy), ≥2 chunks
    actually streamed, and ZERO executable-cache misses across the timed
    replays; gates tiled/monolithic overhead at ``max_overhead`` when
    non-zero.
    """
    g = rmat_graph(scale, edge_factor, seed=5)
    oracle = int(triangle_count_scipy(g))

    t0 = time.perf_counter()
    mono = TriangleCounter(g, CountOptions(algorithm="intersection"))
    res_m = mono.count()
    mono_prep_us = (time.perf_counter() - t0) * 1e6
    assert int(res_m) == oracle, (int(res_m), oracle)

    # budget = largest bucket / 4: every bucket above it streams, and the
    # top bucket streams in ≥4 chunks (pow2 chunk rows round down)
    bucket_bytes = [int(e) * (8 * int(w) + 8)
                    for e, w in res_m.meta["bucket_shapes"]]
    budget = max(bucket_bytes) // 4
    t0 = time.perf_counter()
    tiled = TriangleCounter(g, CountOptions(algorithm="intersection",
                                            max_device_bytes=budget))
    res_t = tiled.count()
    tiled_prep_us = (time.perf_counter() - t0) * 1e6
    assert int(res_t) == oracle, (int(res_t), oracle)
    chunks = int(res_t.meta["num_chunks"])
    assert chunks >= 2, res_t.meta

    before = executable_cache_info()["misses"]
    mono_us = _time(mono.plan.count, iters=iters)
    tile_us = _time(tiled.plan.count, iters=iters)
    recompiles = executable_cache_info()["misses"] - before
    assert recompiles == 0, \
        f"fig_tile: {recompiles} recompiles during steady-state replays"
    overhead = tile_us / mono_us
    _emit("fig_tile_mono", mono_prep_us, mono_us,
          f"oracle=ok;budget={budget}")
    _emit("fig_tile_tiled", tiled_prep_us, tile_us,
          f"oracle=ok;chunks={chunks};recompiles={recompiles};"
          f"overhead={overhead:.2f}x")
    if max_overhead:
        assert overhead <= max_overhead, \
            f"fig_tile streaming overhead {overhead:.2f}x exceeds the " \
            f"{max_overhead}x gate"


_SMOKE_DATASETS = ["tiny-rmat", "tiny-grid"]
_SMOKE_SCALES = [7, 8]
_BATCH_SIZES = (2, 4, 8, 16)
_SMOKE_BATCH_SIZES = (4, 8)

_FIGURES = ("table1", "fig5", "fig6", "strat", "fig_batch", "fig_truss",
            "fig_stream", "fig_auto", "fig_serve", "fig_dist", "fig_tile")


def _parse_figures(spec: str):
    """Split and validate a ``--figures`` list. Unknown names raise
    ``ValueError`` naming every valid figure, mirroring
    ``repro.graphs.datasets.load_dataset``'s unknown-dataset error."""
    figures = [f for f in spec.split(",") if f]
    unknown = sorted(set(figures) - set(_FIGURES))
    if unknown:
        raise ValueError(
            f"unknown figure(s) {', '.join(repr(f) for f in unknown)}; "
            f"available: {', '.join(_FIGURES)}"
        )
    return figures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--figures", default=None,
                    help=f"comma list from {{{','.join(_FIGURES)}}}")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced subset on the tiny fixtures (CI job): "
                         "table1+fig5 by default, any --figures supported")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_<figure>.json sidecars "
                         "(default: current directory)")
    args = ap.parse_args()

    if args.smoke:
        spec = args.figures or "table1,fig5"
        datasets, scales, budget, iters = _SMOKE_DATASETS, _SMOKE_SCALES, False, 1
        batch_sizes = _SMOKE_BATCH_SIZES
    else:
        spec = args.figures or ",".join(_FIGURES)
        datasets, scales, budget, iters = DATASETS_FIG5, FIG6_SCALES, True, 2
        batch_sizes = _BATCH_SIZES
    try:
        figures = _parse_figures(spec)
    except ValueError as e:
        ap.error(str(e))

    print("name,prep_us,count_us,derived")
    if "table1" in figures:
        table1(datasets)
    if "fig5" in figures:
        fig5(datasets, budget=budget, iters=iters)
    if "fig6" in figures:
        fig6(scales, iters=iters)
    if "strat" in figures:
        strat(datasets, iters=iters)
    if "fig_batch" in figures:
        fig_batch(batch_sizes, iters=iters)
    if "fig_truss" in figures:
        fig_truss(datasets, budget=budget, iters=iters)
    if "fig_stream" in figures:
        if args.smoke:
            fig_stream(num_batches=6, batch_edges=32, scale=12,
                       min_speedup=3.0)
        else:
            fig_stream()
    if "fig_auto" in figures:
        fig_auto(datasets, iters=iters, json_dir=args.json_dir)
    if "fig_serve" in figures:
        if args.smoke:
            fig_serve(requests=32, sweep_requests=24, burst_requests=48,
                      min_speedup=2.0)
        else:
            fig_serve(pool_size=12, requests=96, sweep_requests=48,
                      burst_requests=96)
    if "fig_dist" in figures:
        if args.smoke:
            fig_dist(scale=8, edge_factor=8, iters=2, min_speedup=1.0)
        else:
            fig_dist(scale=10, edge_factor=16, iters=3, min_speedup=1.0)
    if "fig_tile" in figures:
        if args.smoke:
            fig_tile(scale=11, edge_factor=8, iters=2, max_overhead=2.0)
        else:
            fig_tile(scale=12, edge_factor=16, iters=3)
    _write_json(figures, args.json_dir, args.smoke)


if __name__ == "__main__":
    main()
