"""Compare two dry-run sweep JSONL files cell by cell.

Each input is a ``launch/dryrun.py`` sweep output: one JSON object per line
with ``arch``, ``shape``, ``mesh``, ``status``, a ``roofline`` block
(``dominant``, ``t_<term>`` seconds, ``useful_ratio``), and a ``memory``
block (``temp_size_in_bytes``). Cells are matched on (arch, shape, mesh);
for every cell present in both files the table shows the dominant roofline
term's time before/after, the delta %, temp memory, and the useful-flop
ratio — the §Perf table of EXPERIMENTS.md.

Cells that are missing from the baseline, failed (``status != "ok"``), or
lack a roofline/memory block are reported as explicit ``n/a`` rows rather
than dropped, so a sweep regression can't hide by erroring out. NaN or
missing metric values render as ``n/a`` too.

Usage:
    python benchmarks/compare_sweeps.py dryrun_baseline.jsonl dryrun_final.jsonl
    python benchmarks/compare_sweeps.py base.jsonl final.jsonl --only-ok

See docs/BENCHMARKS.md §Comparing dry-run sweeps.
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def load(path: str) -> dict:
    """{(arch, shape, mesh): row} from a JSONL sweep file.

    Malformed lines are skipped with a note on stderr instead of aborting
    the whole comparison.
    """
    out = {}
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                r = json.loads(line)
                key = (r["arch"], r.get("shape"), r["mesh"])
            except (json.JSONDecodeError, KeyError, TypeError) as e:
                print(f"# {path}:{lineno}: skipping malformed line ({e})",
                      file=sys.stderr)
                continue
            out[key] = r
    return out


def _num(x) -> float | None:
    """A finite float, or None for missing/NaN/non-numeric values."""
    try:
        v = float(x)
    except (TypeError, ValueError):
        return None
    return v if math.isfinite(v) else None


def _fmt(v: float | None, spec: str, suffix: str = "") -> str:
    return "n/a" if v is None else f"{v:{spec}}{suffix}"


def _dominant_time(row: dict) -> tuple[str, float | None]:
    roof = row.get("roofline") or {}
    dom = roof.get("dominant") or "?"
    return dom, _num(roof.get(f"t_{dom}"))


def _term_time(row: dict, dom: str) -> float | None:
    """Time of a *specific* roofline term (the baseline's dominant), so both
    columns of a row compare the same term even when dominance shifted."""
    return _num((row.get("roofline") or {}).get(f"t_{dom}"))


def compare(base: dict, final: dict, *, only_ok: bool = False) -> int:
    """Print the comparison table; returns the number of comparable cells."""
    print(f"{'cell':46s} {'dom':10s} {'t_dom before':>12s} {'after':>8s} "
          f"{'Δ%':>6s} {'temp before':>11s} {'after':>7s} {'useful b→a':>10s}")
    compared = 0
    for key in sorted(final.keys(), key=str):
        cell = f"{key[0]}/{key[1]}@{key[2]}"
        f = final[key]
        b = base.get(key)
        if b is None:
            if not only_ok:
                print(f"{cell:46s} {'n/a':10s}  (no baseline cell)")
            continue
        if b.get("status") != "ok" or f.get("status") != "ok":
            if not only_ok:
                print(f"{cell:46s} {'n/a':10s}  (status "
                      f"{b.get('status')!r} → {f.get('status')!r})")
            continue
        dom, tb = _dominant_time(b)
        tf = _term_time(f, dom)  # same term as the baseline's dominant
        mb = _num((b.get("memory") or {}).get("temp_size_in_bytes"))
        mf = _num((f.get("memory") or {}).get("temp_size_in_bytes"))
        ub = _num((b.get("roofline") or {}).get("useful_ratio"))
        uf = _num((f.get("roofline") or {}).get("useful_ratio"))
        delta = None if tb in (None, 0.0) or tf is None \
            else 100.0 * (tf - tb) / tb
        print(f"{cell:46s} {dom:10s} {_fmt(tb, '12.2f')} {_fmt(tf, '8.2f')} "
              f"{_fmt(delta, '5.0f', '%')} "
              f"{_fmt(None if mb is None else mb / 1e9, '10.1f', 'G')} "
              f"{_fmt(None if mf is None else mf / 1e9, '6.1f', 'G')} "
              f"{_fmt(ub, '.2f')}→{_fmt(uf, '.2f')}")
        compared += 1
    return compared


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog="See docs/BENCHMARKS.md §Comparing dry-run sweeps.",
    )
    ap.add_argument("baseline", help="baseline sweep JSONL (dryrun output)")
    ap.add_argument("final", help="final sweep JSONL to compare against it")
    ap.add_argument("--only-ok", action="store_true",
                    help="suppress the explicit n/a rows for missing or "
                         "failed cells")
    args = ap.parse_args()

    base = load(args.baseline)
    final = load(args.final)
    compared = compare(base, final, only_ok=args.only_ok)
    print(f"# compared {compared} cells "
          f"({len(base)} baseline, {len(final)} final)")
    if compared == 0:
        print("# no comparable cells — check the inputs", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
