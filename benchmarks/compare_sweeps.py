"""Compare baseline vs final dry-run sweeps for EXPERIMENTS.md §Perf."""
import json, sys

def load(p):
    out = {}
    for line in open(p):
        r = json.loads(line)
        out[(r["arch"], r.get("shape"), r["mesh"])] = r
    return out

base = load("dryrun_baseline.jsonl")
final = load("dryrun_final.jsonl")
print(f"{'cell':46s} {'dom':10s} {'t_dom before':>12s} {'after':>8s} {'Δ%':>6s} "
      f"{'temp before':>11s} {'after':>7s} {'useful b→a':>10s}")
for key in sorted(final.keys()):
    if key not in base: continue
    b, f = base[key], final[key]
    if b["status"] != "ok" or f["status"] != "ok": continue
    rb, rf = b["roofline"], f["roofline"]
    dom = rb["dominant"]
    tb = rb[f"t_{dom}" if dom != "collective" else "t_collective"]
    tf = rf[f"t_{dom}" if dom != "collective" else "t_collective"]
    mb = b["memory"].get("temp_size_in_bytes", 0)/1e9
    mf = f["memory"].get("temp_size_in_bytes", 0)/1e9
    d = 100*(tf-tb)/tb if tb else 0
    print(f"{key[0]+'/'+str(key[1])+'@'+key[2]:46s} {dom:10s} {tb:12.2f} {tf:8.2f} {d:5.0f}% "
          f"{mb:10.1f}G {mf:6.1f}G {rb['useful_ratio']:.2f}→{rf['useful_ratio']:.2f}")
