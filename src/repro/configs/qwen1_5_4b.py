"""qwen1.5-4b [dense] — QKV bias, MHA 20q/20kv [hf:Qwen/Qwen1.5-*]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, kv_heads=20,
    d_ff=6912, vocab=151_936, qkv_bias=True, rope_theta=1_000_000.0,
    microbatches=8,
)

REDUCED = CONFIG.replace(
    name="qwen1.5-4b-reduced", num_layers=4, d_model=64, num_heads=4,
    kv_heads=4, d_ff=128, vocab=256, microbatches=1,
)
