"""One module per assigned architecture (+ the paper's own TC dataset config).

Each exports CONFIG (the exact published configuration) and REDUCED (a
same-family scale-down that one CPU core can forward/train-step in a smoke
test)."""
