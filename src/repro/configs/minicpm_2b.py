"""minicpm-2b [dense] — llama-like, depth-scaled residuals (scale_depth=1.4),
WSD schedule (see train/optimizer.py) [arXiv:2404.06395]."""
import math
from repro.models.config import ModelConfig

_L = 40

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=_L, d_model=2304, num_heads=36, kv_heads=36,
    d_ff=5760, vocab=122_753,
    residual_scale=1.4 / math.sqrt(_L), scale_embedding=True,
    microbatches=8,
)

REDUCED = CONFIG.replace(
    name="minicpm-2b-reduced", num_layers=4, d_model=72, num_heads=4,
    kv_heads=4, d_ff=144, vocab=256,
    residual_scale=1.4 / math.sqrt(4), microbatches=1,
)
