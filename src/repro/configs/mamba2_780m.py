"""mamba2-780m [ssm] — SSD, attention-free, d_state=128 [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, kv_heads=1, d_ff=0,
    vocab=50_280, ssm_state=128, ssm_heads=48, ssm_head_dim=64,
    ssm_chunk=256, expand=2, conv_width=4,
    microbatches=4,
)

REDUCED = CONFIG.replace(
    name="mamba2-780m-reduced", num_layers=4, d_model=64, ssm_state=16,
    ssm_heads=4, ssm_head_dim=32, ssm_chunk=16, vocab=256, microbatches=1,
)
