"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
sandwich norms, GQA 8q/4kv, head_dim 256 [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256_000,
    sliding_window=4096, local_global_pattern=True,
    logit_softcap=50.0, final_softcap=30.0,
    post_norms=True, scale_embedding=True, tie_embeddings=True,
    microbatches=8,
)

REDUCED = CONFIG.replace(
    name="gemma2-2b-reduced", num_layers=4, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=256, sliding_window=16,
    microbatches=1,
)
