"""paligemma-3b [vlm] — SigLIP patch embeddings (stub) prefixed to a
gemma-style decoder, prefix-bidirectional masking, MQA kv=1
[arXiv:2407.07726]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257_216, scale_embedding=True,
    vision_tokens=256, vision_dim=1152,
    microbatches=8,
)

REDUCED = CONFIG.replace(
    name="paligemma-3b-reduced", num_layers=3, d_model=64, num_heads=4,
    kv_heads=1, head_dim=16, d_ff=128, vocab=256, vision_tokens=8,
    vision_dim=24, microbatches=1,
)
