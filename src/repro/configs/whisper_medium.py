"""whisper-medium [audio/encdec] — 24+24 layers, conv frontend stubbed to
precomputed frame embeddings (B, 1500, d) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, kv_heads=16,
    d_ff=4096, vocab=51_865, act="gelu",
    encoder_layers=24, encoder_seq=1500,
    microbatches=4,
)

REDUCED = CONFIG.replace(
    name="whisper-medium-reduced", num_layers=3, d_model=64, num_heads=4,
    kv_heads=4, d_ff=128, vocab=256, encoder_layers=2, encoder_seq=16,
    microbatches=1,
)
