"""The paper's own experiment configuration: Table-1 dataset registry keys
and per-figure benchmark settings (see benchmarks/run.py)."""

DATASETS_FIG5 = [
    "coauthors-like", "copapers-like", "road-like",
    "soclj-like", "citpatents-like", "orkut-like",
]

METHODS = ["tc-intersection-filtered", "tc-intersection-full", "tc-matrix", "tc-SM"]

# SSD-scaling sweep (Fig. 6): RMAT scales with fixed edge factor
FIG6_SCALES = [8, 9, 10, 11, 12, 13]
FIG6_EDGE_FACTOR = 8
