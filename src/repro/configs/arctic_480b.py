"""arctic-480b [moe] — 128 experts top-2 PLUS parallel dense residual FFN
(dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, kv_heads=8,
    d_ff=4864, vocab=32_000,
    num_experts=128, top_k=2, moe_capacity_factor=1.25,
    dense_residual=True, dense_residual_ff=4864,
    fsdp=True, microbatches=4, grad_accum_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    name="arctic-480b-reduced", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, d_ff=96, vocab=256, num_experts=4, top_k=2,
    dense_residual_ff=96, fsdp=False, microbatches=1,
)
