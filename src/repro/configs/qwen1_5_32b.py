"""qwen1.5-32b [dense] — QKV bias, 64L wide [hf:Qwen/Qwen1.5-*]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, kv_heads=40,
    d_ff=27392, vocab=152_064, qkv_bias=True, rope_theta=1_000_000.0,
    fsdp=True, microbatches=8, grad_accum_dtype="bfloat16",
    kv_cache_dtype="int8",
)

REDUCED = CONFIG.replace(
    name="qwen1.5-32b-reduced", num_layers=4, d_model=64, num_heads=4,
    kv_heads=4, d_ff=192, vocab=256, fsdp=False, microbatches=1,
)
