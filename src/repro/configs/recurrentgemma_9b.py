"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, (rec,rec,attn)
pattern, MQA kv=1, window 2048 [arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256_000,
    block_pattern=("rec", "rec", "attn"), lru_width=4096,
    sliding_window=2048, conv_width=4, scale_embedding=True,
    microbatches=8,
)

REDUCED = CONFIG.replace(
    name="recurrentgemma-9b-reduced", num_layers=6, d_model=64, num_heads=4,
    kv_heads=1, head_dim=16, d_ff=128, vocab=256, lru_width=64,
    sliding_window=16, microbatches=1,
)
