"""dbrx-132b [moe] — 16 experts top-4 fine-grained [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, kv_heads=8,
    d_ff=10752, vocab=100_352,
    num_experts=16, top_k=4, moe_capacity_factor=1.25,
    fsdp=True, microbatches=8, grad_accum_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    name="dbrx-132b-reduced", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, d_ff=96, vocab=256, num_experts=4, top_k=2, fsdp=False,
    microbatches=1,
)
