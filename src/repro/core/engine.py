"""Plan/execute engine for exact triangle counting.

The paper's pipeline for every method splits into a *host stage* (filtering,
orientation, degree-class grouping, tile scheduling — §3's FORM_FILTERED_
EDGE_LIST / permute-split / INITIALIZE_CANDIDATE_SET steps) and a *device
stage* (the intersection / masked-SpGEMM / join kernels that §4 measures).
The one-shot ``triangle_count_*`` entry points redo the host stage on every
call, so repeated counts and benchmark sweeps are dominated by numpy prep
instead of the kernels the paper compares.

This module makes the split explicit:

    plan = plan_triangle_count(g, algorithm="intersection", backend="jnp")
    plan.count()   # first call traces + compiles (or hits the shared cache)
    plan.count()   # device-only replay: no numpy, no retrace, no recompile

``plan_triangle_count`` runs the host stage ONCE — orientation + bucketing +
padded neighbor gathers for the intersection path; degree permutation + BSR
tile schedule for the matrix path; 2-core peel + induced-subgraph reform +
bucket setup for the subgraph-matching path — uploads the resulting
statically-shaped arrays to the default device, and binds each work unit to a
jit-compiled executable from a process-wide cache keyed by
``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``. Two
consequences:

* ``plan.count()`` is a pure device replay: one traced computation per bucket
  shape (the kernel AND its reduction live inside the same jit), summed as
  Python ints on the way out.
* Plans over same-shaped graphs (e.g. the fig6 R-MAT sweep, or batches of
  generated graphs) hit the executable cache and skip XLA compilation — the
  TRUST-style decoupling of preprocessing/partitioning from counting.

On the intersection lane (and the subgraph lane's join, which reuses it) the
plan stage also selects a *set-intersection strategy* per degree bucket —
``broadcast`` / ``probe`` / ``bitmap``, see ``repro.kernels.intersect.ops`` —
via the documented ``choose_strategy`` cost model (``strategy="auto"``, the
default: bitmap when the bucket's id range fits the packed width, probe for
wide buckets, broadcast for narrow ones). The choice can be overridden per
plan (``strategy="probe"`` etc.), is baked into each stage's executable-cache
key, and is surfaced as ``meta["bucket_strategies"]`` by
``count_with_stats()``.

Since PR 4 the prep stage itself is *device-resident* by default
(``prep_backend="device"``): orientation, bucketing, padded gathers, the
2-core peel, and the induced-subgraph reform run as the jitted stages in
``repro.core.prep`` / ``repro.graphs.device``, with a ``ShapePolicy``
rounding every data-dependent extent to a power of two so same-policy graphs
share traced prep stages and counting executables. ``prep_backend="host"``
keeps the numpy parity path. On top of the static shapes, ``GraphBatch``
stacks same-policy graphs and counts the whole batch in ONE vmapped device
dispatch (the ``TriangleCounter.count_many`` fast path).

The historical prep helpers (``prepare_intersection_buckets``,
``build_tile_schedule``, ``choose_block``, ``peel_to_two_core``) are thin
wrappers over ``repro.core.prep``, re-exported by the per-algorithm modules
for backward compatibility.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.formats import (
    Graph,
    apply_permutation,
    bucket_edges_by_degree,
    csr_to_padded_neighbors,
    degree_order_permutation,
    induced_subgraph,
    orient_forward,
    to_block_sparse,
)
from repro.graphs.device import DEFAULT_SHAPE_POLICY, DeviceGraph, ShapePolicy
from repro.core import prep
# _two_core_peel: back-compat re-export (it lived here before PR 4)
from repro.core.prep import DeviceBucket, _two_core_peel  # noqa: F401
from repro.core.options import DEFAULT_WIDTHS, resolve_interpret
from repro.kernels.intersect.ops import (
    STRATEGIES,
    choose_strategy,
    intersect_counts,
    resolve_strategy,
)
from repro.kernels.masked_spgemm.ops import masked_spgemm_counts

__all__ = [
    "GraphBatch",
    "TrianglePlan",
    "plan_triangle_count",
    "prepare_intersection_buckets",
    "build_tile_schedule",
    "choose_block",
    "peel_to_two_core",
    "choose_strategy",
    "resolve_strategy",
    "executable_cache_info",
    "clear_executable_cache",
    "DEFAULT_WIDTHS",
    "STRATEGIES",
]

ALGORITHMS = ("intersection", "matrix", "subgraph")


# ---------------------------------------------------------------------------
# Prep stage — thin wrappers over repro.core.prep (kept for the historical
# import surface; the plan stage below calls prep directly)
# ---------------------------------------------------------------------------

def prepare_intersection_buckets(
    g: Graph,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
) -> list:
    """Numpy intersection prep (parity reference) — see
    ``repro.core.prep.prepare_intersection_buckets_host``. The plan stage
    uses the device-resident prep by default (``prep_backend="device"``)."""
    return prep.prepare_intersection_buckets_host(g, variant=variant,
                                                  widths=widths)


def choose_block(g: Graph) -> int:
    """Adaptive matrix-lane tile size — see ``repro.core.prep.choose_block``."""
    return prep.choose_block(g)


def build_tile_schedule(
    g: Graph, block: int = 128, permute: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Matrix-lane tile schedule — see ``repro.core.prep.build_tile_schedule``."""
    return prep.build_tile_schedule(g, block=block, permute=permute)


def peel_to_two_core(g: Graph, labels: Optional[np.ndarray] = None,
                     query_label: Optional[int] = None) -> np.ndarray:
    """Host-API 2-core peel — see ``repro.core.prep.peel_to_two_core``."""
    return prep.peel_to_two_core(g, labels=labels, query_label=query_label)


# ---------------------------------------------------------------------------
# Executable cache — jit-compiled device programs, shared across plans
# ---------------------------------------------------------------------------

_EXECUTABLE_CACHE: Dict[tuple, Callable] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def _build_intersect_executable(strategy: str, backend: str, interpret: bool,
                                bitmap_bits) -> Callable:
    @jax.jit
    def run(u_lists, v_lists):
        counts = intersect_counts(
            u_lists, v_lists, strategy=strategy, backend=backend,
            interpret=interpret, bitmap_bits=bitmap_bits,
        )
        return jnp.sum(counts)

    return run


def _build_matrix_executable(backend: str, interpret: bool) -> Callable:
    @jax.jit
    def run(l_tiles, u_tiles, a_tiles):
        partials = masked_spgemm_counts(
            l_tiles, u_tiles, a_tiles, backend=backend, interpret=interpret
        )
        return jnp.sum(partials)

    return run


def _build_vertex_executable(n: int) -> Callable:
    """Per-vertex triangle counts for one filtered-intersection bucket.

    A probe-style (searchsorted) membership test marks which u-list entries
    appear in both forward neighbor lists; each match (e, w) is one triangle
    (src[e], dst[e], w), so three segment_sums attribute it to its three
    vertices. Padding never matches (disjoint u/v sentinels), so the clip on
    the scatter ids is safe.
    """

    @jax.jit
    def run(u_lists, v_lists, src, dst):
        def one(u, v):
            pos = jnp.clip(jnp.searchsorted(v, u), 0, v.shape[0] - 1)
            return v[pos] == u

        matched = jax.vmap(one)(u_lists, v_lists)  # (E, W) bool
        per_edge = matched.sum(axis=1, dtype=jnp.int32)
        t = jax.ops.segment_sum(per_edge, src, num_segments=n)
        t = t + jax.ops.segment_sum(per_edge, dst, num_segments=n)
        w_ids = jnp.clip(u_lists.reshape(-1), 0, n - 1)
        t = t + jax.ops.segment_sum(
            matched.reshape(-1).astype(jnp.int32), w_ids, num_segments=n
        )
        return t

    return run


def get_executable(algorithm: str, backend: str, interpret: bool,
                   shape_key: tuple, strategy: Optional[str] = None,
                   bitmap_bits: Optional[int] = None) -> Callable:
    """Fetch (or build) the jitted executable for one statically-shaped work
    unit.

    Args:
      algorithm: "intersection" | "subgraph" (both use the intersection
        executables) | "matrix" | "vertex" (per-vertex triangle counts for
        one filtered bucket — the analysis path ``TriangleCounter`` routes
        through the plan).
      backend: "jnp" | "pallas" | "ref" (see ``repro.kernels.*.ops``).
      interpret: pallas interpret mode flag (part of the key: interpret and
        compiled kernels are distinct executables).
      shape_key: the work unit's static array shape, e.g. one degree bucket's
        (E, W), a tile schedule's (T, B, B), or a vertex stage's (E, W, n).
      strategy: resolved set-intersection strategy ("broadcast" | "probe" |
        "bitmap") for the intersection lanes; None for matrix/vertex.
      bitmap_bits: static packed-bitmap capacity when strategy="bitmap",
        else None.

    Returns:
      A jitted callable reducing the work unit (a scalar count, or an (n,)
      per-vertex vector for "vertex"). Cached process-wide under
      ``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``
      so plans over same-shaped buckets/schedules share the compiled kernel.
    """
    if backend not in ("jnp", "pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected 'jnp', 'pallas', or 'ref'")
    key = (algorithm, strategy, backend, bool(interpret), bitmap_bits,
           tuple(shape_key))
    fn = _EXECUTABLE_CACHE.get(key)
    if fn is not None:
        _CACHE_STATS["hits"] += 1
        return fn
    _CACHE_STATS["misses"] += 1
    if algorithm in ("intersection", "subgraph"):
        if strategy not in STRATEGIES:
            raise ValueError(f"unresolved strategy {strategy!r}; "
                             f"expected one of {STRATEGIES}")
        fn = _build_intersect_executable(strategy, backend, interpret,
                                         bitmap_bits)
    elif algorithm == "matrix":
        fn = _build_matrix_executable(backend, interpret)
    elif algorithm == "vertex":
        fn = _build_vertex_executable(int(shape_key[-1]))
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    _EXECUTABLE_CACHE[key] = fn
    return fn


def _build_batch_executable(specs: tuple, backend: str,
                            interpret: bool) -> Callable:
    """One jitted program counting a whole stacked batch of graphs.

    ``specs`` is one ``(strategy, bitmap_bits, (e_pad, width))`` triple per
    bucket; the executable takes the flattened (u, v) pairs — each a
    (B, e_pad, width) stack — and returns the (B,) per-graph totals. Every
    bucket's vmapped intersection and the cross-bucket reduction live in a
    single traced computation: ONE device dispatch per batch.
    """

    @jax.jit
    def run(*arrays):
        total = jnp.zeros(arrays[0].shape[0], jnp.int32)
        for i, (strat, bits, _) in enumerate(specs):
            u, v = arrays[2 * i], arrays[2 * i + 1]

            def one(uu, vv, strat=strat, bits=bits):
                return jnp.sum(intersect_counts(
                    uu, vv, strategy=strat, backend=backend,
                    interpret=interpret, bitmap_bits=bits,
                ))

            total = total + jax.vmap(one)(u, v)
        return total

    return run


def get_batch_executable(specs: tuple, backend: str, interpret: bool,
                         batch: int) -> Callable:
    """Fetch (or build) the vmapped batch executable for one stacked layout.

    Cached in the same process-wide executable cache under
    ``("intersection_batch", None, backend, interpret, None,
    (batch,) + specs)`` — the shape-policy-keyed batch-plan cache: two
    batches whose policy-rounded layouts collide share one compiled program.
    """
    key = ("intersection_batch", None, backend, bool(interpret), None,
           (int(batch),) + tuple(specs))
    fn = _EXECUTABLE_CACHE.get(key)
    if fn is not None:
        _CACHE_STATS["hits"] += 1
        return fn
    _CACHE_STATS["misses"] += 1
    fn = _build_batch_executable(tuple(specs), backend, bool(interpret))
    _EXECUTABLE_CACHE[key] = fn
    return fn


def executable_cache_info() -> dict:
    """{'size': ..., 'hits': ..., 'misses': ...} for tests and benchmarks."""
    return dict(size=len(_EXECUTABLE_CACHE), **_CACHE_STATS)


def clear_executable_cache() -> None:
    _EXECUTABLE_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


# ---------------------------------------------------------------------------
# TrianglePlan — the device-resident, replayable count
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Stage:
    executable: Callable
    args: Tuple[jnp.ndarray, ...]  # device-resident
    shape_key: tuple
    strategy: Optional[str] = None  # resolved intersection strategy
    bitmap_bits: Optional[int] = None  # packed capacity when strategy="bitmap"
    # (src, dst) edge endpoints, device-resident — filtered intersection
    # stages only; lets the per-vertex analysis path replay the same buffers
    vertex_args: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None


@dataclasses.dataclass
class TrianglePlan:
    """A prepared triangle count: device buffers + compiled executables.

    ``count()`` replays the device stage only — no host-side numpy runs after
    construction (tests verify this by poisoning the prep helpers). Build via
    ``plan_triangle_count``.
    """

    algorithm: str
    backend: str
    interpret: bool
    stages: List[_Stage]
    divisor: int  # 6 for the full-variant intersection (each triangle ×6)
    meta: Dict[str, Any]
    prep_seconds: float
    executions: int = 0

    def count(self) -> int:
        """Exact triangle count; pure device replay of the cached stages."""
        if self.algorithm == "matrix":
            total_f = 0.0
            for st in self.stages:
                total_f += float(st.executable(*st.args))
            total = int(round(total_f))
        else:
            total = 0
            for st in self.stages:
                total += int(st.executable(*st.args))
        if self.divisor != 1:
            assert total % self.divisor == 0, total
            total //= self.divisor
        self.executions += 1
        return total

    def count_with_stats(self) -> Tuple[int, dict]:
        """Count once and return the plan's prep statistics alongside.

        Returns:
          (count, meta): meta carries statistics gathered at plan time —
          prune fractions, tile schedule sizes, bucket shapes, and on the
          intersection/subgraph lanes ``bucket_strategies``: one
          ``(width, strategy)`` pair per degree bucket as resolved by the
          ``strategy="auto"`` cost model (or the per-plan override).
        """
        c = self.count()
        stats = dict(self.meta)
        if self.algorithm == "subgraph":
            stats["num_embeddings"] = 6 * c
        return c, stats

    def triangles_per_vertex(self) -> np.ndarray:
        """Per-vertex triangle counts, replayed through this plan's cached
        device buffers (the analysis path ``repro.core.api.TriangleCounter``
        routes here instead of the host-side enumeration in ``listing.py``).

        Supported on plans whose stages carry edge endpoints — the filtered
        intersection lane and the subgraph lane (whose counts on the pruned
        graph scatter back through ``meta["vertex_map"]``; peeled vertices
        are in no triangle by construction).

        Returns:
          (n,) int64 numpy array, t[v] = number of triangles containing v.

        Raises:
          NotImplementedError: matrix lane or the full intersection variant
            (no per-edge endpoints to attribute matches to); callers fall
            back to a filtered-intersection sidecar plan.
        """
        if self.algorithm not in ("intersection", "subgraph") \
                or self.divisor != 1 \
                or any(st.vertex_args is None for st in self.stages):
            raise NotImplementedError(
                f"per-vertex counts need filtered-intersection stages; "
                f"algorithm={self.algorithm!r} divisor={self.divisor} does "
                f"not carry them"
            )
        n_local = int(self.meta.get("vertex_n", self.meta["n"]))
        total = np.zeros(n_local, dtype=np.int64)
        for st in self.stages:
            e, w = st.shape_key
            fn = get_executable("vertex", "jnp", False, (e, w, n_local))
            total += np.asarray(fn(*st.args, *st.vertex_args), dtype=np.int64)
        vertex_map = self.meta.get("vertex_map")
        if vertex_map is not None:  # subgraph lane: pruned ids -> original
            out = np.zeros(int(self.meta["n"]), dtype=np.int64)
            out[np.asarray(vertex_map)] = total
            return out
        return total

    def block_until_ready(self) -> "TrianglePlan":
        """Force all device buffers resident (useful before timing counts)."""
        for st in self.stages:
            for a in st.args:
                a.block_until_ready()
        return self

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def shape_keys(self) -> List[tuple]:
        return [st.shape_key for st in self.stages]


def _resolve_bucket_strategy(width: int, id_range: int, strategy: str,
                             bitmap_bits: Optional[int]):
    """Resolve one bucket's (strategy, bitmap_bits), honoring a forced
    ``bitmap_bits`` override (which must cover the id range)."""
    strat, bits = resolve_strategy(width, id_range, strategy=strategy)
    if bitmap_bits is not None and strat == "bitmap":
        if bitmap_bits < id_range:
            raise ValueError(
                f"bitmap_bits={bitmap_bits} cannot represent id range "
                f"{id_range} (n + 2 sentinel ids); ids past the capacity "
                f"would silently never match"
            )
        bits = int(bitmap_bits)
    return strat, bits


def _buckets_for_plan(g, variant: str, widths: Sequence[int],
                      prep_backend: str, policy: Optional[ShapePolicy],
                      ) -> List[DeviceBucket]:
    """Run the prep stage on the requested backend; either way the result is
    device-resident ``DeviceBucket``s (the host path uploads its arrays)."""
    if prep_backend == "device":
        return prep.prepare_intersection_buckets_device(
            g, variant=variant, widths=widths, policy=policy,
        )
    host = prep.prepare_intersection_buckets_host(g, variant=variant,
                                                  widths=widths)
    return [
        DeviceBucket(
            width=b["width"], edges=int(b["u_lists"].shape[0]),
            u_lists=jnp.asarray(b["u_lists"]), v_lists=jnp.asarray(b["v_lists"]),
            src=jnp.asarray(b["src"]), dst=jnp.asarray(b["dst"]),
        )
        for b in host
    ]


def _plan_intersection(g, variant: str, backend: str, interpret: bool,
                       widths: Sequence[int], strategy: str = "auto",
                       bitmap_bits: Optional[int] = None,
                       prep_backend: str = "device",
                       shape_policy: Optional[ShapePolicy] = None,
                       ) -> Tuple[List[_Stage], int, dict]:
    buckets = _buckets_for_plan(g, variant, widths, prep_backend, shape_policy)
    # id range covers real vertex ids [0, n) plus the in-row padding
    # sentinels n (u rows) and n+1 (v rows); whole-row padding (-1/-2) is
    # negative and never matches in any core
    id_range = g.n + 2
    stages = []
    for b in buckets:
        shape_key = b.shape
        strat, bits = _resolve_bucket_strategy(b.width, id_range, strategy,
                                               bitmap_bits)
        fn = get_executable("intersection", backend, interpret, shape_key,
                            strategy=strat, bitmap_bits=bits)
        vertex_args = None
        if variant == "filtered":
            vertex_args = (b.src, b.dst)
        stages.append(_Stage(
            executable=fn,
            args=(b.u_lists, b.v_lists),
            shape_key=shape_key,
            strategy=strat,
            bitmap_bits=bits,
            vertex_args=vertex_args,
        ))
    policy = shape_policy if shape_policy is not None else DEFAULT_SHAPE_POLICY
    meta = dict(
        variant=variant,
        widths=tuple(widths),
        strategy=strategy,
        prep_backend=prep_backend,
        shape_policy=policy.key() if prep_backend == "device" else None,
        bucket_shapes=[s.shape_key for s in stages],
        bucket_strategies=[(s.shape_key[1], s.strategy) for s in stages],
        bucket_edges=[b.edges for b in buckets],
        edges=int(sum(b.edges for b in buckets)),
    )
    return stages, (6 if variant == "full" else 1), meta


def _plan_matrix(g: Graph, block, permute: bool, backend: str,
                 interpret: bool) -> Tuple[List[_Stage], int, dict]:
    if block == "auto":
        block = choose_block(g)
    l_sel, u_sel, a_sel, stats = build_tile_schedule(
        g, block=block, permute=permute
    )
    stages = []
    if l_sel.shape[0]:
        shape_key = tuple(l_sel.shape)
        fn = get_executable("matrix", backend, interpret, shape_key)
        stages.append(_Stage(
            executable=fn,
            args=(jnp.asarray(l_sel), jnp.asarray(u_sel), jnp.asarray(a_sel)),
            shape_key=shape_key,
        ))
    meta = dict(permute=permute, **stats)
    return stages, 1, meta


def _plan_subgraph(g: Graph, backend: str, interpret: bool,
                   widths: Sequence[int], strategy: str = "auto",
                   bitmap_bits: Optional[int] = None,
                   prep_backend: str = "device",
                   shape_policy: Optional[ShapePolicy] = None,
                   ) -> Tuple[List[_Stage], int, dict]:
    if prep_backend == "device":
        # FILTER + RECONSTRUCT on device: the induced graph keeps original
        # vertex ids (dead vertices just lose their rows), so stage counts
        # scatter directly into original-id space — no vertex_map needed
        policy = shape_policy if shape_policy is not None \
            else DEFAULT_SHAPE_POLICY
        dg = DeviceGraph.from_graph(g, policy)
        alive = prep.peel_to_two_core_device(dg)
        sub_dg = prep.induced_device_graph(dg, alive)
        alive_count = int(jnp.sum(alive))
        stages, _, inner = _plan_intersection(
            sub_dg, variant="filtered", backend=backend, interpret=interpret,
            widths=widths, strategy=strategy, bitmap_bits=bitmap_bits,
            prep_backend="device", shape_policy=policy,
        )
        # the sub-plan's id range is the parent's (ids are preserved)
        meta = dict(
            vertices_pruned=int(g.n - alive_count),
            prune_fraction=float(1.0 - alive_count / max(g.n, 1)),
            edges_after=sub_dg.m_undirected,
            edges_before=g.m_undirected,
            vertex_n=g.n,
            **inner,
        )
        return stages, 1, meta

    alive = peel_to_two_core(g)
    sub, old_ids = induced_subgraph(g, alive)
    # join on the pruned graph; forward-filtered intersection counts each
    # triangle once (embeddings = 6 × that)
    stages, _, inner = _plan_intersection(
        sub, variant="filtered", backend=backend, interpret=interpret,
        widths=widths, strategy=strategy, bitmap_bits=bitmap_bits,
        prep_backend="host",
    )
    # subgraph stages share the intersection executables by construction
    meta = dict(
        vertices_pruned=int(g.n - alive.sum()),
        prune_fraction=float(1.0 - alive.sum() / max(g.n, 1)),
        edges_after=sub.m_undirected,
        edges_before=g.m_undirected,
        # per-vertex analysis: stage counts are on the pruned graph's ids;
        # scatter back through old_ids (peeled vertices hold no triangles)
        vertex_n=sub.n,
        vertex_map=np.asarray(old_ids),
        **inner,
    )
    return stages, 1, meta


def plan_triangle_count(
    g: Graph,
    algorithm: str = "intersection",
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
    block="auto",
    permute: bool = True,
    bitmap_bits: Optional[int] = None,
    prep_backend: str = "device",
    shape_policy: Optional[ShapePolicy] = None,
) -> TrianglePlan:
    """Run the host stage once and return a device-resident ``TrianglePlan``.

    Args:
      g: the input ``Graph`` (undirected simple CSR).
      algorithm: "intersection" | "matrix" | "subgraph".
      backend: "jnp" | "pallas" | "ref" per-kernel execution path.
      interpret: pallas interpret mode (True runs kernel bodies on CPU);
        None (default) resolves to ``repro.core.options.DEFAULT_INTERPRET``
        (the ``TC_INTERPRET`` env var, unset ⇒ True).
      variant: intersection lane only — "filtered" (forward algorithm) or
        "full" (every directed edge, each triangle found 6×).
      widths: degree-class bucket widths for the intersection/subgraph lanes.
      strategy: intersection/subgraph lanes only — per-bucket set-intersection
        core: "auto" (default; the documented ``choose_strategy`` cost model
        picks bitmap/probe/broadcast per bucket) or a forced "broadcast" |
        "probe" | "bitmap" override applied to every bucket.
      block: matrix lane tile size, or "auto" (``choose_block``).
      permute: matrix lane degree permutation toggle.
      bitmap_bits: optional forced packed capacity for bitmap-strategy
        buckets (must cover the graph's id range ``n + 2``); None sizes it
        via ``resolve_strategy``.
      prep_backend: intersection/subgraph lanes — "device" (default) runs
        the prep stage as the jitted pipeline in ``repro.core.prep``;
        "host" runs the numpy parity path.
      shape_policy: the ``ShapePolicy`` rounding device-prep extents into
        static shape classes; None means ``DEFAULT_SHAPE_POLICY``.

    Returns:
      A ``TrianglePlan`` whose ``count()`` replays the device stage only.
      The per-algorithm keyword arguments match ``CountOptions``; the
      facade (``repro.core.api.TriangleCounter``) and the deprecated
      one-shot ``triangle_count_*`` shims both route here.
    """
    interpret = resolve_interpret(interpret)
    t0 = time.perf_counter()
    if algorithm == "intersection":
        stages, divisor, meta = _plan_intersection(
            g, variant, backend, interpret, widths, strategy, bitmap_bits,
            prep_backend, shape_policy,
        )
    elif algorithm == "matrix":
        stages, divisor, meta = _plan_matrix(g, block, permute, backend, interpret)
    elif algorithm == "subgraph":
        stages, divisor, meta = _plan_subgraph(g, backend, interpret, widths,
                                               strategy, bitmap_bits,
                                               prep_backend, shape_policy)
    else:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; expected one of {ALGORITHMS}"
        )
    meta.setdefault("graph", g.name)
    meta["n"], meta["m"] = g.n, g.m_undirected
    prep_seconds = time.perf_counter() - t0
    return TrianglePlan(
        algorithm=algorithm,
        backend=backend,
        interpret=interpret,
        stages=stages,
        divisor=divisor,
        meta=meta,
        prep_seconds=prep_seconds,
    )


# ---------------------------------------------------------------------------
# GraphBatch — same-policy graphs stacked into one vmapped dispatch
# ---------------------------------------------------------------------------

def _pad_bucket_rows(arr: jnp.ndarray, e_pad: int, fill: int) -> jnp.ndarray:
    pad = e_pad - int(arr.shape[0])
    if pad <= 0:
        return arr
    return jnp.concatenate(
        [arr, jnp.full((pad, arr.shape[1]), fill, arr.dtype)]
    )


@dataclasses.dataclass
class GraphBatch:
    """A batch of graphs prepped under one ``ShapePolicy`` and stacked so the
    whole batch is counted by ONE vmapped device dispatch.

    Build via ``from_graphs``: each member runs the device-resident
    intersection prep, the per-width buckets are harmonized to the maximum
    policy-rounded extent across members (missing widths become all-padding
    buckets, which count zero), and each width's (u, v) pairs are stacked
    into (B, E, W) arrays. ``counts()`` then runs a single jitted program —
    every bucket's vmapped intersection plus the cross-bucket sum — from the
    shape-policy-keyed batch-executable cache. This is the
    ``TriangleCounter.count_many`` fast path.
    """

    graphs: List[Any]
    backend: str
    interpret: bool
    divisor: int
    specs: tuple  # ((strategy, bitmap_bits, (e_pad, width)), ...) per bucket
    arrays: List[jnp.ndarray]  # flattened (u, v) stacks, device-resident
    meta: Dict[str, Any]
    prep_seconds: float
    executions: int = 0

    @property
    def batch_size(self) -> int:
        return len(self.graphs)

    @property
    def shape_keys(self) -> List[tuple]:
        return [shape for _, _, shape in self.specs]

    def counts(self) -> np.ndarray:
        """(B,) exact triangle counts — one device dispatch for the batch."""
        if not self.specs:
            out = np.zeros(self.batch_size, dtype=np.int64)
        else:
            fn = get_batch_executable(self.specs, self.backend,
                                      self.interpret, self.batch_size)
            out = np.asarray(fn(*self.arrays), dtype=np.int64)
        if self.divisor != 1:
            assert (out % self.divisor == 0).all(), out
            out //= self.divisor
        self.executions += 1
        return out

    def block_until_ready(self) -> "GraphBatch":
        for a in self.arrays:
            a.block_until_ready()
        return self

    @classmethod
    def from_graphs(cls, graphs: Sequence[Graph], options=None,
                    **overrides) -> "GraphBatch":
        """Prep + stack ``graphs`` under one options bag.

        Args:
          graphs: host ``Graph``s (any mix of sizes; the stacked layout is
            the per-width maximum of the policy-rounded extents).
          options: a ``CountOptions``; None builds one from ``**overrides``.
            Must have ``backend="jnp"`` (the vmapped cores are the pure-jnp
            paths) and ``prep_backend="device"``.

        Raises:
          ValueError: empty batch, or options outside the batchable regime.
        """
        from repro.core.options import CountOptions

        if options is None:
            options = CountOptions(**overrides)
        elif overrides:
            options = options.replace(**overrides)
        graphs = list(graphs)
        if not graphs:
            raise ValueError("GraphBatch needs at least one graph")
        if options.backend != "jnp":
            raise ValueError(
                f"GraphBatch requires backend='jnp' (vmapped pure-jnp "
                f"cores); got {options.backend!r}"
            )
        if options.prep_backend != "device":
            raise ValueError(
                "GraphBatch requires prep_backend='device' (the stacked "
                "layout is defined by the device prep's ShapePolicy)"
            )
        policy = options.resolved_shape_policy
        interpret = options.resolved_interpret
        t0 = time.perf_counter()
        per_graph = [
            prep.prepare_intersection_buckets_device(
                g, variant=options.variant, widths=options.widths,
                policy=policy,
            )
            for g in graphs
        ]
        # harmonize: per width, every member is padded to the max rounded
        # extent; members without that width contribute all-padding buckets
        widths_union = sorted({b.width for bs in per_graph for b in bs})
        id_range = max(g.n for g in graphs) + 2
        specs, arrays = [], []
        for w in widths_union:
            members = [
                {b.width: b for b in bs}.get(w) for bs in per_graph
            ]
            e_pad = max(policy.round_edges(1) if b is None else b.e_pad
                        for b in members)
            us, vs = [], []
            for b in members:
                if b is None:
                    us.append(jnp.full((e_pad, w), -1, jnp.int32))
                    vs.append(jnp.full((e_pad, w), -2, jnp.int32))
                else:
                    us.append(_pad_bucket_rows(b.u_lists, e_pad, -1))
                    vs.append(_pad_bucket_rows(b.v_lists, e_pad, -2))
            strat, bits = _resolve_bucket_strategy(
                w, id_range, options.strategy, options.bitmap_bits
            )
            specs.append((strat, bits, (e_pad, w)))
            arrays.extend([jnp.stack(us), jnp.stack(vs)])
        prep_seconds = time.perf_counter() - t0
        meta = dict(
            batch_size=len(graphs),
            variant=options.variant,
            widths=tuple(options.widths),
            strategy=options.strategy,
            shape_policy=policy.key(),
            prep_backend="device",
            bucket_shapes=[s[2] for s in specs],
            bucket_strategies=[(s[2][1], s[0]) for s in specs],
            graphs=[g.name for g in graphs],
        )
        return cls(
            graphs=graphs,
            backend=options.backend,
            interpret=interpret,
            divisor=6 if options.variant == "full" else 1,
            specs=tuple(specs),
            arrays=arrays,
            meta=meta,
            prep_seconds=prep_seconds,
        )
