"""Plan/execute engine for exact triangle counting.

The paper's pipeline for every method splits into a *host stage* (filtering,
orientation, degree-class grouping, tile scheduling — §3's FORM_FILTERED_
EDGE_LIST / permute-split / INITIALIZE_CANDIDATE_SET steps) and a *device
stage* (the intersection / masked-SpGEMM / join kernels that §4 measures).
The one-shot ``triangle_count_*`` entry points redo the host stage on every
call, so repeated counts and benchmark sweeps are dominated by numpy prep
instead of the kernels the paper compares.

This module makes the split explicit:

    plan = plan_triangle_count(g, algorithm="intersection", backend="jnp")
    plan.count()   # first call traces + compiles (or hits the shared cache)
    plan.count()   # device-only replay: no numpy, no retrace, no recompile

``plan_triangle_count`` runs the host stage ONCE — orientation + bucketing +
padded neighbor gathers for the intersection path; degree permutation + BSR
tile schedule for the matrix path; 2-core peel + induced-subgraph reform +
bucket setup for the subgraph-matching path — uploads the resulting
statically-shaped arrays to the default device, and binds each work unit to a
jit-compiled executable from a process-wide cache keyed by
``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``. Two
consequences:

* ``plan.count()`` is a pure device replay: one traced computation per bucket
  shape (the kernel AND its reduction live inside the same jit), summed as
  Python ints on the way out.
* Plans over same-shaped graphs (e.g. the fig6 R-MAT sweep, or batches of
  generated graphs) hit the executable cache and skip XLA compilation — the
  TRUST-style decoupling of preprocessing/partitioning from counting.

On the intersection lane (and the subgraph lane's join, which reuses it) the
plan stage also selects a *set-intersection strategy* per degree bucket —
``broadcast`` / ``probe`` / ``bitmap``, see ``repro.kernels.intersect.ops`` —
via the documented ``choose_strategy`` cost model (``strategy="auto"``, the
default: bitmap when the bucket's id range fits the packed width, probe for
wide buckets, broadcast for narrow ones). The choice can be overridden per
plan (``strategy="probe"`` etc.), is baked into each stage's executable-cache
key, and is surfaced as ``meta["bucket_strategies"]`` by
``count_with_stats()``.

Since PR 4 the prep stage itself is *device-resident* by default
(``prep_backend="device"``): orientation, bucketing, padded gathers, the
2-core peel, and the induced-subgraph reform run as the jitted stages in
``repro.core.prep`` / ``repro.graphs.device``, with a ``ShapePolicy``
rounding every data-dependent extent to a power of two so same-policy graphs
share traced prep stages and counting executables. ``prep_backend="host"``
keeps the numpy parity path. On top of the static shapes, ``GraphBatch``
stacks same-policy graphs and counts the whole batch in ONE vmapped device
dispatch (the ``TriangleCounter.count_many`` fast path).

Since PR 5 the engine also owns the *edge lane* (``algorithm="edge"``,
``plan_edge_support`` → ``TrussPlan``): cached per-edge support executables
mirroring the "vertex" analysis executables, plus the device k-truss peel
loop (support recompute → filter → re-orient through the same device prep
machinery) — the last host-enumeration hot path (``listing.py``'s
``edge_support``/``k_truss``) made device-resident.

The historical prep helpers (``prepare_intersection_buckets``,
``build_tile_schedule``, ``choose_block``, ``peel_to_two_core``) are thin
wrappers over ``repro.core.prep``, re-exported by the per-algorithm modules
for backward compatibility.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

try:  # jax ≥ 0.6 exports shard_map at top level
    from jax import shard_map
except ImportError:  # older jax ships it under experimental
    from jax.experimental.shard_map import shard_map

from repro.graphs.formats import (
    Graph,
    apply_permutation,
    bucket_edges_by_degree,
    csr_to_padded_neighbors,
    degree_order_permutation,
    edges_to_csr,
    induced_subgraph,
    orient_forward,
    to_block_sparse,
)
from repro.graphs.device import (
    DEFAULT_SHAPE_POLICY,
    EDGE_KEY_SENTINEL,
    DeviceCSR,
    DeviceGraph,
    GraphTooLargeError,
    ShapePolicy,
    ShardedDeviceCSR,
    bfs_levels,
    deal_across_shards,
    dynamic_update_step,
    edge_key_context,
    edge_key_dtype,
    edge_key_sentinel,
    fits_int32_pair_keys,
    next_pow2,
    resolve_edge_key_mode,
    shard_valid_counts,
)
from repro.core import prep
# _two_core_peel: back-compat re-export (it lived here before PR 4)
from repro.core.prep import DeviceBucket, _two_core_peel  # noqa: F401
from repro.core.options import DEFAULT_WIDTHS, resolve_interpret
from repro.core.registry import register_algorithm
from repro.kernels.intersect.ops import (
    STRATEGIES,
    choose_strategy,
    intersect_counts,
    intersect_matches,
    intersect_matches_both,
    resolve_mask_strategy,
    resolve_strategy,
)
from repro.kernels.hash_tc.ops import (
    build_hash_table,
    hash_num_buckets,
    hash_probe_counts,
    hash_table_depth,
)
from repro.kernels.masked_spgemm.ops import masked_spgemm_counts

__all__ = [
    "DynamicPlan",
    "GraphBatch",
    "TrianglePlan",
    "TrussPlan",
    "plan_triangle_count",
    "plan_bfs_count",
    "plan_edge_support",
    "plan_dynamic_count",
    "plan_hash_count",
    "prepare_intersection_buckets",
    "build_tile_schedule",
    "choose_block",
    "peel_to_two_core",
    "choose_strategy",
    "resolve_strategy",
    "executable_cache_info",
    "clear_executable_cache",
    "mesh_cache_component",
    "DEFAULT_WIDTHS",
    "DISTRIBUTED_ALGORITHMS",
    "STRATEGIES",
]

ALGORITHMS = ("intersection", "matrix", "subgraph", "hash", "bfs")

# Mesh-planned lanes: same plan/execute machinery, per-shard executables in
# the same process-wide cache (key gains the mesh component), one scalar
# psum per stage. ``plan_triangle_count(..., mesh=...)`` accepts these.
DISTRIBUTED_ALGORITHMS = ("intersection_distributed", "matrix_distributed")


def mesh_cache_component(mesh) -> tuple:
    """The hashable mesh identity folded into distributed cache keys:
    ``(axis names, mesh shape, flat device ids)``. Two meshes with equal
    components produce identical sharded programs, so their executables may
    be shared; any shard-shape change (e.g. (8,) → (4, 2)) misses exactly
    once."""
    return (tuple(str(a) for a in mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


# ---------------------------------------------------------------------------
# Prep stage — thin wrappers over repro.core.prep (kept for the historical
# import surface; the plan stage below calls prep directly)
# ---------------------------------------------------------------------------

def prepare_intersection_buckets(
    g: Graph,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
) -> list:
    """Numpy intersection prep (parity reference) — see
    ``repro.core.prep.prepare_intersection_buckets_host``. The plan stage
    uses the device-resident prep by default (``prep_backend="device"``)."""
    return prep.prepare_intersection_buckets_host(g, variant=variant,
                                                  widths=widths)


def choose_block(g: Graph) -> int:
    """Adaptive matrix-lane tile size — see ``repro.core.prep.choose_block``."""
    return prep.choose_block(g)


def build_tile_schedule(
    g: Graph, block: int = 128, permute: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Matrix-lane tile schedule — see ``repro.core.prep.build_tile_schedule``."""
    return prep.build_tile_schedule(g, block=block, permute=permute)


def peel_to_two_core(g: Graph, labels: Optional[np.ndarray] = None,
                     query_label: Optional[int] = None) -> np.ndarray:
    """Host-API 2-core peel — see ``repro.core.prep.peel_to_two_core``."""
    return prep.peel_to_two_core(g, labels=labels, query_label=query_label)


# ---------------------------------------------------------------------------
# Executable cache — jit-compiled device programs, shared across plans
# ---------------------------------------------------------------------------

class _BoundedLRU:
    """Thread-safe, size-bounded LRU of jitted executables.

    ``get_or_build`` is the single get-or-compile gate the serving layer
    relies on: a hit moves the key to the MRU end; a miss claims the key
    under the lock, releases it, builds, then inserts and evicts from the
    LRU end. Racing requests for the same key block on the claimant's event
    and pick up the one built callable (counted as hits) — no duplicate
    compiles. Eviction only drops the *cache reference*: live plans hold
    direct references to their executables, so an evicted program keeps
    working and is simply rebuilt on its next cold fetch (jit tracing is
    lazy, so a rebuild is cheap until the shape is actually re-run).
    """

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        self._data: "OrderedDict[tuple, Callable]" = OrderedDict()
        self._lock = threading.RLock()
        self._pending: Dict[tuple, threading.Event] = {}
        self.maxsize = int(maxsize)
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_build(self, key: tuple, builder: Callable[[], Callable]):
        while True:
            with self._lock:
                fn = self._data.get(key)
                if fn is not None:
                    self._data.move_to_end(key)
                    self.hits += 1
                    return fn
                ev = self._pending.get(key)
                if ev is None:
                    self._pending[key] = threading.Event()
                    self.misses += 1
                    break
            ev.wait()  # someone else is building this key; re-check
        try:
            fn = builder()
        except BaseException:
            with self._lock:
                self._pending.pop(key).set()
            raise
        with self._lock:
            self._data[key] = fn
            self._data.move_to_end(key)
            self._evict_locked()
            self._pending.pop(key).set()
        return fn

    def _evict_locked(self) -> None:
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1

    def set_maxsize(self, maxsize: int) -> int:
        if maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        with self._lock:
            old = self.maxsize
            self.maxsize = int(maxsize)
            self._evict_locked()
            return old

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def info(self, include_keys: bool = False) -> dict:
        with self._lock:
            d = dict(size=len(self._data), hits=self.hits,
                     misses=self.misses, maxsize=self.maxsize,
                     evictions=self.evictions)
            if include_keys:
                d["keys"] = tuple(self._data.keys())
            return d

    # dict-compatible read views (tests poke entries by key)
    def __getitem__(self, key: tuple) -> Callable:
        with self._lock:
            return self._data[key]

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


def _env_cache_size() -> int:
    raw = os.environ.get("TC_EXEC_CACHE_SIZE", "512")
    try:
        size = int(raw)
    except ValueError as e:
        raise ValueError(f"TC_EXEC_CACHE_SIZE={raw!r} is not an int") from e
    if size < 1:
        raise ValueError(f"TC_EXEC_CACHE_SIZE must be >= 1, got {size}")
    return size


_EXECUTABLE_CACHE = _BoundedLRU(_env_cache_size())


def _build_intersect_executable(strategy: str, backend: str, interpret: bool,
                                bitmap_bits) -> Callable:
    @jax.jit
    def run(u_lists, v_lists):
        counts = intersect_counts(
            u_lists, v_lists, strategy=strategy, backend=backend,
            interpret=interpret, bitmap_bits=bitmap_bits,
        )
        return jnp.sum(counts)

    return run


def _build_matrix_executable(backend: str, interpret: bool) -> Callable:
    @jax.jit
    def run(l_tiles, u_tiles, a_tiles):
        partials = masked_spgemm_counts(
            l_tiles, u_tiles, a_tiles, backend=backend, interpret=interpret
        )
        return jnp.sum(partials)

    return run


def _build_hash_executable(backend: str, interpret: bool) -> Callable:
    """Per-bucket total for the TRUST-style hashing lane.

    The stage args are ``(v_lists, src, table)``: the bucket's candidate
    rows (N⁺(dst), the standard v-side sentinel layout), their anchor
    vertices, and the plan-wide (n, B, D) per-vertex hash table. The core
    (``repro.kernels.hash_tc``) probes each candidate against its anchor's
    hash row, so per-edge work is O(W·D) instead of the sorted-merge costs.
    The cache ``shape_key`` is ``(e_pad, width, num_buckets, depth)`` — the
    table shape class rides in the key because the traced gather shapes
    depend on it.
    """

    @jax.jit
    def run(w_lists, src, table):
        counts = hash_probe_counts(
            w_lists, src, table, backend=backend, interpret=interpret
        )
        return jnp.sum(counts)

    return run


def _build_vertex_executable(n: int) -> Callable:
    """Per-vertex triangle counts for one filtered-intersection bucket.

    ``intersect_matches`` (the mask form of the set-intersection core) marks
    which u-list entries appear in both forward neighbor lists; each match
    (e, w) is one triangle (src[e], dst[e], w), so three segment_sums
    attribute it to its three vertices. Padding never matches (disjoint u/v
    sentinels), so the clip on the scatter ids is safe.
    """

    @jax.jit
    def run(u_lists, v_lists, src, dst):
        matched = intersect_matches(u_lists, v_lists)  # (E, W) bool
        per_edge = matched.sum(axis=1, dtype=jnp.int32)
        t = jax.ops.segment_sum(per_edge, src, num_segments=n)
        t = t + jax.ops.segment_sum(per_edge, dst, num_segments=n)
        w_ids = jnp.clip(u_lists.reshape(-1), 0, n - 1)
        t = t + jax.ops.segment_sum(
            matched.reshape(-1).astype(jnp.int32), w_ids, num_segments=n
        )
        return t

    return run


def _build_edge_executable(strategy: str, bitmap_bits: Optional[int],
                           shape_key: tuple) -> Callable:
    """Per-edge support contributions for one filtered-intersection bucket.

    The edge analogue of the vertex executable: every match (e, j) is one
    triangle (src, dst, w = u_lists[e, j]) whose three undirected edges are
    (src, dst), (src, w) and (dst, w). Support is accumulated in *forward
    CSR slot* order — each undirected edge owns exactly one oriented slot —
    which turns the heavy side-edge scatters into dense per-row adds:

    * (src, dst): slot = row_ptr[src] + (dst's position in the u row); one
      E-sized scatter of the per-edge intersection sizes.
    * (src, w):   w sits at u-row position j, so its slot is
      row_ptr[src] + j. Group the u-side match mask by src
      (one row-wise segment_sum to (n, W)) and add whole rows at
      row_ptr[src] + arange(W) — no per-element binary search.
    * (dst, w):   symmetric via the v-side match mask (``matched_v`` from
      ``intersect_matches_both``) grouped by dst.

    The caller converts slot order to sorted-key (= ``edge_list_unique``)
    order with the permutation from ``prep.forward_edge_keys_*`` — once per
    round, not per bucket.

    ``strategy``/``bitmap_bits`` are the resolved match-mask core — the
    mask-specific ``resolve_mask_strategy`` cost model (bitmap out to ~4·W
    packed bits, since the probe mask pays two searchsorted passes), so
    dense-id buckets get the TRUST bitmap core (pack + gather-test, the big
    win on clique-like graphs), wide ones probe, narrow ones broadcast.
    ``shape_key`` is ``(e_pad, width, mk, n1, *peel_knobs)`` — mk the padded
    slot-array length, n1 = n + 1. The trailing peel knobs
    (``max_peel_iters``, ``peel_early_exit``) do not change the traced
    computation; they are folded into the key so ``CountOptions`` equality
    exactly tracks edge-executable sharing (see ``get_executable``).

    Padding is inert everywhere: padded bucket rows (u = -1 / v = -2) and
    in-row sentinels (n / n+1) never match, so their scatter values are
    zero; positions past a row's true degree carry zeros, and out-of-range
    slots are dropped (``mode="drop"``).
    """
    _, width, mk, n1 = (int(x) for x in shape_key[:4])
    n = n1 - 1

    body = _edge_support_body(strategy, bitmap_bits, width, mk, n)
    return jax.jit(body)


def _edge_support_body(strategy: str, bitmap_bits: Optional[int],
                       width: int, mk: int, n: int) -> Callable:
    """The traced slot-ordered support computation shared by the single-host
    edge executable (jitted directly) and the distributed one (wrapped in
    shard_map over a dealt row partition — the scatters target the full
    (mk,) slot space whichever rows a shard holds, so partial supports sum
    under psum)."""

    def run(u_lists, v_lists, src, dst, row_ptr):
        matched_u, matched_v = intersect_matches_both(
            u_lists, v_lists, strategy=strategy, bitmap_bits=bitmap_bits)
        per_edge = matched_u.sum(axis=1, dtype=jnp.int32)
        # (src, dst): dst's position in the sorted u row
        base_j = jax.vmap(
            lambda u, d: jnp.clip(jnp.searchsorted(u, d), 0, width - 1)
        )(u_lists, dst)
        supp = jnp.zeros(mk, jnp.int32).at[row_ptr[src] + base_j].add(
            per_edge, mode="drop")
        # (src, w) / (dst, w): row-grouped masks, added as whole rows
        by_src = jax.ops.segment_sum(matched_u.astype(jnp.int32), src,
                                     num_segments=max(n, 1))
        by_dst = jax.ops.segment_sum(matched_v.astype(jnp.int32), dst,
                                     num_segments=max(n, 1))
        rowpos = (row_ptr[:n, None]
                  + jnp.arange(width, dtype=jnp.int32)[None, :]).reshape(-1)
        return supp.at[rowpos].add((by_src + by_dst).reshape(-1),
                                   mode="drop")

    return run


def _build_dynamic_step_executable(shape_key: tuple) -> Callable:
    """One jitted device step applying a padded edge-update batch in place.

    ``shape_key`` is ``(cap, ub, n1, width)`` — the packed-key capacity
    class, padded update rows, n + 1, and the anchor-row width class —
    with a trailing ``"wide"`` marker appended in the wide (int64) key
    mode, so the two key dtypes never share a cache slot.
    The numeric extents are :class:`~repro.graphs.device.ShapePolicy` pow2
    classes, so a session re-compiles only when an extent overflows its
    class (and then exactly once: the classes grow monotonically and never
    shrink). The body is :func:`repro.graphs.device.dynamic_update_step` —
    resolve the batch against the sorted key orderings, tombstone deletes,
    merge inserts, and gather the batch's anchor adjacency rows (pre- and
    post-update) for the delta executables; the key dtype follows the
    ``keys`` argument (the caller wraps wide calls in
    ``edge_key_context``).
    """
    if shape_key and shape_key[-1] == "wide":
        shape_key = shape_key[:-1]
    cap, ub, n1, width = (int(x) for x in shape_key)
    del cap, ub  # fixed by the argument shapes; keyed for cache-stats

    @jax.jit
    def run(keys, rkeys, upd_keys, upd_rkeys, upd_ins, upd_valid):
        return dynamic_update_step(keys, rkeys, upd_keys, upd_rkeys,
                                   upd_ins, upd_valid,
                                   n=n1 - 1, width=width)

    return run


def _resolve_delta_classes(bounds: Sequence[int], n: int, strategy: str,
                           bitmap_bits: Optional[int]) -> list:
    """Resolve the per-width match-mask strategy for a delta executable.

    Same cost model as the edge lane (``resolve_mask_strategy`` over
    id_range = n + 2, covering both in-row sentinels), with the same forced
    ``bitmap_bits`` override semantics.
    """
    id_range = n + 2
    resolved = []
    for w in bounds:
        strat, bits = resolve_mask_strategy(int(w), id_range, strategy)
        if bitmap_bits is not None and strat == "bitmap":
            if bitmap_bits < id_range:
                raise ValueError(
                    f"bitmap_bits={bitmap_bits} cannot cover vertex id "
                    f"range {id_range} (n + 2 sentinel rows)")
            bits = int(bitmap_bits)
        resolved.append((strat, bits))
    return resolved


def _build_delta_executable(strategy: str, bitmap_bits: Optional[int],
                            shape_key: tuple) -> Callable:
    """Weighted triangle deltas for one padded batch of anchor edges.

    ``shape_key`` is ``(ub, n1, *bounds)``: padded update rows, n + 1, and
    the session's width classes — deliberately capacity-independent (the
    inputs are the step's (ub, width) anchor-row blocks, not the key
    arrays), so a capacity-class overflow recompiles only the step. The
    wide (int64) key mode appends a trailing ``"wide"`` marker; the packed
    key dtype itself follows the ``skeys`` argument. The
    executable re-buckets only the anchor
    edges (``prep.delta_update_buckets``), runs the strategy-dispatched
    match mask per class, and for every matched triangle (lo, hi, w) weighs
    the contribution by how many of its three edges sit in the anchor set
    ``skeys`` (a sorted packed-key array padded with ``EDGE_KEY_SENTINEL``):
    a triangle containing k anchor edges is discovered once per anchor
    edge, so weighting each hit 6/k — via the integer table [0, 6, 3, 2] —
    makes the grand total exactly 6 x (#triangles touching the anchor set).
    The caller asserts divisibility by 6 (a cheap drift tripwire) and
    divides. Membership probes use clip-searchsorted-equality; sentinel
    neighbors (w = n from in-row padding) can never equal a real key
    (real keys have hi <= n - 1 mod n1) and padded rows (u = -1) go
    negative, so padding contributes zero even before the match mask
    gates it.
    """
    if shape_key and shape_key[-1] == "wide":
        shape_key = shape_key[:-1]
    ub, n1 = int(shape_key[0]), int(shape_key[1])
    bounds = tuple(int(w) for w in shape_key[2:])
    n = n1 - 1
    resolved = _resolve_delta_classes(bounds, n, strategy, bitmap_bits)

    @jax.jit
    def run(lo_rows, hi_rows, lo_deg, hi_deg, lo, hi, valid, skeys):
        weight = jnp.array([0, 6, 3, 2], jnp.int32)
        kdt = skeys.dtype  # int32 fast path / int64 wide key mode
        nn1 = jnp.asarray(n1, kdt)
        total = jnp.int32(0)
        classes = prep.delta_update_buckets(lo_rows, hi_rows, lo_deg,
                                            hi_deg, lo, hi, valid,
                                            n=n, bounds=bounds)
        for (_, u, v, sb, db), (strat, bits) in zip(classes, resolved):
            matched = intersect_matches(u, v, strategy=strat,
                                        bitmap_bits=bits)
            s = sb[:, None].astype(kdt)
            d = db[:, None].astype(kdt)
            uk = u.astype(kdt)
            e1 = jnp.minimum(s, uk) * nn1 + jnp.maximum(s, uk)
            e2 = jnp.minimum(d, uk) * nn1 + jnp.maximum(d, uk)
            i1 = jnp.clip(jnp.searchsorted(skeys, e1), 0, ub - 1)
            i2 = jnp.clip(jnp.searchsorted(skeys, e2), 0, ub - 1)
            k = (1 + (skeys[i1] == e1).astype(jnp.int32)
                 + (skeys[i2] == e2).astype(jnp.int32))
            total = total + jnp.sum(jnp.where(matched, weight[k], 0),
                                    dtype=jnp.int32)
        return total

    return run


def _build_dist_intersect_executable(strategy: str,
                                     bitmap_bits: Optional[int],
                                     shape_key: tuple, mesh) -> Callable:
    """One degree bucket's sharded intersection count: every shard runs the
    resolved jnp core over its dealt rows, length-gated so padding costs
    nothing, and ONE scalar psum yields the global partial.

    ``shape_key`` is ``(rows_per_shard, width, chunk)``. The chunk loop has
    a *dynamic* trip count ``ceil(valid / chunk)`` — chunks past a shard's
    last real row are never dispatched — and the tail chunk masks rows at
    index ≥ valid out of the sum, so dealt padding contributes zero to the
    count even if its slots hold garbage (the poison regression test relies
    on exactly this, not on sentinel rows happening to be inert).
    """
    rows, width, chunk = (int(x) for x in shape_key[:3])
    axes = tuple(mesh.axis_names)
    spec = PartitionSpec(axes)

    @jax.jit
    def run(u, v, valid):
        def local(u, v, valid):
            u, v, valid = u[0], v[0], valid[0]

            def body(i, acc):
                start = i * chunk
                uu = jax.lax.dynamic_slice_in_dim(u, start, chunk)
                vv = jax.lax.dynamic_slice_in_dim(v, start, chunk)
                counts = intersect_counts(
                    uu, vv, strategy=strategy, backend="jnp",
                    bitmap_bits=bitmap_bits)
                rowid = start + jnp.arange(chunk, dtype=jnp.int32)
                return acc + jnp.sum(
                    jnp.where(rowid < valid, counts, 0), dtype=jnp.int32)

            active = (valid + chunk - 1) // chunk
            acc = jax.lax.fori_loop(0, active, body, jnp.int32(0))
            return jax.lax.psum(acc, axes)

        return shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=PartitionSpec(),
                         check_rep=False)(u, v, valid)

    return run


def _build_dist_matrix_executable(shape_key: tuple, mesh) -> Callable:
    """The sharded masked block-SpGEMM count: each shard reduces its dealt
    tile triples locally, one scalar psum yields the global sum.

    ``shape_key`` is ``(tiles_per_shard, block, block)``. The tile loop's
    trip count is the shard's *real* tile count, so dealt zero-padding
    tiles dispatch no FLOPs at all (tile granularity = exact gating; the
    NaN-poison regression test asserts padded slots are never touched).
    """
    axes = tuple(mesh.axis_names)
    spec = PartitionSpec(axes)

    @jax.jit
    def run(l, u, a, valid):
        def local(l, u, a, valid):
            l, u, a, valid = l[0], u[0], a[0], valid[0]

            def body(i, acc):
                lt = jax.lax.dynamic_index_in_dim(l, i, keepdims=False)
                ut = jax.lax.dynamic_index_in_dim(u, i, keepdims=False)
                at = jax.lax.dynamic_index_in_dim(a, i, keepdims=False)
                prod = jnp.dot(lt, ut,
                               preferred_element_type=jnp.float32)
                return acc + (prod * at).sum(dtype=jnp.float32)

            acc = jax.lax.fori_loop(0, valid, body, jnp.float32(0.0))
            return jax.lax.psum(acc, axes)

        return shard_map(local, mesh=mesh, in_specs=(spec,) * 4,
                         out_specs=PartitionSpec(),
                         check_rep=False)(l, u, a, valid)

    return run


def _build_dist_edge_executable(strategy: str, bitmap_bits: Optional[int],
                                shape_key: tuple, mesh) -> Callable:
    """One bucket's sharded per-edge support: each shard scatters its dealt
    rows' contributions into the full (mk,) slot space and one vector psum
    (communication = the support itself, the lane's output) combines them.
    ``shape_key`` is ``(rows_per_shard, width, mk, n1, *peel_knobs)``;
    ``row_ptr`` is replicated (in_spec ``P()``)."""
    _, width, mk, n1 = (int(x) for x in shape_key[:4])
    body = _edge_support_body(strategy, bitmap_bits, width, mk, n1 - 1)
    axes = tuple(mesh.axis_names)
    spec = PartitionSpec(axes)

    @jax.jit
    def run(u_lists, v_lists, src, dst, row_ptr):
        def local(u, v, s, d, rp):
            supp = body(u[0], v[0], s[0], d[0], rp)
            return jax.lax.psum(supp, axes)

        return shard_map(
            local, mesh=mesh,
            in_specs=(spec, spec, spec, spec, PartitionSpec()),
            out_specs=PartitionSpec(), check_rep=False,
        )(u_lists, v_lists, src, dst, row_ptr)

    return run


def get_executable(algorithm: str, backend: str, interpret: bool,
                   shape_key: tuple, strategy: Optional[str] = None,
                   bitmap_bits: Optional[int] = None, mesh=None) -> Callable:
    """Fetch (or build) the jitted executable for one statically-shaped work
    unit.

    Args:
      algorithm: "intersection" | "subgraph" | "bfs" (all three use the
        intersection executables — the BFS lane's wedge closure is the same
        per-bucket computation over level-oriented rows, so it shares the
        compiled kernels) | "matrix" | "hash" (the TRUST-style per-vertex
        hash-probe stage, shape_key ``(e_pad, width, num_buckets, depth)``)
        | "vertex" (per-vertex triangle counts for
        one filtered bucket — the analysis path ``TriangleCounter`` routes
        through the plan) | "edge" (per-edge support contributions for one
        filtered bucket — the ``TrussPlan`` lane) | "dynamic_step" /
        "delta" (the ``DynamicPlan`` lane: the in-place edge-update step
        and the anchored triangle-delta pass) | "intersection_distributed"
        / "matrix_distributed" / "edge_distributed" (the mesh-planned
        sharded stages: shard_map over a round-robin dealt partition,
        length-gated per shard, one psum; require ``mesh``).
      backend: "jnp" | "pallas" | "ref" (see ``repro.kernels.*.ops``).
      interpret: pallas interpret mode flag (part of the key: interpret and
        compiled kernels are distinct executables).
      shape_key: the work unit's static array shape, e.g. one degree bucket's
        (E, W), a tile schedule's (T, B, B), a vertex stage's (E, W, n), or
        an edge stage's (E, W, mk, n1, max_peel_iters, peel_early_exit) —
        the edge lane folds the plan's peel knobs into its key so equal
        ``CountOptions`` (peel knobs included) share one cached edge
        executable and unequal knobs miss.
      strategy: resolved set-intersection strategy ("broadcast" | "probe" |
        "bitmap") for the intersection lanes, or the resolved match-mask
        strategy (same three names, via ``resolve_mask_strategy``) for the
        edge lane; None for matrix/vertex.
      bitmap_bits: static packed-bitmap capacity when strategy="bitmap",
        else None.
      mesh: jax device mesh — required for (and only consumed by) the
        ``*_distributed`` algorithms. ``mesh_cache_component(mesh)`` is
        appended to the cache key, so equal-mesh plans share per-shard
        executables (zero recompiles steady-state) and a shard-shape change
        misses exactly once.

    Returns:
      A jitted callable reducing the work unit (a scalar count, or an (n,)
      per-vertex vector for "vertex"). Cached process-wide under
      ``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``
      (+ the mesh component when sharded) so plans over same-shaped
      buckets/schedules share the compiled kernel.
    """
    # validate BEFORE touching the cache so bad args never claim a key or
    # skew the hit/miss counters
    if backend not in ("jnp", "pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected 'jnp', 'pallas', or 'ref'")
    if algorithm in ("intersection", "subgraph", "edge",
                     "intersection_distributed", "edge_distributed") \
            and strategy not in STRATEGIES:
        raise ValueError(f"unresolved strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}")
    if algorithm.endswith("_distributed") and mesh is None:
        raise ValueError(
            f"algorithm {algorithm!r} needs a mesh; pass mesh=")
    builders: Dict[str, Callable[[], Callable]] = {
        "intersection": lambda: _build_intersect_executable(
            strategy, backend, interpret, bitmap_bits),
        "subgraph": lambda: _build_intersect_executable(
            strategy, backend, interpret, bitmap_bits),
        "matrix": lambda: _build_matrix_executable(backend, interpret),
        "hash": lambda: _build_hash_executable(backend, interpret),
        "vertex": lambda: _build_vertex_executable(int(shape_key[-1])),
        "edge": lambda: _build_edge_executable(
            strategy, bitmap_bits, tuple(shape_key)),
        "dynamic_step": lambda: _build_dynamic_step_executable(
            tuple(shape_key)),
        "delta": lambda: _build_delta_executable(
            strategy, bitmap_bits, tuple(shape_key)),
        "intersection_distributed": lambda: _build_dist_intersect_executable(
            strategy, bitmap_bits, tuple(shape_key), mesh),
        "matrix_distributed": lambda: _build_dist_matrix_executable(
            tuple(shape_key), mesh),
        "edge_distributed": lambda: _build_dist_edge_executable(
            strategy, bitmap_bits, tuple(shape_key), mesh),
    }
    builder = builders.get(algorithm)
    if builder is None:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    key = (algorithm, strategy, backend, bool(interpret), bitmap_bits,
           tuple(shape_key))
    if mesh is not None:
        key = key + (mesh_cache_component(mesh),)
    return _EXECUTABLE_CACHE.get_or_build(key, builder)


def _build_batch_executable(specs: tuple, backend: str,
                            interpret: bool) -> Callable:
    """One jitted program counting a whole stacked batch of graphs.

    ``specs`` is one ``(strategy, bitmap_bits, (e_pad, width))`` triple per
    bucket; the executable takes the flattened (u, v) pairs — each a
    (B, e_pad, width) stack — and returns the (B,) per-graph totals. Every
    bucket's vmapped intersection and the cross-bucket reduction live in a
    single traced computation: ONE device dispatch per batch.
    """

    @jax.jit
    def run(*arrays):
        total = jnp.zeros(arrays[0].shape[0], jnp.int32)
        for i, (strat, bits, _) in enumerate(specs):
            u, v = arrays[2 * i], arrays[2 * i + 1]

            def one(uu, vv, strat=strat, bits=bits):
                return jnp.sum(intersect_counts(
                    uu, vv, strategy=strat, backend=backend,
                    interpret=interpret, bitmap_bits=bits,
                ))

            total = total + jax.vmap(one)(u, v)
        return total

    return run


def get_batch_executable(specs: tuple, backend: str, interpret: bool,
                         batch: int) -> Callable:
    """Fetch (or build) the vmapped batch executable for one stacked layout.

    Cached in the same process-wide executable cache under
    ``("intersection_batch", None, backend, interpret, None,
    (batch,) + specs)`` — the shape-policy-keyed batch-plan cache: two
    batches whose policy-rounded layouts collide share one compiled program.
    """
    key = ("intersection_batch", None, backend, bool(interpret), None,
           (int(batch),) + tuple(specs))
    return _EXECUTABLE_CACHE.get_or_build(
        key,
        lambda: _build_batch_executable(tuple(specs), backend,
                                        bool(interpret)),
    )


def executable_cache_info() -> dict:
    """``{'size', 'hits', 'misses', 'maxsize', 'evictions'}`` for tests and
    benchmarks. Since PR 8 the cache is a thread-safe bounded LRU (default
    512 entries, override via ``TC_EXEC_CACHE_SIZE`` or
    ``set_cache_limit``), so the snapshot also reports the bound and how
    many cold entries it has dropped."""
    return _EXECUTABLE_CACHE.info()


def clear_executable_cache() -> None:
    _EXECUTABLE_CACHE.clear()


def cache_info() -> dict:
    """``executable_cache_info()`` plus the live ``keys`` tuple (MRU last).

    The introspection handle the serving metrics registry snapshots and
    tests use instead of poking the private cache dict: each key is the
    ``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``
    tuple documented on ``get_executable``.
    """
    return _EXECUTABLE_CACHE.info(include_keys=True)


def clear_caches() -> None:
    """Drop every cached executable and zero the hit/miss/eviction counters
    (the public alias of ``clear_executable_cache``)."""
    clear_executable_cache()


def set_cache_limit(maxsize: int) -> int:
    """Re-bound the process-wide executable cache; returns the old bound.

    Shrinking evicts LRU entries immediately (counted in ``evictions``).
    Live plans keep direct references to their executables, so eviction
    never breaks an existing plan — it only forces a rebuild on the next
    cold ``get_executable`` for that key.
    """
    return _EXECUTABLE_CACHE.set_maxsize(maxsize)


# ---------------------------------------------------------------------------
# TrianglePlan — the device-resident, replayable count
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Stage:
    executable: Callable
    args: Tuple[jnp.ndarray, ...]  # device-resident
    shape_key: tuple
    strategy: Optional[str] = None  # resolved intersection strategy
    bitmap_bits: Optional[int] = None  # packed capacity when strategy="bitmap"
    # (src, dst) edge endpoints, device-resident — filtered intersection
    # stages only; lets the per-vertex analysis path replay the same buffers
    vertex_args: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None

    def run(self):
        """One device dispatch over the resident buffers."""
        return self.executable(*self.args)


@dataclasses.dataclass
class _TiledStage:
    """A bucket too large for the ``max_device_bytes`` budget, streamed
    through ONE cached chunk-shaped executable instead of held resident.

    The bucket's padded arrays stay in host memory; ``run()`` uploads
    ``chunk_rows`` rows at a time (tail chunks padded with the repo-wide
    inert row fills) and accumulates the partial counts on host. Chunk rows
    are a pow2 class ≤ the bucket extent, so every chunk of every
    same-width bucket under the same budget shares a single executable —
    zero steady-state recompiles, cache-stats-asserted in
    ``tests/test_tiled.py`` — and the count is bit-identical to the
    monolithic path (integer partials; the matrix lane's float partials are
    exact integers far below 2^24).
    """

    executable: Callable
    host_args: Tuple[np.ndarray, ...]  # full padded bucket, host-resident
    fills: Tuple[Any, ...]  # tail-chunk fill per host array (inert rows)
    chunk_rows: int
    shape_key: tuple  # FULL bucket shape (meta parity with _Stage)
    chunk_shape_key: tuple  # the executable's shape class
    strategy: Optional[str] = None
    bitmap_bits: Optional[int] = None
    # host (src, dst) for the chunked per-vertex path (filtered stages only)
    vertex_args: Optional[Tuple[np.ndarray, np.ndarray]] = None
    float_acc: bool = False  # matrix lane accumulates float partials
    args: Tuple = ()  # no resident device buffers (block_until_ready no-op)

    @property
    def rows(self) -> int:
        return int(self.host_args[0].shape[0])

    @property
    def num_chunks(self) -> int:
        return -(-self.rows // self.chunk_rows)

    def _iter_chunks(self, arrays, fills):
        """Upload successive (chunk_rows, ...) slices, tail-padded to the
        single chunk shape class."""
        for s in range(0, self.rows, self.chunk_rows):
            out = []
            for a, f in zip(arrays, fills):
                c = a[s:s + self.chunk_rows]
                if c.shape[0] < self.chunk_rows:
                    pad = np.full((self.chunk_rows - c.shape[0],)
                                  + c.shape[1:], f, a.dtype)
                    c = np.concatenate([c, pad], axis=0)
                out.append(jnp.asarray(c))
            yield tuple(out)

    def run(self):
        """Stream every chunk through the cached executable; host-side
        accumulation of the partial counts."""
        total = 0.0 if self.float_acc else 0
        for chunk_args in self._iter_chunks(self.host_args, self.fills):
            r = self.executable(*chunk_args)
            total += float(r) if self.float_acc else int(r)
        return total

    def iter_vertex_chunks(self):
        """Chunked (u, v, src, dst) uploads for the per-vertex path."""
        assert self.vertex_args is not None
        return self._iter_chunks(self.host_args + tuple(self.vertex_args),
                                 self.fills + (0, 0))


@dataclasses.dataclass
class TrianglePlan:
    """A prepared triangle count: device buffers + compiled executables.

    ``count()`` replays the device stage only — no host-side numpy runs after
    construction (tests verify this by poisoning the prep helpers). Build via
    ``plan_triangle_count``.
    """

    algorithm: str
    backend: str
    interpret: bool
    stages: List[_Stage]
    divisor: int  # 6 for the full-variant intersection (each triangle ×6)
    meta: Dict[str, Any]
    prep_seconds: float
    executions: int = 0

    def count(self) -> int:
        """Exact triangle count; pure device replay of the cached stages
        (tiled stages stream their bucket chunk-by-chunk through the same
        cached executables, accumulating partials on host)."""
        if self.algorithm in ("matrix", "matrix_distributed"):
            total_f = 0.0
            for st in self.stages:
                total_f += float(st.run())
            total = int(round(total_f))
        else:
            total = 0
            for st in self.stages:
                total += int(st.run())
        if self.divisor != 1:
            assert total % self.divisor == 0, total
            total //= self.divisor
        self.executions += 1
        return total

    def count_with_stats(self) -> Tuple[int, dict]:
        """Count once and return the plan's prep statistics alongside.

        Returns:
          (count, meta): meta carries statistics gathered at plan time —
          prune fractions, tile schedule sizes, bucket shapes, and on the
          intersection/subgraph lanes ``bucket_strategies``: one
          ``(width, strategy)`` pair per degree bucket as resolved by the
          ``strategy="auto"`` cost model (or the per-plan override).
        """
        c = self.count()
        stats = dict(self.meta)
        if self.algorithm == "subgraph":
            stats["num_embeddings"] = 6 * c
        return c, stats

    def triangles_per_vertex(self) -> np.ndarray:
        """Per-vertex triangle counts, replayed through this plan's cached
        device buffers (the analysis path ``repro.core.api.TriangleCounter``
        routes here instead of the host-side enumeration in ``listing.py``).

        Supported on plans whose stages carry edge endpoints — the filtered
        intersection lane, the BFS lane (level-oriented stages carry the
        same (src, dst) layout), and the subgraph lane (whose counts on the
        pruned graph scatter back through ``meta["vertex_map"]``; peeled
        vertices are in no triangle by construction).

        Returns:
          (n,) int64 numpy array, t[v] = number of triangles containing v.

        Raises:
          NotImplementedError: matrix lane or the full intersection variant
            (no per-edge endpoints to attribute matches to); callers fall
            back to a filtered-intersection sidecar plan.
        """
        if self.algorithm not in ("intersection", "subgraph", "bfs") \
                or self.divisor != 1 \
                or any(st.vertex_args is None for st in self.stages):
            raise NotImplementedError(
                f"per-vertex counts need filtered-intersection stages; "
                f"algorithm={self.algorithm!r} divisor={self.divisor} does "
                f"not carry them"
            )
        n_local = int(self.meta.get("vertex_n", self.meta["n"]))
        total = np.zeros(n_local, dtype=np.int64)
        for st in self.stages:
            if isinstance(st, _TiledStage):
                e, w = st.chunk_shape_key
                fn = get_executable("vertex", "jnp", False, (e, w, n_local))
                for chunk_args in st.iter_vertex_chunks():
                    total += np.asarray(fn(*chunk_args), dtype=np.int64)
                continue
            e, w = st.shape_key
            fn = get_executable("vertex", "jnp", False, (e, w, n_local))
            total += np.asarray(fn(*st.args, *st.vertex_args), dtype=np.int64)
        vertex_map = self.meta.get("vertex_map")
        if vertex_map is not None:  # subgraph lane: pruned ids -> original
            out = np.zeros(int(self.meta["n"]), dtype=np.int64)
            out[np.asarray(vertex_map)] = total
            return out
        return total

    def block_until_ready(self) -> "TrianglePlan":
        """Force all device buffers resident (useful before timing counts)."""
        for st in self.stages:
            for a in st.args:
                a.block_until_ready()
        return self

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def shape_keys(self) -> List[tuple]:
        return [st.shape_key for st in self.stages]


def _resolve_bucket_strategy(width: int, id_range: int, strategy: str,
                             bitmap_bits: Optional[int]):
    """Resolve one bucket's (strategy, bitmap_bits), honoring a forced
    ``bitmap_bits`` override (which must cover the id range)."""
    strat, bits = resolve_strategy(width, id_range, strategy=strategy)
    if bitmap_bits is not None and strat == "bitmap":
        if bitmap_bits < id_range:
            raise ValueError(
                f"bitmap_bits={bitmap_bits} cannot represent id range "
                f"{id_range} (n + 2 sentinel ids); ids past the capacity "
                f"would silently never match"
            )
        bits = int(bitmap_bits)
    return strat, bits


def _buckets_for_plan(g, variant: str, widths: Sequence[int],
                      prep_backend: str, policy: Optional[ShapePolicy],
                      ) -> List[DeviceBucket]:
    """Run the prep stage on the requested backend; either way the result is
    device-resident ``DeviceBucket``s (the host path uploads its arrays)."""
    if prep_backend == "device":
        return prep.prepare_intersection_buckets_device(
            g, variant=variant, widths=widths, policy=policy,
        )
    host = prep.prepare_intersection_buckets_host(g, variant=variant,
                                                  widths=widths)
    return [
        DeviceBucket(
            width=b["width"], edges=int(b["u_lists"].shape[0]),
            u_lists=jnp.asarray(b["u_lists"]), v_lists=jnp.asarray(b["v_lists"]),
            src=jnp.asarray(b["src"]), dst=jnp.asarray(b["dst"]),
        )
        for b in host
    ]


def _bucket_nbytes(e_pad: int, width: int) -> int:
    """Device bytes one resident intersection bucket costs: the (e, w)
    int32 u/v neighbor-list pair plus the (e,) int32 src/dst endpoints."""
    return int(e_pad) * (8 * int(width) + 8)


def _tile_chunk_rows(rows: int, row_bytes: int,
                     max_device_bytes: int) -> int:
    """Largest pow2 chunk row count whose device footprint fits the budget
    (floored at 1 — graceful degradation: a budget below one row's cost
    still streams row-by-row rather than failing)."""
    c = 1
    while c * 2 <= rows and (c * 2) * row_bytes <= max_device_bytes:
        c *= 2
    return c


def _plan_intersection(g, variant: str, backend: str, interpret: bool,
                       widths: Sequence[int], strategy: str = "auto",
                       bitmap_bits: Optional[int] = None,
                       prep_backend: str = "device",
                       shape_policy: Optional[ShapePolicy] = None,
                       max_device_bytes: Optional[int] = None,
                       ) -> Tuple[List[_Stage], int, dict]:
    buckets = _buckets_for_plan(g, variant, widths, prep_backend, shape_policy)
    # id range covers real vertex ids [0, n) plus the in-row padding
    # sentinels n (u rows) and n+1 (v rows); whole-row padding (-1/-2) is
    # negative and never matches in any core
    id_range = g.n + 2
    stages = []
    tiled_buckets = []
    for b in buckets:
        shape_key = b.shape
        strat, bits = _resolve_bucket_strategy(b.width, id_range, strategy,
                                               bitmap_bits)
        e_pad, width = int(shape_key[0]), int(shape_key[1])
        if max_device_bytes is not None \
                and _bucket_nbytes(e_pad, width) > max_device_bytes:
            # stream this bucket: host keeps the padded arrays, count()
            # uploads pow2-row chunks through one chunk-shaped executable
            chunk = _tile_chunk_rows(e_pad, _bucket_nbytes(1, width),
                                     max_device_bytes)
            chunk_key = (chunk, width)
            fn = get_executable("intersection", backend, interpret,
                                chunk_key, strategy=strat, bitmap_bits=bits)
            vertex_args = None
            if variant == "filtered":
                vertex_args = (np.asarray(b.src), np.asarray(b.dst))
            stages.append(_TiledStage(
                executable=fn,
                host_args=(np.asarray(b.u_lists), np.asarray(b.v_lists)),
                fills=(-1, -2),  # whole-row padding: zero matches everywhere
                chunk_rows=chunk,
                shape_key=shape_key,
                chunk_shape_key=chunk_key,
                strategy=strat,
                bitmap_bits=bits,
                vertex_args=vertex_args,
            ))
            tiled_buckets.append(dict(shape=shape_key, chunk_rows=chunk,
                                      num_chunks=stages[-1].num_chunks))
            continue
        fn = get_executable("intersection", backend, interpret, shape_key,
                            strategy=strat, bitmap_bits=bits)
        vertex_args = None
        if variant == "filtered":
            vertex_args = (b.src, b.dst)
        stages.append(_Stage(
            executable=fn,
            args=(b.u_lists, b.v_lists),
            shape_key=shape_key,
            strategy=strat,
            bitmap_bits=bits,
            vertex_args=vertex_args,
        ))
    policy = shape_policy if shape_policy is not None else DEFAULT_SHAPE_POLICY
    meta = dict(
        variant=variant,
        widths=tuple(widths),
        strategy=strategy,
        prep_backend=prep_backend,
        shape_policy=policy.key() if prep_backend == "device" else None,
        bucket_shapes=[s.shape_key for s in stages],
        bucket_strategies=[(s.shape_key[1], s.strategy) for s in stages],
        bucket_edges=[b.edges for b in buckets],
        edges=int(sum(b.edges for b in buckets)),
        max_device_bytes=max_device_bytes,
        tiled_buckets=tiled_buckets,
        num_chunks=int(sum(t["num_chunks"] for t in tiled_buckets)),
    )
    return stages, (6 if variant == "full" else 1), meta


def _plan_matrix(g: Graph, block, permute: bool, backend: str,
                 interpret: bool,
                 max_device_bytes: Optional[int] = None,
                 ) -> Tuple[List[_Stage], int, dict]:
    if block == "auto":
        block = choose_block(g)
    l_sel, u_sel, a_sel, stats = build_tile_schedule(
        g, block=block, permute=permute
    )
    stages = []
    tiled_buckets = []
    if l_sel.shape[0]:
        shape_key = tuple(l_sel.shape)
        t, bsz = int(shape_key[0]), int(shape_key[1])
        # three (T, B, B) float32 stacks resident at once
        tile_bytes = 3 * bsz * bsz * 4
        if max_device_bytes is not None \
                and t * tile_bytes > max_device_bytes:
            chunk = _tile_chunk_rows(t, tile_bytes, max_device_bytes)
            chunk_key = (chunk,) + shape_key[1:]
            fn = get_executable("matrix", backend, interpret, chunk_key)
            st = _TiledStage(
                executable=fn,
                host_args=(np.asarray(l_sel), np.asarray(u_sel),
                           np.asarray(a_sel)),
                fills=(0.0, 0.0, 0.0),  # all-zero tiles contribute 0.0
                chunk_rows=chunk,
                shape_key=shape_key,
                chunk_shape_key=chunk_key,
                float_acc=True,
            )
            stages.append(st)
            tiled_buckets.append(dict(shape=shape_key, chunk_rows=chunk,
                                      num_chunks=st.num_chunks))
        else:
            fn = get_executable("matrix", backend, interpret, shape_key)
            stages.append(_Stage(
                executable=fn,
                args=(jnp.asarray(l_sel), jnp.asarray(u_sel),
                      jnp.asarray(a_sel)),
                shape_key=shape_key,
            ))
    meta = dict(permute=permute, max_device_bytes=max_device_bytes,
                tiled_buckets=tiled_buckets,
                num_chunks=int(sum(t["num_chunks"] for t in tiled_buckets)),
                **stats)
    return stages, 1, meta


def _plan_intersection_distributed(
        g, mesh, variant: str, backend: str, interpret: bool,
        widths: Sequence[int], strategy: str = "auto",
        bitmap_bits: Optional[int] = None, prep_backend: str = "device",
        shape_policy: Optional[ShapePolicy] = None,
) -> Tuple[List[_Stage], int, dict]:
    """The intersection lane over a ``ShardedDeviceCSR``: device prep once,
    each degree bucket dealt round-robin across the mesh's shards, one
    cached length-gated executable + one scalar psum per bucket. The
    intersection cores always run their jnp formulation under shard_map
    (exactly as the pre-engine one-shot lane did); ``backend`` is recorded
    but does not change the sharded program."""
    policy = shape_policy if shape_policy is not None else DEFAULT_SHAPE_POLICY
    sharded = ShardedDeviceCSR.from_graph(
        g, mesh, variant=variant, widths=widths, policy=policy,
        prep_backend=prep_backend,
    )
    id_range = g.n + 2  # real ids + the in-row sentinels n / n+1
    stages = []
    for b in sharded.buckets:
        strat, bits = _resolve_bucket_strategy(b.width, id_range, strategy,
                                               bitmap_bits)
        shape_key = b.shape + (b.chunk,)
        fn = get_executable("intersection_distributed", "jnp", False,
                            shape_key, strategy=strat, bitmap_bits=bits,
                            mesh=mesh)
        stages.append(_Stage(
            executable=fn,
            args=(b.u_lists, b.v_lists, b.valid),
            shape_key=shape_key,
            strategy=strat,
            bitmap_bits=bits,
        ))
    meta = dict(
        variant=variant,
        widths=tuple(widths),
        strategy=strategy,
        prep_backend=prep_backend,
        shape_policy=policy.key(),
        core_backend="jnp",
        bucket_shapes=[s.shape_key for s in stages],
        bucket_strategies=[(s.shape_key[1], s.strategy) for s in stages],
        bucket_edges=[b.edges for b in sharded.buckets],
        edges=sharded.edges,
        mesh_axes=tuple(str(a) for a in mesh.axis_names),
        mesh_shape=tuple(int(s) for s in mesh.devices.shape),
        num_shards=sharded.num_shards,
        rows_per_shard=[b.rows_per_shard for b in sharded.buckets],
        shard_valid=[b.shard_rows for b in sharded.buckets],
        shard_work=sharded.shard_work(),
    )
    return stages, (6 if variant == "full" else 1), meta


def _plan_matrix_distributed(
        g: Graph, mesh, block, permute: bool, backend: str, interpret: bool,
) -> Tuple[List[_Stage], int, dict]:
    """The matrix lane over the mesh: the host-built heavy-first tile
    schedule is dealt round-robin across shards (equal dense/sparse mix per
    shard by construction), zero-padded to the per-shard extent, and the
    cached executable's tile loop runs exactly each shard's real tile count
    — dealt padding dispatches no FLOPs."""
    if block == "auto":
        block = choose_block(g)
    l_sel, u_sel, a_sel, stats = build_tile_schedule(
        g, block=block, permute=permute
    )
    ndev = int(np.prod(mesh.devices.shape))
    axes = tuple(mesh.axis_names)
    row_sharding = NamedSharding(mesh, PartitionSpec(axes))
    stages = []
    t = int(l_sel.shape[0])
    tiles_ps = -(-t // ndev) if t else 0
    valid_h = shard_valid_counts(t, ndev)
    if t:
        l_d, u_d, a_d = (
            jax.device_put(
                deal_across_shards(jnp.asarray(x), ndev, tiles_ps, fill=0),
                row_sharding)
            for x in (l_sel, u_sel, a_sel)
        )
        valid = jax.device_put(jnp.asarray(valid_h), row_sharding)
        shape_key = (tiles_ps,) + tuple(l_sel.shape[1:])
        fn = get_executable("matrix_distributed", "jnp", False, shape_key,
                            mesh=mesh)
        stages.append(_Stage(
            executable=fn,
            args=(l_d, u_d, a_d, valid),
            shape_key=shape_key,
        ))
    meta = dict(
        permute=permute,
        **stats,
        mesh_axes=axes,
        mesh_shape=tuple(int(s) for s in mesh.devices.shape),
        num_shards=ndev,
        tiles_per_shard=tiles_ps,
        shard_valid=[tuple(int(x) for x in valid_h)],
        shard_work=tuple(int(x) for x in valid_h),
    )
    return stages, 1, meta


def _plan_subgraph(g: Graph, backend: str, interpret: bool,
                   widths: Sequence[int], strategy: str = "auto",
                   bitmap_bits: Optional[int] = None,
                   prep_backend: str = "device",
                   shape_policy: Optional[ShapePolicy] = None,
                   max_device_bytes: Optional[int] = None,
                   ) -> Tuple[List[_Stage], int, dict]:
    if prep_backend == "device":
        # FILTER + RECONSTRUCT on device: the induced graph keeps original
        # vertex ids (dead vertices just lose their rows), so stage counts
        # scatter directly into original-id space — no vertex_map needed
        policy = shape_policy if shape_policy is not None \
            else DEFAULT_SHAPE_POLICY
        dg = DeviceGraph.from_graph(g, policy)
        alive = prep.peel_to_two_core_device(dg)
        sub_dg = prep.induced_device_graph(dg, alive)
        alive_count = int(jnp.sum(alive))
        stages, _, inner = _plan_intersection(
            sub_dg, variant="filtered", backend=backend, interpret=interpret,
            widths=widths, strategy=strategy, bitmap_bits=bitmap_bits,
            prep_backend="device", shape_policy=policy,
            max_device_bytes=max_device_bytes,
        )
        # the sub-plan's id range is the parent's (ids are preserved)
        meta = dict(
            vertices_pruned=int(g.n - alive_count),
            prune_fraction=float(1.0 - alive_count / max(g.n, 1)),
            edges_after=sub_dg.m_undirected,
            edges_before=g.m_undirected,
            vertex_n=g.n,
            **inner,
        )
        return stages, 1, meta

    alive = peel_to_two_core(g)
    sub, old_ids = induced_subgraph(g, alive)
    # join on the pruned graph; forward-filtered intersection counts each
    # triangle once (embeddings = 6 × that)
    stages, _, inner = _plan_intersection(
        sub, variant="filtered", backend=backend, interpret=interpret,
        widths=widths, strategy=strategy, bitmap_bits=bitmap_bits,
        prep_backend="host", max_device_bytes=max_device_bytes,
    )
    # subgraph stages share the intersection executables by construction
    meta = dict(
        vertices_pruned=int(g.n - alive.sum()),
        prune_fraction=float(1.0 - alive.sum() / max(g.n, 1)),
        edges_after=sub.m_undirected,
        edges_before=g.m_undirected,
        # per-vertex analysis: stage counts are on the pruned graph's ids;
        # scatter back through old_ids (peeled vertices hold no triangles)
        vertex_n=sub.n,
        vertex_map=np.asarray(old_ids),
        **inner,
    )
    return stages, 1, meta


def _plan_hash(g, backend: str, interpret: bool, widths: Sequence[int],
               prep_backend: str = "device",
               shape_policy: Optional[ShapePolicy] = None,
               ) -> Tuple[List[_Stage], int, dict]:
    """The TRUST-style vertex-centric hashing lane (arXiv:2103.08053).

    Prep reuses the filtered degree-class buckets (the candidate rows are
    exactly the intersection lane's ``v_lists`` = N⁺(dst)), plus one extra
    plan-wide structure: an (n, B, D) per-vertex hash table over the
    oriented neighbor rows (``repro.kernels.hash_tc``). The count stage
    probes each bucket's candidates against ``table[src]`` — each forward
    edge (u, v) contributes |N⁺(v) ∩ N⁺(u)|, so every triangle is counted
    exactly once at its degree-rank-minimum edge, same invariant as the
    filtered intersection lane. One extra scalar sync at plan time measures
    the maximum bucket chain length; B and D are pow2-rounded so the table
    shape is a deterministic function of the graph's shape class.
    """
    buckets = _buckets_for_plan(g, "filtered", widths, prep_backend,
                                shape_policy)
    policy = shape_policy if shape_policy is not None else DEFAULT_SHAPE_POLICY
    stages: List[_Stage] = []
    meta = dict(
        variant="filtered",
        widths=tuple(widths),
        prep_backend=prep_backend,
        shape_policy=policy.key() if prep_backend == "device" else None,
    )
    if buckets:
        table_width = max(b.width for b in buckets)
        num_buckets = hash_num_buckets(table_width)
        if prep_backend == "device":
            dg = DeviceGraph.from_graph(g, policy)
            nbrs = dg.padded_neighbors(table_width, oriented=True)
        else:
            fwd = orient_forward(g)
            nbrs = jnp.asarray(
                csr_to_padded_neighbors(fwd, pad_to=table_width))
        # one scalar sync: the real max chain length, rounded to a pow2 class
        depth = next_pow2(max(1, int(hash_table_depth(
            nbrs, jnp.int32(num_buckets)))))
        table = build_hash_table(nbrs, num_buckets=num_buckets, depth=depth)
        for b in buckets:
            shape_key = (b.e_pad, b.width, num_buckets, depth)
            fn = get_executable("hash", backend, interpret, shape_key)
            stages.append(_Stage(
                executable=fn,
                args=(b.v_lists, b.src, table),
                shape_key=shape_key,
            ))
        meta.update(
            hash_num_buckets=num_buckets,
            hash_depth=depth,
            table_width=table_width,
        )
    meta.update(
        bucket_shapes=[s.shape_key for s in stages],
        bucket_edges=[b.edges for b in buckets],
        edges=int(sum(b.edges for b in buckets)),
    )
    return stages, 1, meta


def _plan_bfs(g: Graph, backend: str, interpret: bool,
              widths: Sequence[int], strategy: str = "auto",
              bitmap_bits: Optional[int] = None,
              shape_policy: Optional[ShapePolicy] = None,
              ) -> Tuple[List[_Stage], int, dict]:
    """The BFS-based lane (Fast BFS-Based Triangle Counting, arXiv:1909.02127).

    A level-ordered traversal replaces the degree rank: BFS levels come from
    the jitted ``graphs.device.bfs_levels`` fixpoint over the ``DeviceCSR``
    (one (n,) sync at plan time), then every edge is oriented toward its
    larger ``(level, id)`` endpoint — a total order, so each triangle closes
    exactly once at its rank-minimum wedge. The count stage is forward-edge
    wedge closure |N_f(u) ∩ N_f(v)| over level-oriented degree-class
    buckets, which is byte-for-byte the intersection lane's computation —
    the stages bind the *same cached intersection executables* (shared
    process-wide), only the oriented rows differ. No packed pair keys ⇒ no
    n ≲ 46k bound.
    """
    policy = shape_policy if shape_policy is not None else DEFAULT_SHAPE_POLICY
    meta = dict(
        variant="bfs-forward",
        widths=tuple(widths),
        strategy=strategy,
        shape_policy=policy.key(),
    )
    if g.n == 0 or g.m_undirected == 0:
        meta.update(bucket_shapes=[], bucket_strategies=[], bucket_edges=[],
                    edges=0, levels_max=0, bfs_sources=int(g.n))
        return [], 1, meta

    dg = DeviceGraph.from_graph(g, policy)
    lvl = np.asarray(bfs_levels(dg))  # one (n,) sync at plan time
    src_all, dst_all = g.edge_endpoints()
    keep = (lvl[src_all] < lvl[dst_all]) | (
        (lvl[src_all] == lvl[dst_all]) & (src_all < dst_all))
    fsrc = src_all[keep].astype(np.int32)
    fdst = dst_all[keep].astype(np.int32)
    counts = np.bincount(fsrc, minlength=g.n)
    outdeg = counts.astype(np.int32)
    row_ptr = np.zeros(g.n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    # rows stay sorted by dst id because the parent CSR rows were
    fg = Graph(n=g.n, row_ptr=row_ptr, col_idx=fdst, name=g.name + "+bfs")

    id_range = g.n + 2
    stages: List[_Stage] = []
    bucket_edges: List[int] = []
    for b in bucket_edges_by_degree(fsrc, fdst, outdeg, widths):
        w = int(b["width"])
        bs, bd = b["src"], b["dst"]
        nbrs = csr_to_padded_neighbors(fg, pad_to=w)  # in-row sentinel n
        u_rows = nbrs[bs]
        v_rows = np.where(nbrs[bd] == g.n, g.n + 1, nbrs[bd])
        e = int(bs.shape[0])
        e_pad = policy.round_edges(e)
        pad = e_pad - e
        if pad:
            u_rows = np.vstack([u_rows, np.full((pad, w), -1, np.int32)])
            v_rows = np.vstack([v_rows, np.full((pad, w), -2, np.int32)])
            bs = np.concatenate([bs, np.zeros(pad, np.int32)])
            bd = np.concatenate([bd, np.zeros(pad, np.int32)])
        shape_key = (e_pad, w)
        strat, bits = _resolve_bucket_strategy(w, id_range, strategy,
                                               bitmap_bits)
        fn = get_executable("intersection", backend, interpret, shape_key,
                            strategy=strat, bitmap_bits=bits)
        stages.append(_Stage(
            executable=fn,
            args=(jnp.asarray(u_rows, dtype=jnp.int32),
                  jnp.asarray(v_rows, dtype=jnp.int32)),
            shape_key=shape_key,
            strategy=strat,
            bitmap_bits=bits,
            vertex_args=(jnp.asarray(bs, dtype=jnp.int32),
                         jnp.asarray(bd, dtype=jnp.int32)),
        ))
        bucket_edges.append(e)
    meta.update(
        bucket_shapes=[s.shape_key for s in stages],
        bucket_strategies=[(s.shape_key[1], s.strategy) for s in stages],
        bucket_edges=bucket_edges,
        edges=int(fsrc.shape[0]),
        levels_max=int(lvl.max(initial=0)),
        bfs_sources=int((lvl == 0).sum()),
    )
    return stages, 1, meta


def plan_triangle_count(
    g: Graph,
    algorithm: str = "intersection",
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
    block="auto",
    permute: bool = True,
    bitmap_bits: Optional[int] = None,
    prep_backend: str = "device",
    shape_policy: Optional[ShapePolicy] = None,
    max_device_bytes: Optional[int] = None,
    mesh=None,
) -> TrianglePlan:
    """Run the host stage once and return a device-resident ``TrianglePlan``.

    Args:
      g: the input ``Graph`` (undirected simple CSR).
      algorithm: "intersection" | "matrix" | "subgraph" | "hash" (the
        TRUST-style per-vertex hashing lane) | "bfs" (level-ordered
        wedge closure) | "intersection_distributed" /
        "matrix_distributed" (the mesh-planned sharded lanes: prep once,
        degree buckets / heavy-first tiles dealt round-robin across the
        mesh's shards, per-shard executables cached under a mesh-extended
        key, one scalar psum per stage).
      backend: "jnp" | "pallas" | "ref" per-kernel execution path.
      interpret: pallas interpret mode (True runs kernel bodies on CPU);
        None (default) resolves to ``repro.core.options.DEFAULT_INTERPRET``
        (the ``TC_INTERPRET`` env var, unset ⇒ True).
      variant: intersection lane only — "filtered" (forward algorithm) or
        "full" (every directed edge, each triangle found 6×).
      widths: degree-class bucket widths for the intersection/subgraph lanes.
      strategy: intersection/subgraph lanes only — per-bucket set-intersection
        core: "auto" (default; the documented ``choose_strategy`` cost model
        picks bitmap/probe/broadcast per bucket) or a forced "broadcast" |
        "probe" | "bitmap" override applied to every bucket.
      block: matrix lane tile size, or "auto" (``choose_block``).
      permute: matrix lane degree permutation toggle.
      bitmap_bits: optional forced packed capacity for bitmap-strategy
        buckets (must cover the graph's id range ``n + 2``); None sizes it
        via ``resolve_strategy``.
      prep_backend: intersection/subgraph lanes — "device" (default) runs
        the prep stage as the jitted pipeline in ``repro.core.prep``;
        "host" runs the numpy parity path.
      shape_policy: the ``ShapePolicy`` rounding device-prep extents into
        static shape classes; None means ``DEFAULT_SHAPE_POLICY``.
      max_device_bytes: intersection/subgraph/matrix lanes — optional
        per-bucket device-bytes budget. Buckets (or the matrix tile stack)
        whose resident arrays would exceed it are kept host-side and
        streamed through one cached chunk-shaped executable at ``count()``
        time (pow2 chunk rows ⇒ monotone shape classes, zero steady-state
        recompiles; counts bit-identical to monolithic). None (default)
        plans everything resident. Distributed lanes ignore it — the mesh
        deal already partitions the working set.
      mesh: jax device mesh — consumed by the ``*_distributed`` lanes only
        (None there defaults to a 1-D mesh over every visible device,
        matching the historical one-shot functions); single-host lanes
        ignore it.

    Returns:
      A ``TrianglePlan`` whose ``count()`` replays the device stage only.
      The per-algorithm keyword arguments match ``CountOptions``; the
      facade (``repro.core.api.TriangleCounter``) and the deprecated
      one-shot ``triangle_count_*`` shims both route here.
    """
    interpret = resolve_interpret(interpret)
    t0 = time.perf_counter()
    if algorithm == "intersection":
        stages, divisor, meta = _plan_intersection(
            g, variant, backend, interpret, widths, strategy, bitmap_bits,
            prep_backend, shape_policy, max_device_bytes,
        )
    elif algorithm == "matrix":
        stages, divisor, meta = _plan_matrix(g, block, permute, backend,
                                             interpret, max_device_bytes)
    elif algorithm == "subgraph":
        stages, divisor, meta = _plan_subgraph(g, backend, interpret, widths,
                                               strategy, bitmap_bits,
                                               prep_backend, shape_policy,
                                               max_device_bytes)
    elif algorithm == "hash":
        stages, divisor, meta = _plan_hash(g, backend, interpret, widths,
                                           prep_backend, shape_policy)
    elif algorithm == "bfs":
        stages, divisor, meta = _plan_bfs(g, backend, interpret, widths,
                                          strategy, bitmap_bits, shape_policy)
    elif algorithm in DISTRIBUTED_ALGORITHMS:
        if mesh is None:
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((jax.device_count(),), ("data",))
        if algorithm == "intersection_distributed":
            stages, divisor, meta = _plan_intersection_distributed(
                g, mesh, variant, backend, interpret, widths, strategy,
                bitmap_bits, prep_backend, shape_policy,
            )
        else:
            stages, divisor, meta = _plan_matrix_distributed(
                g, mesh, block, permute, backend, interpret,
            )
        meta["mesh"] = mesh_cache_component(mesh)
    else:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; expected one of "
            f"{ALGORITHMS + DISTRIBUTED_ALGORITHMS}"
        )
    meta.setdefault("graph", g.name)
    meta["n"], meta["m"] = g.n, g.m_undirected
    prep_seconds = time.perf_counter() - t0
    return TrianglePlan(
        algorithm=algorithm,
        backend=backend,
        interpret=interpret,
        stages=stages,
        divisor=divisor,
        meta=meta,
        prep_seconds=prep_seconds,
    )


def plan_hash_count(
    g: Graph,
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    prep_backend: str = "device",
    shape_policy: Optional[ShapePolicy] = None,
) -> TrianglePlan:
    """Plan the TRUST-style vertex-centric hashing lane (see ``_plan_hash``).

    Args mirror ``plan_triangle_count``'s shared subset; the lane has no
    ``strategy`` knob — its count core is the hash probe, not the sorted
    merge. Returns a ``TrianglePlan`` with ``algorithm="hash"``.
    """
    return plan_triangle_count(
        g, "hash", backend=backend, interpret=interpret, widths=widths,
        prep_backend=prep_backend, shape_policy=shape_policy,
    )


def plan_bfs_count(
    g: Graph,
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
    bitmap_bits: Optional[int] = None,
    shape_policy: Optional[ShapePolicy] = None,
) -> TrianglePlan:
    """Plan the BFS-based lane (see ``_plan_bfs``).

    Args mirror ``plan_triangle_count``'s shared subset; ``strategy`` /
    ``bitmap_bits`` select the per-bucket intersection core exactly as on
    the intersection lane (the executables are shared). Returns a
    ``TrianglePlan`` with ``algorithm="bfs"``.
    """
    return plan_triangle_count(
        g, "bfs", backend=backend, interpret=interpret, widths=widths,
        strategy=strategy, bitmap_bits=bitmap_bits, shape_policy=shape_policy,
    )


def _hash_planner(g: Graph, options, *, mesh=None) -> TrianglePlan:
    """Registry planner: CountOptions → hashing-lane TrianglePlan."""
    return plan_hash_count(g, **options.plan_kwargs("hash"))


register_algorithm("hash", _hash_planner)


def _bfs_planner(g: Graph, options, *, mesh=None) -> TrianglePlan:
    """Registry planner: CountOptions → BFS-lane TrianglePlan."""
    return plan_bfs_count(g, **options.plan_kwargs("bfs"))


register_algorithm("bfs", _bfs_planner)


# ---------------------------------------------------------------------------
# TrussPlan — the edge lane: per-edge support + the device k-truss peel
# ---------------------------------------------------------------------------

def _decode_edge_keys(keys: np.ndarray, n1: int):
    """Packed ``lo * n1 + hi`` keys → ((lo, hi) int32 arrays), the single
    place the key encoding is inverted (host side)."""
    keys = np.asarray(keys, dtype=np.int64)
    return (keys // n1).astype(np.int32), (keys % n1).astype(np.int32)


@dataclasses.dataclass
class _EdgeStage:
    executable: Callable
    args: Tuple[jnp.ndarray, ...]  # (u_lists, v_lists, src, dst, row_ptr)
    shape_key: tuple
    strategy: str  # resolved match-mask strategy (broadcast | probe | bitmap)


def _edge_stages(g, *, widths: Sequence[int], strategy: str,
                 bitmap_bits: Optional[int], prep_backend: str,
                 policy: ShapePolicy, peel_key: tuple, mesh=None,
                 key_mode: str = "auto"):
    """Build one graph's edge-support stages: prep the filtered buckets (on
    the requested backend), materialize the slot→key addressing structure
    (sorted keys + permutation + forward row_ptr), and bind each bucket to
    its cached edge executable.

    Returns (stages, edge_keys, perm, m_edges, meta) — ``edge_keys`` is the
    (mk,) sorted device array whose leading ``m_edges`` slots are the real
    edges and ``perm`` reorders slot-indexed support into key order; the
    k-truss peel calls this once per round on the re-oriented survivor
    graph.

    With ``mesh`` set, each bucket's rows are dealt round-robin across the
    mesh's shards (``deal_across_shards``; ``row_ptr`` replicated) and the
    stages bind to the cached "edge_distributed" executables — every shard
    scatters its rows into the full (mk,) slot space and one vector psum
    per bucket combines the partial supports.
    """
    n = g.n
    mode = prep.check_edge_key_range(n, key_mode)
    buckets = _buckets_for_plan(g, "filtered", widths, prep_backend, policy)
    if prep_backend == "device":
        keys, perm, row_ptr, m_edges = prep.forward_edge_keys_device(
            g, policy=policy, key_mode=mode)
    else:
        keys_h, perm_h, row_ptr_h, m_edges = prep.forward_edge_keys_host(
            g, mode)
        with edge_key_context(mode):
            keys = jnp.asarray(keys_h, dtype=jnp.dtype(edge_key_dtype(mode)))
        perm = jnp.asarray(perm_h, dtype=jnp.int32)
        row_ptr = jnp.asarray(row_ptr_h, dtype=jnp.int32)
    mk, n1 = int(keys.shape[0]), n + 1
    id_range = n + 2  # real ids + the in-row sentinels n (u) and n+1 (v)
    if mesh is not None:
        ndev = int(np.prod(mesh.devices.shape))
        row_sharding = NamedSharding(mesh, PartitionSpec(
            tuple(mesh.axis_names)))
        row_ptr = jax.device_put(row_ptr,
                                 NamedSharding(mesh, PartitionSpec()))
    stages = []
    for b in buckets:
        # mask-specific cost model: the probe mask pays two searchsorted
        # passes, so bitmap wins out to ~4·W packed bits (resolve_mask_
        # strategy), not just the counting lane's id_range ≤ packed_bits(W)
        strat, bits = resolve_mask_strategy(b.width, id_range, strategy)
        if bitmap_bits is not None and strat == "bitmap":
            if bitmap_bits < id_range:
                raise ValueError(
                    f"bitmap_bits={bitmap_bits} cannot represent id range "
                    f"{id_range} (n + 2 sentinel ids); ids past the "
                    f"capacity would silently never match"
                )
            bits = int(bitmap_bits)
        if mesh is None:
            shape_key = b.shape + (mk, n1) + tuple(peel_key)
            fn = get_executable("edge", "jnp", False, shape_key,
                                strategy=strat, bitmap_bits=bits)
            args = (b.u_lists, b.v_lists, b.src, b.dst, row_ptr)
        else:
            rows = policy.round_edges(-(-b.edges // ndev))
            u = jax.device_put(
                deal_across_shards(b.u_lists, ndev, rows, fill=-1),
                row_sharding)
            v = jax.device_put(
                deal_across_shards(b.v_lists, ndev, rows, fill=-2),
                row_sharding)
            sb = jax.device_put(
                deal_across_shards(b.src, ndev, rows, fill=0), row_sharding)
            db = jax.device_put(
                deal_across_shards(b.dst, ndev, rows, fill=0), row_sharding)
            shape_key = (rows, b.width, mk, n1) + tuple(peel_key)
            fn = get_executable("edge_distributed", "jnp", False, shape_key,
                                strategy=strat, bitmap_bits=bits, mesh=mesh)
            args = (u, v, sb, db, row_ptr)
        stages.append(_EdgeStage(
            executable=fn,
            args=args,
            shape_key=shape_key,
            strategy=strat,
        ))
    meta = dict(
        bucket_shapes=[s.shape_key[:2] for s in stages],
        bucket_strategies=[(s.shape_key[1], s.strategy) for s in stages],
        bucket_edges=[b.edges for b in buckets],
        key_mode=mode,
    )
    if mesh is not None:
        meta["mesh"] = mesh_cache_component(mesh)
        meta["num_shards"] = ndev
    return stages, keys, perm, m_edges, meta


@dataclasses.dataclass
class TrussPlan:
    """A prepared edge-analytics session: device buffers + cached edge
    executables for per-edge support, plus the device k-truss peel loop.

    Mirrors ``TrianglePlan`` for the edge lane (registered as
    ``algorithm="edge"``): construction runs the prep stage once —
    orientation, bucketing, padded gathers, and the sorted undirected-edge
    key array — and ``support()`` / ``edge_support()`` / ``count()`` are
    device replays of the cached stages. ``k_truss(k)`` iterates the peel
    (support recompute → filter → re-orient through
    ``DeviceCSR.from_edges`` and the device prep pipeline) until fixpoint
    or ``max_peel_iters``; every round's stages come from the same
    process-wide executable cache, so rounds whose policy-rounded shapes
    collide compile nothing new. The host enumeration in
    ``repro.core.listing`` is never called (tests poison it).
    """

    graph: Graph
    stages: List[_EdgeStage]
    edge_keys: jnp.ndarray  # (mk,) sorted keys; padding = key-dtype max
    perm: jnp.ndarray  # (mk,) slot→key-order permutation
    m_edges: int
    widths: Tuple[int, ...]
    strategy: str
    bitmap_bits: Optional[int]
    prep_backend: str
    policy: ShapePolicy
    max_peel_iters: int
    peel_early_exit: bool
    meta: Dict[str, Any]
    prep_seconds: float
    executions: int = 0
    mesh: Any = None  # device mesh when the support stages are sharded
    key_mode: str = "int32"  # resolved packed-key mode (int32 | wide)

    algorithm: str = "edge"

    @staticmethod
    def _run_stages(stages: List[_EdgeStage], keys: jnp.ndarray,
                    perm: jnp.ndarray) -> jnp.ndarray:
        """Sum the per-bucket slot-ordered supports, then reorder into
        sorted-key order (one gather per round, aligned with ``keys``)."""
        total = jnp.zeros(keys.shape[0], jnp.int32)
        for st in stages:
            total = total + st.executable(*st.args)
        return total[perm]

    def support(self) -> np.ndarray:
        """(m,) int64 per-edge triangle-membership counts, in
        ``edge_list_unique`` (lex (lo, hi)) order; pure device replay."""
        total = self._run_stages(self.stages, self.edge_keys, self.perm)
        self.executions += 1
        return np.asarray(total, dtype=np.int64)[: self.m_edges]

    def edge_support(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, support) with src < dst — the device replacement for
        ``repro.core.listing.edge_support`` (same order, same dtypes)."""
        keys = np.asarray(self.edge_keys)[: self.m_edges]
        su, sv = _decode_edge_keys(keys, self.graph.n + 1)
        return su, sv, self.support()

    def count(self) -> int:
        """Exact triangle count: every triangle contributes 1 to each of
        its three edges, so Σ support = 3Δ."""
        total = int(self.support().sum())
        assert total % 3 == 0, total
        return total // 3

    def count_with_stats(self) -> Tuple[int, dict]:
        return self.count(), dict(self.meta)

    def _peel(self, start: Optional[Graph], k: int,
              max_iters: int) -> Tuple[np.ndarray, int, bool]:
        """Bulk k-truss peel to fixpoint (or ``max_iters`` rounds).

        ``start=None`` peels the plan's own graph, reusing the cached
        first-round stages. Returns (surviving packed keys as int64 numpy,
        rounds run, converged) — identical semantics to the host oracle:
        every round removes ALL edges with support < k − 2 simultaneously.
        """
        thresh = int(k) - 2
        peel_key = (self.max_peel_iters, self.peel_early_exit)
        kw = dict(widths=self.widths, strategy=self.strategy,
                  bitmap_bits=self.bitmap_bits,
                  prep_backend=self.prep_backend, policy=self.policy,
                  peel_key=peel_key, mesh=self.mesh,
                  key_mode=self.key_mode)
        if start is None:
            stages, keys, perm, m_cur = (self.stages, self.edge_keys,
                                         self.perm, self.m_edges)
        else:
            stages, keys, perm, m_cur, _ = _edge_stages(start, **kw)
        n, n1 = self.graph.n, self.graph.n + 1
        rounds, converged = 0, (m_cur == 0)
        while rounds < max_iters and m_cur > 0:
            supp = self._run_stages(stages, keys, perm)
            keep = supp[:m_cur] >= thresh
            kept = int(jnp.sum(keep))  # one scalar sync per round
            rounds += 1
            if kept == m_cur:
                converged = True
                if self.peel_early_exit:
                    break
                continue  # fixpoint is stable; remaining rounds are no-ops
            if kept == 0:
                # the empty edge set is trivially stable: a fixpoint too
                m_cur, converged = 0, True
                break
            if self.prep_backend == "device":
                # re-orient on device: survivors symmetrized through the
                # jitted sort-based CSR build, then re-prepped (decode runs
                # under the key mode's x64 context; vertex ids fit int32)
                with edge_key_context(self.key_mode):
                    lo = (keys[:m_cur] // n1).astype(jnp.int32)
                    hi = (keys[:m_cur] % n1).astype(jnp.int32)
                csr = DeviceCSR.from_edges(
                    jnp.concatenate([lo, hi]), jnp.concatenate([hi, lo]),
                    n, valid=jnp.concatenate([keep, keep]),
                    policy=self.policy, key_mode=self.key_mode,
                )
                cur = DeviceGraph(csr, policy=self.policy,
                                  name=self.graph.name + "+peel")
            else:
                keys_h = np.asarray(keys)[:m_cur][np.asarray(keep)]
                su, sv = _decode_edge_keys(keys_h, n1)
                cur = edges_to_csr(su, sv, n=n,
                                   name=self.graph.name + "+peel")
            stages, keys, perm, m_cur, _ = _edge_stages(cur, **kw)
        self.executions += rounds
        return np.asarray(keys, dtype=np.int64)[:m_cur], rounds, converged

    def k_truss(self, k: int, *, max_iters: Optional[int] = None) -> Graph:
        """Maximal subgraph where every edge is in ≥ k − 2 triangles.

        The device peel loop: support recompute → filter → re-orient per
        round, stopping at the fixpoint (``peel_early_exit``) or after
        ``max_iters`` rounds (default: the plan's ``max_peel_iters``). The
        surviving edge set is bit-identical to the
        ``repro.core.listing.k_truss`` host oracle. ``meta["peel_rounds"]``
        / ``meta["peel_converged"]`` record the last peel.
        """
        max_iters = self.max_peel_iters if max_iters is None else int(max_iters)
        keys, rounds, converged = self._peel(None, k, max_iters)
        self.meta["peel_rounds"] = rounds
        self.meta["peel_converged"] = converged
        su, sv = _decode_edge_keys(keys, self.graph.n + 1)
        return edges_to_csr(su, sv, n=self.graph.n,
                            name=self.graph.name + f"+truss{k}")

    def truss_decomposition(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-edge trussness: the largest k such that the edge survives the
        k-truss. Returns (src, dst, trussness) with src < dst, in
        ``edge_list_unique`` order (edges in no triangle have trussness 2).

        Peels level by level — each k-truss starts from the previous level's
        survivors (the (k)-truss of the (k−1)-truss IS the (k)-truss of the
        graph), so the edges removed between levels are exactly the
        trussness-(k−1) class. Trussness is only defined at the peel's
        fixpoint, so every level must converge within ``max_peel_iters``;
        a bound chosen for truncated ``k_truss`` benchmarking raises here
        instead of silently inflating labels.

        Raises:
          ValueError: a level's peel hit ``max_peel_iters`` before its
            fixpoint.
        """
        n1 = self.graph.n + 1
        orig = np.asarray(self.edge_keys, dtype=np.int64)[: self.m_edges]
        truss = np.full(orig.shape[0], 2, dtype=np.int64)
        cur_keys, cur_graph, k = orig, None, 3
        while cur_keys.size:
            nxt_keys, _, converged = self._peel(cur_graph, k,
                                                self.max_peel_iters)
            if not converged:
                raise ValueError(
                    f"truss_decomposition needs every peel level to reach "
                    f"its fixpoint, but the {k}-truss peel was truncated at "
                    f"max_peel_iters={self.max_peel_iters}; raise the "
                    f"max_peel_iters option"
                )
            removed = cur_keys[~np.isin(cur_keys, nxt_keys)]
            truss[np.searchsorted(orig, removed)] = k - 1
            su, sv = _decode_edge_keys(nxt_keys, n1)
            cur_graph = edges_to_csr(su, sv, n=self.graph.n,
                                     name=self.graph.name + f"+truss{k}")
            cur_keys, k = nxt_keys, k + 1
        su, sv = _decode_edge_keys(orig, n1)
        return su, sv, truss

    def block_until_ready(self) -> "TrussPlan":
        for st in self.stages:
            for a in st.args:
                a.block_until_ready()
        self.edge_keys.block_until_ready()
        return self

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def shape_keys(self) -> List[tuple]:
        return [st.shape_key for st in self.stages]


def plan_edge_support(
    g: Graph,
    *,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
    bitmap_bits: Optional[int] = None,
    prep_backend: str = "device",
    shape_policy: Optional[ShapePolicy] = None,
    max_peel_iters: int = 1000,
    peel_early_exit: bool = True,
    mesh=None,
    key_mode: str = "auto",
) -> TrussPlan:
    """Run the edge lane's prep once and return a replayable ``TrussPlan``.

    Args:
      g: the input ``Graph`` (undirected simple CSR; packed edge keys are
        int32 while ``(n + 1)² ≤ int32 max`` — n ≲ 46k — and promote to
        the wide (x64 int64) mode past it under ``key_mode="auto"``).
      widths: degree-class bucket widths (as the intersection lane).
      strategy: per-bucket match-mask core — the mask-specific
        ``resolve_mask_strategy`` cost model: "auto" (bitmap while the id
        range stays within ~4·W packed bits — the probe mask pays two
        searchsorted passes — then probe for W ≥ 64, broadcast below) or a
        forced "broadcast" | "probe" | "bitmap".
      bitmap_bits: optional forced packed capacity for bitmap buckets
        (must cover the id range ``n + 2``).
      prep_backend: "device" (default; jitted prep + device peel) or "host"
        (numpy parity prep; the support executables still run on device).
      shape_policy: extent-rounding policy (None ⇒ ``DEFAULT_SHAPE_POLICY``).
      max_peel_iters: k-truss peel round bound (the peel normally stops at
        its fixpoint much earlier).
      peel_early_exit: stop the peel at the fixpoint (default) or run
        exactly ``max_peel_iters`` rounds (identical result; benchmarking
        mode). Both knobs are folded into the edge executables' cache key.
      mesh: optional jax device mesh — shards every bucket's support rows
        round-robin across the mesh (``deal_across_shards``); the partial
        (mk,) supports combine under one vector psum per bucket. Peel
        rounds re-deal the survivor graph over the same mesh. None keeps
        the single-host stages.
      key_mode: "auto" (int32 keys while they fit, wide int64 past that) |
        "int32" | "wide" — resolved through the single capacity checkpoint
        ``repro.graphs.device.resolve_edge_key_mode``, which raises
        ``GraphTooLargeError`` when the requested mode cannot represent
        the graph.

    Returns:
      A ``TrussPlan`` exposing ``edge_support()`` / ``k_truss(k)`` /
      ``truss_decomposition()`` / ``count()``. The facade surfaces these as
      ``TriangleCounter.edge_support()`` etc.; ``CountOptions`` maps onto
      the keyword arguments via ``plan_kwargs("edge")``.
    """
    policy = shape_policy if shape_policy is not None else DEFAULT_SHAPE_POLICY
    max_peel_iters = int(max_peel_iters)
    peel_early_exit = bool(peel_early_exit)
    if max_peel_iters < 1:
        raise ValueError(f"max_peel_iters must be ≥ 1, got {max_peel_iters}")
    t0 = time.perf_counter()
    stages, keys, perm, m_edges, bucket_meta = _edge_stages(
        g, widths=tuple(widths), strategy=strategy, bitmap_bits=bitmap_bits,
        prep_backend=prep_backend, policy=policy,
        peel_key=(max_peel_iters, peel_early_exit), mesh=mesh,
        key_mode=key_mode,
    )
    meta = dict(
        graph=g.name,
        n=g.n,
        m=g.m_undirected,
        edges=m_edges,
        widths=tuple(widths),
        strategy=strategy,
        prep_backend=prep_backend,
        shape_policy=policy.key() if prep_backend == "device" else None,
        max_peel_iters=max_peel_iters,
        peel_early_exit=peel_early_exit,
        **bucket_meta,
    )
    prep_seconds = time.perf_counter() - t0
    return TrussPlan(
        graph=g,
        stages=stages,
        edge_keys=keys,
        perm=perm,
        m_edges=m_edges,
        widths=tuple(widths),
        strategy=strategy,
        bitmap_bits=bitmap_bits,
        prep_backend=prep_backend,
        policy=policy,
        max_peel_iters=max_peel_iters,
        peel_early_exit=peel_early_exit,
        meta=meta,
        prep_seconds=prep_seconds,
        mesh=mesh,
        key_mode=bucket_meta["key_mode"],
    )


def _edge_planner(g: Graph, options, *, mesh=None) -> TrussPlan:
    """Registry planner: CountOptions → edge-lane TrussPlan (support
    stages sharded over ``mesh`` when the session carries one)."""
    return plan_edge_support(g, mesh=mesh, **options.plan_kwargs("edge"))


register_algorithm("edge", _edge_planner)


# ---------------------------------------------------------------------------
# DynamicPlan — the dynamic lane: batched edge updates, incremental count
# ---------------------------------------------------------------------------

class DynamicPlan:
    """Device state + cached executables for one dynamic-graph session.

    The plan owns a mutable device-resident edge set — two sorted
    orderings of packed keys, ``lo * (n + 1) + hi`` and
    ``hi * (n + 1) + lo`` (int32 when ``(n + 1)² ≤ int32 max``, else
    x64-gated int64 "wide" keys), with the mode's sentinel in dead
    slots; the
    orderings ARE the adjacency (any vertex's neighbor row is two
    contiguous runs) — and maintains the exact triangle count
    incrementally across batched
    :class:`~repro.graphs.formats.EdgeUpdate` streams:

    1. a cached "dynamic_step" executable resolves the batch against the
       key set (tombstone deletes, merge inserts, one sort per ordering
       compacts) and gathers the batch's anchor-vertex adjacency rows —
       pre- and post-update — in a single device dispatch that touches
       O(batch) adjacency, never a full CSR/neighbor rebuild;
    2. a cached "delta" executable counts triangles *anchored* on the
       effective deletes against the pre-update adjacency (Δ⁻) and on the
       effective inserts against the post-update adjacency (Δ⁺), with the
       6/k multi-anchor weighting described in
       ``_build_delta_executable``;
    3. ``count = count − Δ⁻ + Δ⁺``.

    Every array extent — key capacity, update rows, neighbor width — lives
    in a :class:`~repro.graphs.device.ShapePolicy` class and only ever
    grows, so steady-state batches replay two cached executables with zero
    recompiles; crossing a class boundary re-buckets and compiles exactly
    once (visible in ``executable_cache_info()``). Every
    ``recount_interval`` batches (and on demand via :meth:`recount`) a full
    from-scratch filtered-intersection recount over the device CSR checks
    the incremental count bit-exactly and raises on drift.
    """

    algorithm = "dynamic"

    def __init__(self, g: Graph, *, backend: str = "jnp",
                 interpret: Optional[bool] = None,
                 widths: Sequence[int] = DEFAULT_WIDTHS,
                 strategy: str = "auto",
                 bitmap_bits: Optional[int] = None,
                 shape_policy: Optional[ShapePolicy] = None,
                 update_batch_size: int = 256,
                 recount_interval: int = 64,
                 key_mode: str = "auto"):
        if backend not in ("jnp", "pallas", "ref"):
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected 'jnp', 'pallas', or 'ref'")
        self.key_mode = resolve_edge_key_mode(g.n, key_mode, lane="dynamic")
        self._sentinel = int(edge_key_sentinel(self.key_mode))
        self._key_dtype = edge_key_dtype(self.key_mode)
        update_batch_size = int(update_batch_size)
        recount_interval = int(recount_interval)
        if update_batch_size < 1:
            raise ValueError(
                f"update_batch_size must be ≥ 1, got {update_batch_size}")
        if recount_interval < 0:
            raise ValueError(
                f"recount_interval must be ≥ 0 (0 disables the periodic "
                f"oracle), got {recount_interval}")
        t0 = time.perf_counter()
        self.graph = g
        self.name = g.name
        self.n = int(g.n)
        self.backend = backend
        self.interpret = resolve_interpret(interpret)
        self.widths = tuple(int(w) for w in widths)
        self.strategy = strategy
        self.bitmap_bits = bitmap_bits
        self.policy = (shape_policy if shape_policy is not None
                       else DEFAULT_SHAPE_POLICY)
        self.update_batch_size = update_batch_size
        self.recount_interval = recount_interval
        self.ub = self.policy.round_edges(update_batch_size)
        # width class: the configured widths plus an optional pow2 top
        # bound that only ever grows (never recomputed down — a denser
        # interlude must not force a recompile on the way back)
        self._extra_top: Optional[int] = None
        dmax = int(g.max_degree)
        if dmax > self.widths[-1]:
            self._extra_top = next_pow2(dmax)
        # upload the initial edge set as BOTH sorted key orderings
        lo, hi = g.edge_list_unique()
        self.m = int(lo.shape[0])
        self.cap = self.policy.round_edges(self.m)
        n1 = self.n + 1
        host_keys = np.full(self.cap, self._sentinel, np.int64)
        host_keys[: self.m] = np.sort(
            lo.astype(np.int64) * n1 + hi.astype(np.int64))
        host_rkeys = np.full(self.cap, self._sentinel, np.int64)
        host_rkeys[: self.m] = np.sort(
            hi.astype(np.int64) * n1 + lo.astype(np.int64))
        with edge_key_context(self.key_mode):
            self._keys = jnp.asarray(host_keys.astype(self._key_dtype))
            self._rkeys = jnp.asarray(host_rkeys.astype(self._key_dtype))
        self.batches = 0
        self.inserted = 0
        self.deleted = 0
        self.recounts = 0
        self.executions = 0
        # prime: one all-padding step compiles this shape class
        self._apply_step(
            np.full(self.ub, self._sentinel, np.int64),
            np.full(self.ub, self._sentinel, np.int64),
            np.zeros(self.ub, bool), np.zeros(self.ub, bool))
        self._count = self._full_recount()
        self.meta = dict(
            graph=self.name, n=self.n, m=self.m,
            key_mode=self.key_mode,
            widths=self.widths, strategy=self.strategy,
            shape_policy=self.policy.key(),
            update_batch_size=self.update_batch_size,
            update_rows=self.ub,
            recount_interval=self.recount_interval,
            bounds=self.bounds, capacity=self.cap,
            bucket_strategies=self._bucket_strategies(),
            batches=0, inserted=0, deleted=0, recounts=0,
        )
        self.prep_seconds = time.perf_counter() - t0

    # -- shape classes ------------------------------------------------------

    @property
    def bounds(self) -> tuple:
        """The session's width classes (widths plus the monotone top)."""
        if self._extra_top is not None:
            return self.widths + (self._extra_top,)
        return self.widths

    def _bucket_strategies(self) -> list:
        id_range = self.n + 2
        return [(int(w), resolve_mask_strategy(int(w), id_range,
                                               self.strategy)[0])
                for w in self.bounds]

    def _maybe_grow_width(self, dmax: int) -> bool:
        if dmax <= self.bounds[-1]:
            return False
        self._extra_top = next_pow2(dmax)
        return True

    def _grow_capacity(self, needed: int) -> None:
        new_cap = self.policy.round_edges(needed)
        if new_cap <= self.cap:  # pragma: no cover - rounding is monotone
            raise AssertionError("capacity growth must be monotone")
        with edge_key_context(self.key_mode):
            pad = jnp.full(new_cap - self.cap, self._sentinel,
                           self._keys.dtype)
            self._keys = jnp.concatenate([self._keys, pad])
            self._rkeys = jnp.concatenate([self._rkeys, pad])
        self.cap = new_cap

    # -- cached executables -------------------------------------------------

    def _step_executable(self) -> Callable:
        # wide mode appends a trailing marker so int32 sessions keep their
        # exact historical cache keys (the builder strips it)
        wide = ("wide",) if self.key_mode == "wide" else ()
        return get_executable(
            "dynamic_step", "jnp", False,
            (self.cap, self.ub, self.n + 1, int(self.bounds[-1])) + wide)

    def _delta_executable(self) -> Callable:
        wide = ("wide",) if self.key_mode == "wide" else ()
        return get_executable(
            "delta", "jnp", False,
            (self.ub, self.n + 1) + self.bounds + wide,
            strategy=self.strategy, bitmap_bits=self.bitmap_bits)

    # -- update path --------------------------------------------------------

    def _apply_step(self, upd_keys: np.ndarray, upd_rkeys: np.ndarray,
                    upd_ins: np.ndarray, upd_valid: np.ndarray):
        """Run one padded device step and return its full output tuple."""
        with edge_key_context(self.key_mode):
            return self._step_executable()(
                self._keys, self._rkeys,
                jnp.asarray(upd_keys.astype(self._key_dtype)),
                jnp.asarray(upd_rkeys.astype(self._key_dtype)),
                jnp.asarray(upd_ins), jnp.asarray(upd_valid))

    def apply_updates(self, lo: np.ndarray, hi: np.ndarray,
                      insert: np.ndarray) -> dict:
        """Apply a normalized update stream and maintain the count.

        Args are the arrays produced by
        :func:`repro.graphs.formats.normalize_edge_updates` (oriented
        lo < hi pairs, self-loops dropped, last-wins deduped). The stream
        is chunked by ``update_batch_size``; each chunk runs the step +
        two delta dispatches described in the class docstring. Returns the
        refreshed ``meta`` dict.
        """
        lo = np.asarray(lo, dtype=np.int32)
        hi = np.asarray(hi, dtype=np.int32)
        insert = np.asarray(insert, dtype=bool)
        ubs = self.update_batch_size
        for s in range(0, int(lo.shape[0]), ubs):
            self._apply_chunk(lo[s:s + ubs], hi[s:s + ubs],
                              insert[s:s + ubs])
        return self._sync_meta()

    def _apply_chunk(self, lo_c: np.ndarray, hi_c: np.ndarray,
                     ins_c: np.ndarray) -> None:
        nu = int(lo_c.shape[0])
        if nu == 0:
            return
        # host capacity pre-check: grow the key array BEFORE the step so
        # the step executable compiles at most once per capacity class
        n_ins_req = int(ins_c.sum())
        if self.m + n_ins_req > self.cap:
            self._grow_capacity(self.m + n_ins_req)
        n1 = self.n + 1
        upd_keys = np.full(self.ub, self._sentinel, np.int64)
        upd_keys[:nu] = lo_c.astype(np.int64) * n1 + hi_c.astype(np.int64)
        upd_rkeys = np.full(self.ub, self._sentinel, np.int64)
        upd_rkeys[:nu] = hi_c.astype(np.int64) * n1 + lo_c.astype(np.int64)
        upd_ins = np.zeros(self.ub, bool)
        upd_ins[:nu] = ins_c
        upd_valid = np.zeros(self.ub, bool)
        upd_valid[:nu] = True
        d_lo = np.zeros(self.ub, np.int32)
        d_lo[:nu] = lo_c
        d_hi = np.zeros(self.ub, np.int32)
        d_hi[:nu] = hi_c
        step_out = self._apply_step(upd_keys, upd_rkeys, upd_ins, upd_valid)
        d_lo = jnp.asarray(d_lo)
        d_hi = jnp.asarray(d_hi)
        # Δ⁻: delete-anchored triangles against the PRE-update adjacency
        # (launched before the stats sync; the old rows fit the old class)
        (_, _, eff_ins, eff_del, ins_skeys, del_skeys,
         old_lr, old_hr, old_ld, old_hd, _, _, _, _, st) = step_out
        with edge_key_context(self.key_mode):
            sum_del = self._delta_executable()(
                old_lr, old_hr, old_ld, old_hd, d_lo, d_hi, eff_del,
                del_skeys)
        # one small sync: the step stats drive the (rare) width growth
        m_new, dmax_new, n_ins, n_del = (int(x) for x in np.asarray(st))
        if self._maybe_grow_width(dmax_new):
            # re-run the step once at the grown width class so the Δ⁺
            # anchor rows carry the full widened adjacency; the new-class
            # step/delta executables compile exactly once here (the
            # pre-update state is still uncommitted, so this is a pure
            # replay at the wider shape)
            step_out = self._apply_step(upd_keys, upd_rkeys, upd_ins,
                                        upd_valid)
        (new_keys, new_rkeys, eff_ins, eff_del, ins_skeys, del_skeys,
         _, _, _, _, new_lr, new_hr, new_ld, new_hd, st) = step_out
        # Δ⁺: insert-anchored triangles against the POST-update adjacency
        with edge_key_context(self.key_mode):
            sum_ins = self._delta_executable()(
                new_lr, new_hr, new_ld, new_hd, d_lo, d_hi, eff_ins,
                ins_skeys)
        sdel = int(np.asarray(sum_del))
        sins = int(np.asarray(sum_ins))
        if sdel % 6 or sins % 6:
            raise RuntimeError(
                f"dynamic delta drift on {self.name!r}: weighted anchor "
                f"sums ({sdel}, {sins}) are not divisible by 6")
        self._count += sins // 6 - sdel // 6
        # commit the post-update device state
        self._keys = new_keys
        self._rkeys = new_rkeys
        self.m = m_new
        self.inserted += n_ins
        self.deleted += n_del
        self.executions += 1
        self.batches += 1
        if self.recount_interval and self.batches % self.recount_interval == 0:
            self.recount()

    # -- counting & the parity oracle ---------------------------------------

    def _full_recount(self) -> int:
        if self.m == 0:
            return 0
        # the rare oracle path: materialize the live keys as a CSR (the
        # steady-state update path never builds one) and run the ordinary
        # filtered-intersection plan stages over it
        snap = self.snapshot()
        csr = DeviceCSR(n=self.n, m=2 * self.m,
                        row_ptr=jnp.asarray(snap.row_ptr),
                        col_idx=jnp.asarray(snap.col_idx))
        dg = DeviceGraph(csr, policy=self.policy,
                         name=self.name + "+recount")
        stages, _, _ = _plan_intersection(
            dg, "filtered", self.backend, self.interpret, self.widths,
            self.strategy, self.bitmap_bits, "device", self.policy)
        return sum(int(st.executable(*st.args)) for st in stages)

    def count(self) -> int:
        """The incrementally maintained exact triangle count (O(1))."""
        return self._count

    def count_with_stats(self):
        """(count, meta) with the meta refreshed to the current state."""
        return self._count, self._sync_meta()

    def recount(self) -> int:
        """Full-recount parity oracle: count the device CSR from scratch
        and raise ``RuntimeError`` if the incremental count has drifted."""
        full = self._full_recount()
        self.recounts += 1
        if full != self._count:
            raise RuntimeError(
                f"incremental triangle count drifted on {self.name!r}: "
                f"incremental={self._count}, full recount={full} after "
                f"{self.batches} update batches")
        return full

    def snapshot(self) -> Graph:
        """Materialize the current device edge set as a host ``Graph``."""
        keys = np.asarray(self._keys).astype(np.int64)
        keys = keys[keys != self._sentinel]
        lo, hi = _decode_edge_keys(keys, self.n + 1)
        return edges_to_csr(lo, hi, n=self.n, name=self.name + "+dynamic")

    def _sync_meta(self) -> dict:
        self.meta.update(
            m=self.m, capacity=self.cap, bounds=self.bounds,
            bucket_strategies=self._bucket_strategies(),
            batches=self.batches, inserted=self.inserted,
            deleted=self.deleted, recounts=self.recounts)
        return dict(self.meta)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"DynamicPlan(graph={self.name!r}, n={self.n}, m={self.m}, "
                f"count={self._count}, batches={self.batches})")


def plan_dynamic_count(
    g: Graph,
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
    bitmap_bits: Optional[int] = None,
    shape_policy: Optional[ShapePolicy] = None,
    update_batch_size: int = 256,
    recount_interval: int = 64,
    key_mode: str = "auto",
) -> DynamicPlan:
    """Open a dynamic-graph counting session seeded from ``g``.

    Args:
      g: the seed ``Graph`` (may be empty). Graphs past the int32 packed
        pair-key bound (n ≳ 46k) automatically promote to the x64-gated
        int64 "wide" key mode; see ``key_mode``.
      backend / interpret / widths / strategy / bitmap_bits / shape_policy:
        as the intersection lane — they configure both the delta
        executables and the periodic full recount.
      update_batch_size: updates per device dispatch; longer streams are
        chunked. Padded to a policy extent (the "update rows" class).
      recount_interval: run the full-recount parity oracle every this many
        batches (0 disables it; ``recount()`` is always available).
      key_mode: packed-key representation — ``"auto"`` (int32 when it
        fits, else wide), ``"int32"`` (raise ``GraphTooLargeError`` past
        the bound), or ``"wide"`` (force int64 keys). Resolved by
        :func:`repro.graphs.device.resolve_edge_key_mode`.

    Returns:
      A ``DynamicPlan``; the facade surfaces it as
      ``DynamicTriangleCounter``, and ``CountOptions`` maps onto the
      keyword arguments via ``plan_kwargs("dynamic")``.
    """
    return DynamicPlan(
        g, backend=backend, interpret=interpret, widths=widths,
        strategy=strategy, bitmap_bits=bitmap_bits,
        shape_policy=shape_policy, update_batch_size=update_batch_size,
        recount_interval=recount_interval, key_mode=key_mode)


def _dynamic_planner(g: Graph, options, *, mesh=None) -> DynamicPlan:
    """Registry planner: CountOptions → dynamic-lane DynamicPlan."""
    return plan_dynamic_count(g, **options.plan_kwargs("dynamic"))


register_algorithm("dynamic", _dynamic_planner)


# ---------------------------------------------------------------------------
# GraphBatch — same-policy graphs stacked into one vmapped dispatch
# ---------------------------------------------------------------------------

def _pad_bucket_rows(arr: jnp.ndarray, e_pad: int, fill: int) -> jnp.ndarray:
    pad = e_pad - int(arr.shape[0])
    if pad <= 0:
        return arr
    return jnp.concatenate(
        [arr, jnp.full((pad, arr.shape[1]), fill, arr.dtype)]
    )


@dataclasses.dataclass
class GraphBatch:
    """A batch of graphs prepped under one ``ShapePolicy`` and stacked so the
    whole batch is counted by ONE vmapped device dispatch.

    Build via ``from_graphs``: each member runs the device-resident
    intersection prep, the per-width buckets are harmonized to the maximum
    policy-rounded extent across members (missing widths become all-padding
    buckets, which count zero), and each width's (u, v) pairs are stacked
    into (B, E, W) arrays. ``counts()`` then runs a single jitted program —
    every bucket's vmapped intersection plus the cross-bucket sum — from the
    shape-policy-keyed batch-executable cache. This is the
    ``TriangleCounter.count_many`` fast path.
    """

    graphs: List[Any]
    backend: str
    interpret: bool
    divisor: int
    specs: tuple  # ((strategy, bitmap_bits, (e_pad, width)), ...) per bucket
    arrays: List[jnp.ndarray]  # flattened (u, v) stacks, device-resident
    meta: Dict[str, Any]
    prep_seconds: float
    executions: int = 0

    @property
    def batch_size(self) -> int:
        return len(self.graphs)

    @property
    def shape_keys(self) -> List[tuple]:
        return [shape for _, _, shape in self.specs]

    def counts(self) -> np.ndarray:
        """(B,) exact triangle counts — one device dispatch for the batch."""
        if not self.specs:
            out = np.zeros(self.batch_size, dtype=np.int64)
        else:
            fn = get_batch_executable(self.specs, self.backend,
                                      self.interpret, self.batch_size)
            out = np.asarray(fn(*self.arrays), dtype=np.int64)
        if self.divisor != 1:
            assert (out % self.divisor == 0).all(), out
            out //= self.divisor
        self.executions += 1
        return out

    def block_until_ready(self) -> "GraphBatch":
        for a in self.arrays:
            a.block_until_ready()
        return self

    @classmethod
    def from_graphs(cls, graphs: Sequence[Graph], options=None,
                    **overrides) -> "GraphBatch":
        """Prep + stack ``graphs`` under one options bag.

        Args:
          graphs: host ``Graph``s (any mix of sizes; the stacked layout is
            the per-width maximum of the policy-rounded extents).
          options: a ``CountOptions``; None builds one from ``**overrides``.
            Must have ``backend="jnp"`` (the vmapped cores are the pure-jnp
            paths) and ``prep_backend="device"``.

        Raises:
          ValueError: empty batch, or options outside the batchable regime.
        """
        from repro.core.options import CountOptions

        if options is None:
            options = CountOptions(**overrides)
        elif overrides:
            options = options.replace(**overrides)
        graphs = list(graphs)
        if not graphs:
            raise ValueError("GraphBatch needs at least one graph")
        if options.backend != "jnp":
            raise ValueError(
                f"GraphBatch requires backend='jnp' (vmapped pure-jnp "
                f"cores); got {options.backend!r}"
            )
        if options.prep_backend != "device":
            raise ValueError(
                "GraphBatch requires prep_backend='device' (the stacked "
                "layout is defined by the device prep's ShapePolicy)"
            )
        policy = options.resolved_shape_policy
        interpret = options.resolved_interpret
        t0 = time.perf_counter()
        per_graph = [
            prep.prepare_intersection_buckets_device(
                g, variant=options.variant, widths=options.widths,
                policy=policy,
            )
            for g in graphs
        ]
        # harmonize: per width, every member is padded to the max rounded
        # extent; members without that width contribute all-padding buckets
        widths_union = sorted({b.width for bs in per_graph for b in bs})
        id_range = max(g.n for g in graphs) + 2
        specs, arrays = [], []
        for w in widths_union:
            members = [
                {b.width: b for b in bs}.get(w) for bs in per_graph
            ]
            e_pad = max(policy.round_edges(1) if b is None else b.e_pad
                        for b in members)
            us, vs = [], []
            for b in members:
                if b is None:
                    us.append(jnp.full((e_pad, w), -1, jnp.int32))
                    vs.append(jnp.full((e_pad, w), -2, jnp.int32))
                else:
                    us.append(_pad_bucket_rows(b.u_lists, e_pad, -1))
                    vs.append(_pad_bucket_rows(b.v_lists, e_pad, -2))
            strat, bits = _resolve_bucket_strategy(
                w, id_range, options.strategy, options.bitmap_bits
            )
            specs.append((strat, bits, (e_pad, w)))
            arrays.extend([jnp.stack(us), jnp.stack(vs)])
        prep_seconds = time.perf_counter() - t0
        meta = dict(
            batch_size=len(graphs),
            variant=options.variant,
            widths=tuple(options.widths),
            strategy=options.strategy,
            shape_policy=policy.key(),
            prep_backend="device",
            bucket_shapes=[s[2] for s in specs],
            bucket_strategies=[(s[2][1], s[0]) for s in specs],
            graphs=[g.name for g in graphs],
        )
        return cls(
            graphs=graphs,
            backend=options.backend,
            interpret=interpret,
            divisor=6 if options.variant == "full" else 1,
            specs=tuple(specs),
            arrays=arrays,
            meta=meta,
            prep_seconds=prep_seconds,
        )
