"""Plan/execute engine for exact triangle counting.

The paper's pipeline for every method splits into a *host stage* (filtering,
orientation, degree-class grouping, tile scheduling — §3's FORM_FILTERED_
EDGE_LIST / permute-split / INITIALIZE_CANDIDATE_SET steps) and a *device
stage* (the intersection / masked-SpGEMM / join kernels that §4 measures).
The one-shot ``triangle_count_*`` entry points redo the host stage on every
call, so repeated counts and benchmark sweeps are dominated by numpy prep
instead of the kernels the paper compares.

This module makes the split explicit:

    plan = plan_triangle_count(g, algorithm="intersection", backend="jnp")
    plan.count()   # first call traces + compiles (or hits the shared cache)
    plan.count()   # device-only replay: no numpy, no retrace, no recompile

``plan_triangle_count`` runs the host stage ONCE — orientation + bucketing +
padded neighbor gathers for the intersection path; degree permutation + BSR
tile schedule for the matrix path; 2-core peel + induced-subgraph reform +
bucket setup for the subgraph-matching path — uploads the resulting
statically-shaped arrays to the default device, and binds each work unit to a
jit-compiled executable from a process-wide cache keyed by
``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``. Two
consequences:

* ``plan.count()`` is a pure device replay: one traced computation per bucket
  shape (the kernel AND its reduction live inside the same jit), summed as
  Python ints on the way out.
* Plans over same-shaped graphs (e.g. the fig6 R-MAT sweep, or batches of
  generated graphs) hit the executable cache and skip XLA compilation — the
  TRUST-style decoupling of preprocessing/partitioning from counting.

On the intersection lane (and the subgraph lane's join, which reuses it) the
plan stage also selects a *set-intersection strategy* per degree bucket —
``broadcast`` / ``probe`` / ``bitmap``, see ``repro.kernels.intersect.ops`` —
via the documented ``choose_strategy`` cost model (``strategy="auto"``, the
default: bitmap when the bucket's id range fits the packed width, probe for
wide buckets, broadcast for narrow ones). The choice can be overridden per
plan (``strategy="probe"`` etc.), is baked into each stage's executable-cache
key, and is surfaced as ``meta["bucket_strategies"]`` by
``count_with_stats()``.

The host-stage helpers (``prepare_intersection_buckets``,
``build_tile_schedule``, ``choose_block``, ``peel_to_two_core``) live here and
are re-exported by the per-algorithm modules for backward compatibility.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.formats import (
    Graph,
    apply_permutation,
    bucket_edges_by_degree,
    csr_to_padded_neighbors,
    degree_order_permutation,
    induced_subgraph,
    orient_forward,
    to_block_sparse,
)
from repro.core.options import DEFAULT_WIDTHS, resolve_interpret
from repro.kernels.intersect.ops import (
    STRATEGIES,
    choose_strategy,
    intersect_counts,
    resolve_strategy,
)
from repro.kernels.masked_spgemm.ops import masked_spgemm_counts

__all__ = [
    "TrianglePlan",
    "plan_triangle_count",
    "prepare_intersection_buckets",
    "build_tile_schedule",
    "choose_block",
    "peel_to_two_core",
    "choose_strategy",
    "resolve_strategy",
    "executable_cache_info",
    "clear_executable_cache",
    "DEFAULT_WIDTHS",
    "STRATEGIES",
]

ALGORITHMS = ("intersection", "matrix", "subgraph")


# ---------------------------------------------------------------------------
# Host stage (numpy prep) — runs exactly once per plan
# ---------------------------------------------------------------------------

def prepare_intersection_buckets(
    g: Graph,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
) -> list:
    """Host-side stage of the intersection method: orientation + degree-class
    bucketing + padded neighbor gathers.

    Args:
      g: undirected simple ``Graph``.
      variant: "filtered" — forward orientation (rank = (degree, id)), the
        paper's "filter out half of the edges by degree order"; the oriented
        rows double as the reformed induced subgraph's neighbor lists.
        "full" — all directed edges with full neighbor lists (each triangle
        found 6×), the tc-intersection-full ablation.
      widths: ascending degree-class bucket widths; edges wider than
        ``widths[-1]`` land in a final next-pow2 bucket.

    Returns:
      A list of dicts ``{u_lists, v_lists, src, dst, width}``, one per
      non-empty degree-class bucket. ``u_lists``/``v_lists`` are (E_b, W_b)
      int32 numpy arrays of sorted neighbor lists; ``src``/``dst`` are the
      (E_b,) edge endpoints each row belongs to (per-vertex analysis scatters
      through them). Sentinel-padding rule: u rows pad with ``n``, v rows
      with ``n + 1`` (never equal ⇒ padding contributes zero matches); both
      sentinels sort above every real id, keeping rows sorted.
    """
    if variant == "filtered":
        dag = orient_forward(g)
        src = np.repeat(np.arange(dag.n, dtype=np.int32), dag.degrees)
        dst = dag.col_idx
        deg = dag.degrees
        base = dag
    elif variant == "full":
        src = np.repeat(np.arange(g.n, dtype=np.int32), g.degrees)
        dst = g.col_idx
        deg = g.degrees
        base = g
    else:
        raise ValueError(
            f"unknown variant {variant!r}; expected 'filtered' or 'full'"
        )

    buckets = bucket_edges_by_degree(src, dst, deg, widths=widths)
    out = []
    for b in buckets:
        w = b["width"]
        nbrs = csr_to_padded_neighbors(base, pad_to=max(w, 1), fill=g.n)
        u_lists = nbrs[b["src"]]
        v_lists = nbrs[b["dst"]].copy()
        v_lists[v_lists == g.n] = g.n + 1  # disjoint sentinel
        out.append(dict(u_lists=u_lists, v_lists=v_lists,
                        src=b["src"], dst=b["dst"], width=w))
    return out


def choose_block(g: Graph) -> int:
    """Adaptive tile size (§Perf hillclimb, beyond-paper): degree-permuted
    scale-free graphs densify the bottom-right tile cluster, so 128 (MXU
    native) wins; mesh-like graphs (low, uniform degree) never fill tiles —
    measured 40,000× MXU-flop waste and 25× wall-time regression at 128 vs
    32 on road-like — so low-avg-degree graphs get small tiles."""
    avg_deg = 2.0 * g.m_undirected / max(g.n, 1)
    return 128 if avg_deg >= 8.0 else 32


def build_tile_schedule(
    g: Graph, block: int = 128, permute: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Host-side stage of the matrix method: degree permutation + BSR tiling +
    the L/U/A triple schedule.

    Args:
      g: undirected simple ``Graph``.
      block: dense tile edge length B (128 = MXU native).
      permute: apply the degree-order permutation first (the paper's
        tc-matrix step 1).

    Returns:
      (l_tiles, u_tiles, a_tiles, stats): three stacked (T, B, B) float32
      arrays — the L tile, U tile, and A mask tile of each scheduled triple —
      plus a stats dict (num_triples, tile counts, grid, block, tile_flops).
      Triples are sorted heavy-first (by block density product); that order is
      the unit of distribution for multi-device TC (core/distributed.py deals
      it round-robin for static load balance — the TPU analogue of
      merge-path's equal-work splitting).
    """
    if permute:
        perm = degree_order_permutation(g)
        g = apply_permutation(g, perm)
    a_bsr = to_block_sparse(g, block=block, part="upper")  # mask: strict upper
    l_bsr = to_block_sparse(g, block=block, part="lower")
    u_bsr = to_block_sparse(g, block=block, part="upper")

    # block-row index of L: row -> list of (K, tile_id); block-col index of U
    l_rows: dict = {}
    for t in range(l_bsr.num_blocks):
        l_rows.setdefault(int(l_bsr.block_row[t]), []).append(
            (int(l_bsr.block_col[t]), t)
        )
    u_cols: dict = {}
    for t in range(u_bsr.num_blocks):
        u_cols.setdefault(int(u_bsr.block_col[t]), []).append(
            (int(u_bsr.block_row[t]), t)
        )

    trip_l, trip_u, trip_a = [], [], []
    for t in range(a_bsr.num_blocks):
        bi, bj = int(a_bsr.block_row[t]), int(a_bsr.block_col[t])
        lk = dict(l_rows.get(bi, ()))
        uk = dict(u_cols.get(bj, ()))
        for k in lk.keys() & uk.keys():
            trip_a.append(t)
            trip_l.append(lk[k])
            trip_u.append(uk[k])

    T = len(trip_a)
    stats = dict(
        num_triples=T,
        a_tiles=a_bsr.num_blocks,
        l_tiles=l_bsr.num_blocks,
        u_tiles=u_bsr.num_blocks,
        grid=a_bsr.grid,
        block=block,
        tile_flops=2 * T * block**3,
    )
    if T == 0:
        z = np.zeros((0, block, block), dtype=np.float32)
        return z, z, z, stats

    l_sel = l_bsr.blocks[np.asarray(trip_l)]
    u_sel = u_bsr.blocks[np.asarray(trip_u)]
    a_sel = a_bsr.blocks[np.asarray(trip_a)]
    # heavy-first ordering by nnz(L)·nnz(U) so chunked execution and
    # round-robin sharding see a monotone work profile
    work = l_sel.sum(axis=(1, 2)) * u_sel.sum(axis=(1, 2))
    order = np.argsort(-work, kind="stable")
    return l_sel[order], u_sel[order], a_sel[order], stats


@functools.partial(jax.jit, static_argnames=("n",))
def _two_core_peel(src: jnp.ndarray, dst: jnp.ndarray, init_alive: jnp.ndarray, *, n: int):
    """Fixed-point peel: drop vertices whose alive-degree < 2."""

    def cond(state):
        alive, changed = state
        return changed

    def body(state):
        alive, _ = state
        contrib = (alive[src] & alive[dst]).astype(jnp.int32)
        deg = jax.ops.segment_sum(contrib, src, num_segments=n)
        new_alive = alive & (deg >= 2)
        return new_alive, jnp.any(new_alive != alive)

    alive, _ = jax.lax.while_loop(cond, body, (init_alive, jnp.array(True)))
    return alive


def peel_to_two_core(g: Graph, labels: Optional[np.ndarray] = None,
                     query_label: Optional[int] = None) -> np.ndarray:
    """INITIALIZE_CANDIDATE_SET + iterated filter, to fixed point.

    Args:
      g: undirected simple ``Graph``.
      labels: optional (n,) vertex labels for labeled subgraph queries.
      query_label: with ``labels``, prune vertices whose label cannot match
        any query vertex before the degree peel.

    Returns:
      Bool (n,) numpy mask of vertices surviving the 2-core peel (every
      triangle vertex has ≥ 2 alive neighbors, so counting on the induced
      subgraph is exact).
    """
    src = np.repeat(np.arange(g.n, dtype=np.int32), g.degrees)
    dst = g.col_idx
    init = np.ones(g.n, dtype=bool)
    if labels is not None and query_label is not None:
        init &= np.asarray(labels) == query_label
    if g.m_directed == 0:
        return np.zeros(g.n, dtype=bool)
    alive = _two_core_peel(jnp.asarray(src), jnp.asarray(dst),
                           jnp.asarray(init), n=g.n)
    return np.asarray(alive)


# ---------------------------------------------------------------------------
# Executable cache — jit-compiled device programs, shared across plans
# ---------------------------------------------------------------------------

_EXECUTABLE_CACHE: Dict[tuple, Callable] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def _build_intersect_executable(strategy: str, backend: str, interpret: bool,
                                bitmap_bits) -> Callable:
    @jax.jit
    def run(u_lists, v_lists):
        counts = intersect_counts(
            u_lists, v_lists, strategy=strategy, backend=backend,
            interpret=interpret, bitmap_bits=bitmap_bits,
        )
        return jnp.sum(counts)

    return run


def _build_matrix_executable(backend: str, interpret: bool) -> Callable:
    @jax.jit
    def run(l_tiles, u_tiles, a_tiles):
        partials = masked_spgemm_counts(
            l_tiles, u_tiles, a_tiles, backend=backend, interpret=interpret
        )
        return jnp.sum(partials)

    return run


def _build_vertex_executable(n: int) -> Callable:
    """Per-vertex triangle counts for one filtered-intersection bucket.

    A probe-style (searchsorted) membership test marks which u-list entries
    appear in both forward neighbor lists; each match (e, w) is one triangle
    (src[e], dst[e], w), so three segment_sums attribute it to its three
    vertices. Padding never matches (disjoint u/v sentinels), so the clip on
    the scatter ids is safe.
    """

    @jax.jit
    def run(u_lists, v_lists, src, dst):
        def one(u, v):
            pos = jnp.clip(jnp.searchsorted(v, u), 0, v.shape[0] - 1)
            return v[pos] == u

        matched = jax.vmap(one)(u_lists, v_lists)  # (E, W) bool
        per_edge = matched.sum(axis=1, dtype=jnp.int32)
        t = jax.ops.segment_sum(per_edge, src, num_segments=n)
        t = t + jax.ops.segment_sum(per_edge, dst, num_segments=n)
        w_ids = jnp.clip(u_lists.reshape(-1), 0, n - 1)
        t = t + jax.ops.segment_sum(
            matched.reshape(-1).astype(jnp.int32), w_ids, num_segments=n
        )
        return t

    return run


def get_executable(algorithm: str, backend: str, interpret: bool,
                   shape_key: tuple, strategy: Optional[str] = None,
                   bitmap_bits: Optional[int] = None) -> Callable:
    """Fetch (or build) the jitted executable for one statically-shaped work
    unit.

    Args:
      algorithm: "intersection" | "subgraph" (both use the intersection
        executables) | "matrix" | "vertex" (per-vertex triangle counts for
        one filtered bucket — the analysis path ``TriangleCounter`` routes
        through the plan).
      backend: "jnp" | "pallas" | "ref" (see ``repro.kernels.*.ops``).
      interpret: pallas interpret mode flag (part of the key: interpret and
        compiled kernels are distinct executables).
      shape_key: the work unit's static array shape, e.g. one degree bucket's
        (E, W), a tile schedule's (T, B, B), or a vertex stage's (E, W, n).
      strategy: resolved set-intersection strategy ("broadcast" | "probe" |
        "bitmap") for the intersection lanes; None for matrix/vertex.
      bitmap_bits: static packed-bitmap capacity when strategy="bitmap",
        else None.

    Returns:
      A jitted callable reducing the work unit (a scalar count, or an (n,)
      per-vertex vector for "vertex"). Cached process-wide under
      ``(algorithm, strategy, backend, interpret, bitmap_bits, shape)``
      so plans over same-shaped buckets/schedules share the compiled kernel.
    """
    if backend not in ("jnp", "pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected 'jnp', 'pallas', or 'ref'")
    key = (algorithm, strategy, backend, bool(interpret), bitmap_bits,
           tuple(shape_key))
    fn = _EXECUTABLE_CACHE.get(key)
    if fn is not None:
        _CACHE_STATS["hits"] += 1
        return fn
    _CACHE_STATS["misses"] += 1
    if algorithm in ("intersection", "subgraph"):
        if strategy not in STRATEGIES:
            raise ValueError(f"unresolved strategy {strategy!r}; "
                             f"expected one of {STRATEGIES}")
        fn = _build_intersect_executable(strategy, backend, interpret,
                                         bitmap_bits)
    elif algorithm == "matrix":
        fn = _build_matrix_executable(backend, interpret)
    elif algorithm == "vertex":
        fn = _build_vertex_executable(int(shape_key[-1]))
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    _EXECUTABLE_CACHE[key] = fn
    return fn


def executable_cache_info() -> dict:
    """{'size': ..., 'hits': ..., 'misses': ...} for tests and benchmarks."""
    return dict(size=len(_EXECUTABLE_CACHE), **_CACHE_STATS)


def clear_executable_cache() -> None:
    _EXECUTABLE_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


# ---------------------------------------------------------------------------
# TrianglePlan — the device-resident, replayable count
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Stage:
    executable: Callable
    args: Tuple[jnp.ndarray, ...]  # device-resident
    shape_key: tuple
    strategy: Optional[str] = None  # resolved intersection strategy
    bitmap_bits: Optional[int] = None  # packed capacity when strategy="bitmap"
    # (src, dst) edge endpoints, device-resident — filtered intersection
    # stages only; lets the per-vertex analysis path replay the same buffers
    vertex_args: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None


@dataclasses.dataclass
class TrianglePlan:
    """A prepared triangle count: device buffers + compiled executables.

    ``count()`` replays the device stage only — no host-side numpy runs after
    construction (tests verify this by poisoning the prep helpers). Build via
    ``plan_triangle_count``.
    """

    algorithm: str
    backend: str
    interpret: bool
    stages: List[_Stage]
    divisor: int  # 6 for the full-variant intersection (each triangle ×6)
    meta: Dict[str, Any]
    prep_seconds: float
    executions: int = 0

    def count(self) -> int:
        """Exact triangle count; pure device replay of the cached stages."""
        if self.algorithm == "matrix":
            total_f = 0.0
            for st in self.stages:
                total_f += float(st.executable(*st.args))
            total = int(round(total_f))
        else:
            total = 0
            for st in self.stages:
                total += int(st.executable(*st.args))
        if self.divisor != 1:
            assert total % self.divisor == 0, total
            total //= self.divisor
        self.executions += 1
        return total

    def count_with_stats(self) -> Tuple[int, dict]:
        """Count once and return the plan's prep statistics alongside.

        Returns:
          (count, meta): meta carries statistics gathered at plan time —
          prune fractions, tile schedule sizes, bucket shapes, and on the
          intersection/subgraph lanes ``bucket_strategies``: one
          ``(width, strategy)`` pair per degree bucket as resolved by the
          ``strategy="auto"`` cost model (or the per-plan override).
        """
        c = self.count()
        stats = dict(self.meta)
        if self.algorithm == "subgraph":
            stats["num_embeddings"] = 6 * c
        return c, stats

    def triangles_per_vertex(self) -> np.ndarray:
        """Per-vertex triangle counts, replayed through this plan's cached
        device buffers (the analysis path ``repro.core.api.TriangleCounter``
        routes here instead of the host-side enumeration in ``listing.py``).

        Supported on plans whose stages carry edge endpoints — the filtered
        intersection lane and the subgraph lane (whose counts on the pruned
        graph scatter back through ``meta["vertex_map"]``; peeled vertices
        are in no triangle by construction).

        Returns:
          (n,) int64 numpy array, t[v] = number of triangles containing v.

        Raises:
          NotImplementedError: matrix lane or the full intersection variant
            (no per-edge endpoints to attribute matches to); callers fall
            back to a filtered-intersection sidecar plan.
        """
        if self.algorithm not in ("intersection", "subgraph") \
                or self.divisor != 1 \
                or any(st.vertex_args is None for st in self.stages):
            raise NotImplementedError(
                f"per-vertex counts need filtered-intersection stages; "
                f"algorithm={self.algorithm!r} divisor={self.divisor} does "
                f"not carry them"
            )
        n_local = int(self.meta.get("vertex_n", self.meta["n"]))
        total = np.zeros(n_local, dtype=np.int64)
        for st in self.stages:
            e, w = st.shape_key
            fn = get_executable("vertex", "jnp", False, (e, w, n_local))
            total += np.asarray(fn(*st.args, *st.vertex_args), dtype=np.int64)
        vertex_map = self.meta.get("vertex_map")
        if vertex_map is not None:  # subgraph lane: pruned ids -> original
            out = np.zeros(int(self.meta["n"]), dtype=np.int64)
            out[np.asarray(vertex_map)] = total
            return out
        return total

    def block_until_ready(self) -> "TrianglePlan":
        """Force all device buffers resident (useful before timing counts)."""
        for st in self.stages:
            for a in st.args:
                a.block_until_ready()
        return self

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def shape_keys(self) -> List[tuple]:
        return [st.shape_key for st in self.stages]


def _plan_intersection(g: Graph, variant: str, backend: str, interpret: bool,
                       widths: Sequence[int], strategy: str = "auto",
                       bitmap_bits: Optional[int] = None,
                       ) -> Tuple[List[_Stage], int, dict]:
    buckets = prepare_intersection_buckets(g, variant=variant, widths=widths)
    # id range covers real vertex ids [0, n) plus the in-row padding
    # sentinels n (u rows) and n+1 (v rows)
    id_range = g.n + 2
    stages = []
    for b in buckets:
        shape_key = tuple(b["u_lists"].shape)
        strat, bits = resolve_strategy(b["width"], id_range, strategy=strategy)
        if bitmap_bits is not None and strat == "bitmap":
            if bitmap_bits < id_range:
                raise ValueError(
                    f"bitmap_bits={bitmap_bits} cannot represent id range "
                    f"{id_range} (n + 2 sentinel ids); ids past the capacity "
                    f"would silently never match"
                )
            bits = int(bitmap_bits)
        fn = get_executable("intersection", backend, interpret, shape_key,
                            strategy=strat, bitmap_bits=bits)
        vertex_args = None
        if variant == "filtered":
            vertex_args = (jnp.asarray(b["src"]), jnp.asarray(b["dst"]))
        stages.append(_Stage(
            executable=fn,
            args=(jnp.asarray(b["u_lists"]), jnp.asarray(b["v_lists"])),
            shape_key=shape_key,
            strategy=strat,
            bitmap_bits=bits,
            vertex_args=vertex_args,
        ))
    meta = dict(
        variant=variant,
        widths=tuple(widths),
        strategy=strategy,
        bucket_shapes=[s.shape_key for s in stages],
        bucket_strategies=[(s.shape_key[1], s.strategy) for s in stages],
        edges=int(sum(s.shape_key[0] for s in stages)),
    )
    return stages, (6 if variant == "full" else 1), meta


def _plan_matrix(g: Graph, block, permute: bool, backend: str,
                 interpret: bool) -> Tuple[List[_Stage], int, dict]:
    if block == "auto":
        block = choose_block(g)
    l_sel, u_sel, a_sel, stats = build_tile_schedule(
        g, block=block, permute=permute
    )
    stages = []
    if l_sel.shape[0]:
        shape_key = tuple(l_sel.shape)
        fn = get_executable("matrix", backend, interpret, shape_key)
        stages.append(_Stage(
            executable=fn,
            args=(jnp.asarray(l_sel), jnp.asarray(u_sel), jnp.asarray(a_sel)),
            shape_key=shape_key,
        ))
    meta = dict(permute=permute, **stats)
    return stages, 1, meta


def _plan_subgraph(g: Graph, backend: str, interpret: bool,
                   widths: Sequence[int], strategy: str = "auto",
                   bitmap_bits: Optional[int] = None,
                   ) -> Tuple[List[_Stage], int, dict]:
    alive = peel_to_two_core(g)
    sub, old_ids = induced_subgraph(g, alive)
    # join on the pruned graph; forward-filtered intersection counts each
    # triangle once (embeddings = 6 × that)
    stages, _, inner = _plan_intersection(
        sub, variant="filtered", backend=backend, interpret=interpret,
        widths=widths, strategy=strategy, bitmap_bits=bitmap_bits,
    )
    # subgraph stages share the intersection executables by construction
    meta = dict(
        vertices_pruned=int(g.n - alive.sum()),
        prune_fraction=float(1.0 - alive.sum() / max(g.n, 1)),
        edges_after=sub.m_undirected,
        edges_before=g.m_undirected,
        # per-vertex analysis: stage counts are on the pruned graph's ids;
        # scatter back through old_ids (peeled vertices hold no triangles)
        vertex_n=sub.n,
        vertex_map=np.asarray(old_ids),
        **inner,
    )
    return stages, 1, meta


def plan_triangle_count(
    g: Graph,
    algorithm: str = "intersection",
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
    block="auto",
    permute: bool = True,
    bitmap_bits: Optional[int] = None,
) -> TrianglePlan:
    """Run the host stage once and return a device-resident ``TrianglePlan``.

    Args:
      g: the input ``Graph`` (undirected simple CSR).
      algorithm: "intersection" | "matrix" | "subgraph".
      backend: "jnp" | "pallas" | "ref" per-kernel execution path.
      interpret: pallas interpret mode (True runs kernel bodies on CPU);
        None (default) resolves to ``repro.core.options.DEFAULT_INTERPRET``
        (the ``TC_INTERPRET`` env var, unset ⇒ True).
      variant: intersection lane only — "filtered" (forward algorithm) or
        "full" (every directed edge, each triangle found 6×).
      widths: degree-class bucket widths for the intersection/subgraph lanes.
      strategy: intersection/subgraph lanes only — per-bucket set-intersection
        core: "auto" (default; the documented ``choose_strategy`` cost model
        picks bitmap/probe/broadcast per bucket) or a forced "broadcast" |
        "probe" | "bitmap" override applied to every bucket.
      block: matrix lane tile size, or "auto" (``choose_block``).
      permute: matrix lane degree permutation toggle.
      bitmap_bits: optional forced packed capacity for bitmap-strategy
        buckets (must cover the graph's id range ``n + 2``); None sizes it
        via ``resolve_strategy``.

    Returns:
      A ``TrianglePlan`` whose ``count()`` replays the device stage only.
      The per-algorithm keyword arguments match ``CountOptions``; the
      facade (``repro.core.api.TriangleCounter``) and the deprecated
      one-shot ``triangle_count_*`` shims both route here.
    """
    interpret = resolve_interpret(interpret)
    t0 = time.perf_counter()
    if algorithm == "intersection":
        stages, divisor, meta = _plan_intersection(
            g, variant, backend, interpret, widths, strategy, bitmap_bits
        )
    elif algorithm == "matrix":
        stages, divisor, meta = _plan_matrix(g, block, permute, backend, interpret)
    elif algorithm == "subgraph":
        stages, divisor, meta = _plan_subgraph(g, backend, interpret, widths,
                                               strategy, bitmap_bits)
    else:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; expected one of {ALGORITHMS}"
        )
    meta.setdefault("graph", g.name)
    meta["n"], meta["m"] = g.n, g.m_undirected
    prep_seconds = time.perf_counter() - t0
    return TrianglePlan(
        algorithm=algorithm,
        backend=backend,
        interpret=interpret,
        stages=stages,
        divisor=divisor,
        meta=meta,
        prep_seconds=prep_seconds,
    )
