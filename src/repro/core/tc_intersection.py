"""Set-intersection (forward-algorithm) triangle counting — the paper's
best-performing method (§3.2/§4.2), adapted to TPU execution.

Pipeline (host numpy prep → JAX compute):

  1. FORM_FILTERED_EDGE_LIST — ``orient_forward`` keeps u→v iff
     rank(u) < rank(v) with rank = (degree, id): the paper's "filter out half
     of the edges by degree order". The oriented graph's rows ARE the induced
     subgraph's neighbor lists, so the "reform the induced subgraph" step
     (removes a further ~⅔ of scanned work) falls out of the same structure.
  2. Degree-class bucketing — the TPU replacement for TwoSmall/TwoLarge
     dynamic grouping: each bucket is a statically-shaped (E_b, W_b) problem.
  3. COMPUTE_INTERSECTION — per bucket, one batched intersection kernel call
     fused with its reduction in a single traced computation.

``variant="full"`` reproduces the paper's tc-intersection-full ablation
(intersect over ALL directed edges with full neighbor lists; each triangle is
then found 6×), so benchmarks can measure exactly what the filtering buys.

This module registers the ``"intersection"`` lane with the algorithm registry
(:mod:`repro.core.registry`); the front door is
``TriangleCounter(g, CountOptions(algorithm="intersection", ...))``. The
one-shot ``triangle_count_intersection`` below is a deprecated shim kept for
source compatibility.
"""

from __future__ import annotations

from typing import Optional

from repro.graphs.formats import Graph
from repro.core.engine import (
    DEFAULT_WIDTHS,
    plan_triangle_count,
    prepare_intersection_buckets,  # re-export (prep lives in repro.core.prep;
    # the plan stage runs the device-resident pipeline by default)
)
from repro.core.registry import register_algorithm

__all__ = ["triangle_count_intersection", "prepare_intersection_buckets"]


def _planner(g: Graph, options, *, mesh=None):
    """Registry planner: CountOptions → intersection-lane TrianglePlan."""
    return plan_triangle_count(
        g, "intersection", **options.plan_kwargs("intersection")
    )


register_algorithm("intersection", _planner)


def triangle_count_intersection(
    g: Graph,
    *,
    variant: str = "filtered",
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    widths=DEFAULT_WIDTHS,
    strategy: str = "auto",
) -> int:
    """Deprecated shim: exact triangle count via batched set intersection.

    Use ``TriangleCounter(g, CountOptions(algorithm="intersection", ...))``
    instead. Keyword arguments map 1:1 onto ``CountOptions`` fields
    (``interpret=None`` now means the process-wide ``DEFAULT_INTERPRET``).

    Returns:
      The exact triangle count as a Python int (unchanged behavior).
    """
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_intersection(g, ...)",
        'TriangleCounter(g, CountOptions(algorithm="intersection", ...)).count()',
    )
    opts = CountOptions(
        algorithm="intersection", variant=variant, backend=backend,
        interpret=interpret, widths=tuple(widths), strategy=strategy,
    )
    return int(TriangleCounter(g, opts).count())
