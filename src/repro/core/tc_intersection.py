"""Set-intersection (forward-algorithm) triangle counting — the paper's
best-performing method (§3.2/§4.2), adapted to TPU execution.

Pipeline (host numpy prep → JAX compute):

  1. FORM_FILTERED_EDGE_LIST — ``orient_forward`` keeps u→v iff
     rank(u) < rank(v) with rank = (degree, id): the paper's "filter out half
     of the edges by degree order". The oriented graph's rows ARE the induced
     subgraph's neighbor lists, so the "reform the induced subgraph" step
     (removes a further ~⅔ of scanned work) falls out of the same structure.
  2. Degree-class bucketing — the TPU replacement for TwoSmall/TwoLarge
     dynamic grouping: each bucket is a statically-shaped (E_b, W_b) problem.
  3. COMPUTE_INTERSECTION — per bucket, one batched intersection kernel call
     fused with its reduction in a single traced computation.

``variant="full"`` reproduces the paper's tc-intersection-full ablation
(intersect over ALL directed edges with full neighbor lists; each triangle is
then found 6×), so benchmarks can measure exactly what the filtering buys.

This module is a thin wrapper over the plan/execute engine
(:mod:`repro.core.engine`): one-shot counting builds a ``TrianglePlan`` and
executes it once. Hold the plan (``plan_triangle_count``) to amortize the
host stage across repeated counts.
"""

from __future__ import annotations

from repro.graphs.formats import Graph
from repro.core.engine import (
    DEFAULT_WIDTHS,
    plan_triangle_count,
    prepare_intersection_buckets,  # re-export (prep now lives in the engine)
)

__all__ = ["triangle_count_intersection", "prepare_intersection_buckets"]


def triangle_count_intersection(
    g: Graph,
    *,
    variant: str = "filtered",
    backend: str = "jnp",
    interpret: bool = True,
    widths=DEFAULT_WIDTHS,
    strategy: str = "auto",
) -> int:
    """Exact triangle count via batched set intersection.

    Args:
      g: undirected simple ``Graph``.
      variant: "filtered" — forward algorithm (each triangle counted once);
        "full" — Green-et-al.-style full edge list (counted 6×).
      backend: "jnp" (pure-jnp cores), "pallas" (TPU kernels), "ref" (oracle).
      interpret: pallas interpret mode.
      widths: degree-class bucket widths.
      strategy: per-bucket set-intersection core — "auto" (default cost
        model) or forced "broadcast" | "probe" | "bitmap"; see
        ``repro.kernels.intersect.ops``.

    Returns:
      The exact triangle count as a Python int.
    """
    plan = plan_triangle_count(
        g, "intersection", variant=variant, backend=backend,
        interpret=interpret, widths=widths, strategy=strategy,
    )
    return plan.count()
