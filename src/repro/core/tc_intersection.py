"""Set-intersection (forward-algorithm) triangle counting — the paper's
best-performing method (§3.2/§4.2), adapted to TPU execution.

Pipeline (host numpy prep → JAX compute):

  1. FORM_FILTERED_EDGE_LIST — ``orient_forward`` keeps u→v iff
     rank(u) < rank(v) with rank = (degree, id): the paper's "filter out half
     of the edges by degree order". The oriented graph's rows ARE the induced
     subgraph's neighbor lists, so the "reform the induced subgraph" step
     (removes a further ~⅔ of scanned work) falls out of the same structure.
  2. Degree-class bucketing — the TPU replacement for TwoSmall/TwoLarge
     dynamic grouping: each bucket is a statically-shaped (E_b, W_b) problem.
  3. COMPUTE_INTERSECTION — per bucket, one batched intersection kernel call
     (Pallas or jnp binary-probe), then a single reduction.

``variant="full"`` reproduces the paper's tc-intersection-full ablation
(intersect over ALL directed edges with full neighbor lists; each triangle is
then found 6×), so benchmarks can measure exactly what the filtering buys.
"""

from __future__ import annotations

from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.graphs.formats import (
    Graph,
    bucket_edges_by_degree,
    csr_to_padded_neighbors,
    orient_forward,
)
from repro.kernels.intersect.ops import intersect_counts

__all__ = ["triangle_count_intersection", "prepare_intersection_buckets"]


def prepare_intersection_buckets(
    g: Graph,
    variant: str = "filtered",
    widths=(8, 32, 128, 512),
):
    """Host-side stage: orientation + bucketing + padded gathering.

    Returns a list of dicts {u_lists, v_lists} of jnp-ready numpy arrays,
    one per degree-class bucket. Sentinels: u rows pad with n, v rows with
    n+1 (never equal ⇒ padding contributes zero matches).
    """
    if variant == "filtered":
        dag = orient_forward(g)
        src = np.repeat(np.arange(dag.n, dtype=np.int32), dag.degrees)
        dst = dag.col_idx
        deg = dag.degrees
        base = dag
    elif variant == "full":
        src = np.repeat(np.arange(g.n, dtype=np.int32), g.degrees)
        dst = g.col_idx
        deg = g.degrees
        base = g
    else:
        raise ValueError(variant)

    buckets = bucket_edges_by_degree(src, dst, deg, widths=widths)
    out = []
    for b in buckets:
        w = b["width"]
        nbrs = csr_to_padded_neighbors(base, pad_to=max(w, 1), fill=g.n)
        u_lists = nbrs[b["src"]]
        v_lists = nbrs[b["dst"]].copy()
        v_lists[v_lists == g.n] = g.n + 1  # disjoint sentinel
        out.append(dict(u_lists=u_lists, v_lists=v_lists, width=w))
    return out


def triangle_count_intersection(
    g: Graph,
    *,
    variant: str = "filtered",
    backend: str = "jnp",
    interpret: bool = True,
    widths=(8, 32, 128, 512),
) -> int:
    """Exact triangle count via batched set intersection.

    variant="filtered": forward algorithm (each triangle counted once).
    variant="full":     Green-et-al.-style full edge list (counted 6×).
    backend: "jnp" (binary probe), "pallas" (TPU kernel), "ref" (oracle).
    """
    buckets = prepare_intersection_buckets(g, variant=variant, widths=widths)
    total = 0
    for b in buckets:
        counts = intersect_counts(
            jnp.asarray(b["u_lists"]),
            jnp.asarray(b["v_lists"]),
            backend=backend,
            interpret=interpret,
        )
        total += int(jnp.sum(counts))
    if variant == "full":
        assert total % 6 == 0, total
        return total // 6
    return total
