"""Subgraph-matching triangle counting (paper §3.1/§4.1) — a filtering-and-
joining pipeline in the style of Tran et al., with the paper's optimizations.

FILTER (Gunrock Advance/Filter analogue → JAX):
  Candidate vertices must satisfy the triangle query's degree (≥2) and label
  constraints. The paper iterates filter+reconstruct "for a few iterations to
  prune out more edges"; taken to its fixed point that is exactly a 2-core
  peel, which runs as a `lax.while_loop` over a static edge list (no dynamic
  shapes; `segment_sum` plays the role of the Advance frontier) — see
  :func:`repro.core.engine.peel_to_two_core`. This is what wins on mesh-like
  graphs — leaf cascades collapse.

RECONSTRUCT: the surviving vertex mask reforms the induced subgraph on the
  host (the paper's 'reconstructing the data graph updates node degree and
  neighbor list information').

JOIN: candidate edges are joined under the triangle's intersection rule —
  matches(e=(u,v)) = |N(u) ∩ N(v) ∩ alive|, evaluated with the same bucketed
  batch-intersection kernels as tc_intersection (the paper's joining also
  reduces to verification-by-intersection). The join produces *embeddings*
  (all 6 automorphisms per triangle, as a real subgraph matcher must);
  ``triangle_count_subgraph`` divides by |Aut(K₃)| = 6.

This module registers the ``"subgraph"`` lane with the algorithm registry;
the front door is ``TriangleCounter(g, CountOptions(algorithm="subgraph"))``
(filter + reconstruct + bucket setup run once at plan time, the join replays
on device). The one-shot ``triangle_count_subgraph`` below is a deprecated
shim. ``subgraph_match_triangle`` handles labeled queries, which carry
per-query candidate-edge masks and so stay one-shot.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graphs.formats import Graph, induced_subgraph
from repro.core.prep import _two_core_peel
from repro.core.engine import (
    peel_to_two_core,  # re-export (prep lives in repro.core.prep)
    plan_triangle_count,
)
from repro.core.options import resolve_interpret
from repro.core.registry import register_algorithm

__all__ = [
    "peel_to_two_core",
    "triangle_count_subgraph",
    "subgraph_match_triangle",
]


def _planner(g: Graph, options, *, mesh=None):
    """Registry planner: CountOptions → subgraph-lane TrianglePlan."""
    return plan_triangle_count(g, "subgraph", **options.plan_kwargs("subgraph"))


register_algorithm("subgraph", _planner)


def triangle_count_subgraph(
    g: Graph,
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    return_stats: bool = False,
):
    """Deprecated shim: exact TC via filter(2-core-peel) + reform + join.

    Use ``TriangleCounter(g, CountOptions(algorithm="subgraph", ...))``
    instead — ``CountResult.meta`` carries the stats ``return_stats=True``
    returns here. ``interpret=None`` now means the process-wide
    ``DEFAULT_INTERPRET``. Return values are unchanged: an int, or
    ``(int, stats dict)`` with ``return_stats=True``.
    """
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_subgraph(g, ...)",
        'TriangleCounter(g, CountOptions(algorithm="subgraph", ...)).count()',
    )
    opts = CountOptions(algorithm="subgraph", backend=backend,
                        interpret=interpret)
    result = TriangleCounter(g, opts).count()
    if return_stats:
        meta = result.meta
        stats = dict(
            vertices_pruned=meta["vertices_pruned"],
            prune_fraction=meta["prune_fraction"],
            edges_after=meta["edges_after"],
            edges_before=meta["edges_before"],
            num_embeddings=meta["num_embeddings"],
        )
        return result.count, stats
    return result.count


def subgraph_match_triangle(
    g: Graph,
    labels: np.ndarray,
    query_labels: Tuple[int, int, int],
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
) -> int:
    """Count embeddings of a *labeled* triangle query (the generality the
    paper highlights for the SM formulation: 'find the embeddings of triangles
    with certain label patterns').

    ``interpret=None`` resolves to the process-wide ``DEFAULT_INTERPRET``.

    Returns the number of ordered embeddings (u,v,w) with labels matching
    (q0,q1,q2) and {u,v},{v,w},{u,w} ∈ E.
    """
    interpret = resolve_interpret(interpret)
    labels = np.asarray(labels)
    q0, q1, q2 = query_labels
    # candidate vertices: label in query labels, degree ≥ 2, 2-core
    cand = np.isin(labels, list(query_labels))
    src = np.repeat(np.arange(g.n, dtype=np.int32), g.degrees)
    dst = g.col_idx
    if g.m_directed == 0:
        return 0
    alive = np.asarray(
        _two_core_peel(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(cand), n=g.n)
    )
    sub, old_ids = induced_subgraph(g, alive)
    if sub.m_directed == 0:
        return 0
    sl = labels[old_ids]
    # candidate edges for query edge (q0,q1); join rule: w labeled q2
    s_src = np.repeat(np.arange(sub.n, dtype=np.int32), sub.degrees)
    s_dst = sub.col_idx
    e_keep = (sl[s_src] == q0) & (sl[s_dst] == q1)
    if not e_keep.any():
        return 0
    from repro.graphs.formats import bucket_edges_by_degree, csr_to_padded_neighbors
    from repro.core.engine import get_executable, resolve_strategy

    # restrict intersected neighbor ids to label-q2 vertices by remapping
    # non-q2 neighbors to a sentinel on the u side only (so they never match)
    buckets = bucket_edges_by_degree(s_src[e_keep], s_dst[e_keep], sub.degrees)
    total = 0
    q2_ok = sl == q2
    for b in buckets:
        nbrs = csr_to_padded_neighbors(sub, pad_to=b["width"], fill=sub.n)
        u_lists = nbrs[b["src"]].copy()
        v_lists = nbrs[b["dst"]].copy()
        valid = (u_lists < sub.n) & q2_ok[np.clip(u_lists, 0, sub.n - 1)]
        u_lists[~valid] = sub.n
        v_lists[v_lists == sub.n] = sub.n + 1
        # same per-bucket dispatch as the unlabeled lanes (id range covers
        # real ids plus the n / n+1 sentinels)
        strat, bits = resolve_strategy(b["width"], sub.n + 2)
        run = get_executable(
            "intersection", backend, interpret, tuple(u_lists.shape),
            strategy=strat, bitmap_bits=bits,
        )
        total += int(run(jnp.asarray(u_lists), jnp.asarray(v_lists)))
    return total
