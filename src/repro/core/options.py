"""Typed options for the triangle-counting front door.

``CountOptions`` consolidates every tuning knob that used to be scattered as
free-function kwargs (``algorithm``, ``variant``, ``backend``, ``interpret``,
``strategy``, ``widths``, ``block``, ``permute``, ``bitmap_bits``) into one
frozen, validated, hashable dataclass. The engine's process-wide executable
cache is keyed by fields derived from these options (see
``docs/ARCHITECTURE.md`` §Executable-cache keying rules), so *equal options
imply equal cache keys*: two ``TriangleCounter`` sessions built from equal
``CountOptions`` over same-shaped graphs share every compiled executable.

``DEFAULT_INTERPRET`` is the single source of truth for the pallas
interpret-mode default. It is resolved ONCE at import from the
``TC_INTERPRET`` environment variable (unset ⇒ ``True``, the CPU-safe
default; ``TC_INTERPRET=0`` ⇒ ``False`` for real-accelerator runs), replacing
the per-function ``interpret=True`` defaults that made real-GPU runs pay
interpreter mode by accident. Every entry point now takes ``interpret=None``
meaning "use ``DEFAULT_INTERPRET``"; pass an explicit bool to override.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple, Union

from repro.graphs.device import (
    DEFAULT_SHAPE_POLICY,
    EDGE_KEY_MODES,
    ShapePolicy,
)

__all__ = [
    "BACKENDS",
    "CHOOSERS",
    "CountOptions",
    "DEFAULT_INTERPRET",
    "DEFAULT_WIDTHS",
    "PREP_BACKENDS",
    "VARIANTS",
    "resolve_interpret",
]

DEFAULT_WIDTHS: Tuple[int, ...] = (8, 32, 128, 512)

VARIANTS = ("filtered", "full")
BACKENDS = ("jnp", "pallas", "ref")
PREP_BACKENDS = ("device", "host")
CHOOSERS = ("heuristic", "measured")

_FALSY = ("0", "false", "no", "off", "")


def _resolve_default_interpret() -> bool:
    """Read ``TC_INTERPRET`` once; unset means True (CPU-safe)."""
    raw = os.environ.get("TC_INTERPRET")
    if raw is None:
        return True
    return raw.strip().lower() not in _FALSY


DEFAULT_INTERPRET: bool = _resolve_default_interpret()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None ⇒ the process-wide ``DEFAULT_INTERPRET``; else the explicit bool."""
    return DEFAULT_INTERPRET if interpret is None else bool(interpret)


@dataclasses.dataclass(frozen=True)
class CountOptions:
    """Every knob of a triangle count, validated at construction.

    Attributes:
      algorithm: "auto" (cross-lane cost model, see
        ``repro.core.registry.choose_algorithm``) or a registered lane name —
        "intersection" | "matrix" | "subgraph" | "hash" (TRUST-style
        vertex-centric hashing) | "bfs" (level-ordered wedge closure) |
        "edge" (per-edge support / k-truss) | "intersection_distributed" |
        "matrix_distributed".
      chooser: how ``algorithm="auto"`` resolves — "heuristic" (default:
        the hand-written shape rules on
        ``repro.core.registry._default_chooser``) or "measured" (the
        per-device calibration table from ``repro.core.calibrate``:
        feature-binned lane timings, analytically seeded from executable
        pricing when no measurement exists, falling back to the heuristic
        on a table miss). Ignored when ``algorithm`` names a lane.
      variant: intersection lane — "filtered" (forward algorithm, each
        triangle once) or "full" (every directed edge, found 6×).
      backend: "jnp" | "pallas" | "ref" per-kernel execution path.
      interpret: pallas interpret mode; None (default) resolves to
        ``DEFAULT_INTERPRET`` (the ``TC_INTERPRET`` env var).
      strategy: intersection/subgraph lanes — per-bucket set-intersection
        core: "auto" (documented cost model) or forced "broadcast" |
        "probe" | "bitmap".
      widths: ascending degree-class bucket widths for the
        intersection/subgraph lanes.
      block: matrix lane tile size (int) or "auto" (``choose_block``).
      permute: matrix lane degree-permutation toggle.
      bitmap_bits: optional forced packed-bitmap capacity (multiple of 32)
        for bitmap-strategy buckets; None (default) sizes it from the
        bucket's id range via ``resolve_strategy``.
      prep_backend: where the intersection/subgraph/edge plan stage runs —
        "device" (default: the jitted prep in ``repro.core.prep`` /
        ``repro.graphs.device``) or "host" (the numpy parity path). The
        matrix lane's tile schedule is host-side either way.
      max_peel_iters: edge lane — upper bound on k-truss peel rounds
        (support recompute → filter → re-orient); the peel normally stops
        at the fixpoint long before. Folded into the edge executables'
        cache key, so equal options share cached edge executables and
        unequal peel knobs miss.
      peel_early_exit: edge lane — stop the peel as soon as a round removes
        no edge (the default). False runs exactly ``max_peel_iters`` rounds
        (the fixpoint is stable under further rounds, so the result is
        identical) — a steady-state benchmarking mode. Also part of the
        edge executables' cache key.
      shape_policy: the ``ShapePolicy`` rounding data-dependent prep extents
        into static shape classes; None (default) means
        ``DEFAULT_SHAPE_POLICY`` (pow2 rounding). Part of the cache key:
        same-policy graphs share traced prep stages and counting
        executables, which is what makes ``count_many`` batchable.
      update_batch_size: dynamic lane — how many normalized edge updates one
        device step applies; larger update lists are chunked. The policy
        rounds it to the delta executables' static row extent, so it is part
        of the dynamic lane's shape classes (and of ``key()``).
      recount_interval: dynamic lane — run the full-recount parity oracle
        every this many applied update batches and assert the incremental
        count matches bit-exactly (the drift assertion). 0 disables the
        periodic oracle (``recount()`` stays available on demand).
      key_mode: packed-edge-key capacity mode for the lanes that address
        vertex pairs as ``a * (n + 1) + b`` keys (edge/k-truss, dynamic,
        ``DeviceCSR.from_edges``): "auto" (default) takes the int32 fast
        path while ``fits_int32_pair_keys(n)`` holds and promotes to the
        wide (x64 int64) mode past it; "int32" forces the fast path
        (raising ``GraphTooLargeError`` past the bound); "wide" forces
        int64 keys. See ``repro.graphs.device.resolve_edge_key_mode`` —
        the repo's single capacity checkpoint.
      max_device_bytes: optional per-bucket device-bytes budget for the
        intersection/subgraph/matrix lanes. ``None`` (default) plans every
        bucket monolithically; an int budget makes the engine STREAM any
        bucket whose device arrays would exceed it through the same cached
        executables chunk-by-chunk (pow2 chunk rows ⇒ monotone chunk shape
        classes, zero steady-state recompiles), accumulating partial counts
        on host — graceful degradation instead of OOM. Counts are
        bit-identical to the monolithic path.

    Frozen ⇒ hashable: equal options hash equal, and the engine's
    executable-cache keys are functions of these fields, so equal options
    share cached executables. ``key()`` returns the normalized hashable
    tuple (with ``interpret=None`` and ``shape_policy=None`` resolved) used
    wherever options participate in a cache key.
    """

    algorithm: str = "auto"
    chooser: str = "heuristic"
    variant: str = "filtered"
    backend: str = "jnp"
    interpret: Optional[bool] = None
    strategy: str = "auto"
    widths: Tuple[int, ...] = DEFAULT_WIDTHS
    block: Union[int, str] = "auto"
    permute: bool = True
    bitmap_bits: Optional[int] = None
    prep_backend: str = "device"
    shape_policy: Optional[ShapePolicy] = None
    max_peel_iters: int = 1000
    peel_early_exit: bool = True
    update_batch_size: int = 256
    recount_interval: int = 64
    key_mode: str = "auto"
    max_device_bytes: Optional[int] = None

    def __post_init__(self):
        # normalize widths to a tuple of ints so the dataclass stays hashable
        try:
            widths = tuple(int(w) for w in self.widths)
        except TypeError:
            raise ValueError(f"widths must be an iterable of ints, "
                             f"got {self.widths!r}") from None
        object.__setattr__(self, "widths", widths)

        if self.algorithm != "auto":
            from repro.core.registry import available_algorithms
            names = available_algorithms()
            if self.algorithm not in names:
                raise ValueError(
                    f"unknown algorithm {self.algorithm!r}; expected 'auto' "
                    f"or one of {names}"
                )
        if self.chooser not in CHOOSERS:
            raise ValueError(
                f"unknown chooser {self.chooser!r}; expected one of {CHOOSERS}"
            )
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown variant {self.variant!r}; expected one of {VARIANTS}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKENDS}"
            )
        if self.interpret is not None and not isinstance(self.interpret, bool):
            raise ValueError(
                f"interpret must be None or a bool, got {self.interpret!r}"
            )
        from repro.kernels.intersect.ops import BITMAP_MAX_BITS, STRATEGIES
        if self.strategy != "auto" and self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; expected 'auto' or one "
                f"of {STRATEGIES}"
            )
        if not widths or any(w <= 0 for w in widths) or \
                any(a >= b for a, b in zip(widths, widths[1:])):
            raise ValueError(
                f"widths must be non-empty, positive, strictly ascending; "
                f"got {widths}"
            )
        if self.block != "auto":
            if not isinstance(self.block, int) or isinstance(self.block, bool) \
                    or self.block <= 0:
                raise ValueError(
                    f"block must be a positive int or 'auto', got {self.block!r}"
                )
        if not isinstance(self.permute, bool):
            raise ValueError(f"permute must be a bool, got {self.permute!r}")
        if self.bitmap_bits is not None:
            b = self.bitmap_bits
            if not isinstance(b, int) or isinstance(b, bool) or b <= 0 \
                    or b % 32 or b > BITMAP_MAX_BITS:
                raise ValueError(
                    f"bitmap_bits must be a positive multiple of 32 ≤ "
                    f"{BITMAP_MAX_BITS}, got {b!r}"
                )
        if self.prep_backend not in PREP_BACKENDS:
            raise ValueError(
                f"unknown prep_backend {self.prep_backend!r}; expected one "
                f"of {PREP_BACKENDS}"
            )
        if self.shape_policy is not None and \
                not isinstance(self.shape_policy, ShapePolicy):
            raise ValueError(
                f"shape_policy must be None or a ShapePolicy, "
                f"got {self.shape_policy!r}"
            )
        if not isinstance(self.max_peel_iters, int) \
                or isinstance(self.max_peel_iters, bool) \
                or self.max_peel_iters < 1:
            raise ValueError(
                f"max_peel_iters must be a positive int, "
                f"got {self.max_peel_iters!r}"
            )
        if not isinstance(self.peel_early_exit, bool):
            raise ValueError(
                f"peel_early_exit must be a bool, got {self.peel_early_exit!r}"
            )
        if not isinstance(self.update_batch_size, int) \
                or isinstance(self.update_batch_size, bool) \
                or self.update_batch_size < 1:
            raise ValueError(
                f"update_batch_size must be a positive int, "
                f"got {self.update_batch_size!r}"
            )
        if not isinstance(self.recount_interval, int) \
                or isinstance(self.recount_interval, bool) \
                or self.recount_interval < 0:
            raise ValueError(
                f"recount_interval must be a non-negative int (0 disables "
                f"the periodic oracle), got {self.recount_interval!r}"
            )
        if self.key_mode not in EDGE_KEY_MODES:
            raise ValueError(
                f"unknown key_mode {self.key_mode!r}; expected one of "
                f"{EDGE_KEY_MODES}"
            )
        if self.max_device_bytes is not None:
            b = self.max_device_bytes
            if not isinstance(b, int) or isinstance(b, bool) or b < 1:
                raise ValueError(
                    f"max_device_bytes must be None or a positive int, "
                    f"got {b!r}"
                )

    @property
    def resolved_interpret(self) -> bool:
        """The concrete interpret flag (``None`` ⇒ ``DEFAULT_INTERPRET``)."""
        return resolve_interpret(self.interpret)

    @property
    def resolved_shape_policy(self) -> ShapePolicy:
        """The concrete ``ShapePolicy`` (``None`` ⇒ ``DEFAULT_SHAPE_POLICY``)."""
        return self.shape_policy if self.shape_policy is not None \
            else DEFAULT_SHAPE_POLICY

    def key(self) -> tuple:
        """Normalized hashable identity: the fields the engine's executable
        cache keys derive from, with ``interpret=None`` and
        ``shape_policy=None`` resolved — so options differing only in
        explicit-vs-default values hash alike."""
        return (
            self.algorithm, self.variant, self.backend,
            self.resolved_interpret, self.strategy, self.widths,
            self.block, self.permute, self.bitmap_bits,
            self.prep_backend, self.resolved_shape_policy.key(),
            self.max_peel_iters, self.peel_early_exit,
            self.update_batch_size, self.recount_interval,
            self.chooser, self.key_mode, self.max_device_bytes,
        )

    def replace(self, **changes) -> "CountOptions":
        """A copy with ``changes`` applied (re-validated)."""
        return dataclasses.replace(self, **changes)

    def plan_kwargs(self, lane: str) -> dict:
        """The ``plan_triangle_count`` kwargs this lane consumes.

        Lanes ignore knobs that do not apply to them (the matrix lane has no
        ``widths``; the intersection lane no ``block``), so one options
        object can drive ``algorithm="auto"`` across all lanes.
        """
        if lane == "intersection":
            return dict(variant=self.variant, backend=self.backend,
                        interpret=self.interpret, widths=self.widths,
                        strategy=self.strategy, bitmap_bits=self.bitmap_bits,
                        prep_backend=self.prep_backend,
                        shape_policy=self.shape_policy,
                        max_device_bytes=self.max_device_bytes)
        if lane == "subgraph":
            return dict(backend=self.backend, interpret=self.interpret,
                        widths=self.widths, strategy=self.strategy,
                        bitmap_bits=self.bitmap_bits,
                        prep_backend=self.prep_backend,
                        shape_policy=self.shape_policy,
                        max_device_bytes=self.max_device_bytes)
        if lane == "matrix":
            return dict(backend=self.backend, interpret=self.interpret,
                        block=self.block, permute=self.permute,
                        max_device_bytes=self.max_device_bytes)
        if lane == "edge":
            return dict(widths=self.widths, strategy=self.strategy,
                        bitmap_bits=self.bitmap_bits,
                        prep_backend=self.prep_backend,
                        shape_policy=self.shape_policy,
                        max_peel_iters=self.max_peel_iters,
                        peel_early_exit=self.peel_early_exit,
                        key_mode=self.key_mode)
        if lane == "dynamic":
            return dict(backend=self.backend, interpret=self.interpret,
                        widths=self.widths, strategy=self.strategy,
                        bitmap_bits=self.bitmap_bits,
                        shape_policy=self.shape_policy,
                        update_batch_size=self.update_batch_size,
                        recount_interval=self.recount_interval,
                        key_mode=self.key_mode)
        if lane == "hash":
            return dict(backend=self.backend, interpret=self.interpret,
                        widths=self.widths,
                        prep_backend=self.prep_backend,
                        shape_policy=self.shape_policy)
        if lane == "bfs":
            return dict(backend=self.backend, interpret=self.interpret,
                        widths=self.widths, strategy=self.strategy,
                        bitmap_bits=self.bitmap_bits,
                        shape_policy=self.shape_policy)
        if lane == "intersection_distributed":
            return dict(variant=self.variant, backend=self.backend,
                        interpret=self.interpret, widths=self.widths,
                        strategy=self.strategy, bitmap_bits=self.bitmap_bits,
                        prep_backend=self.prep_backend,
                        shape_policy=self.shape_policy)
        if lane == "matrix_distributed":
            return dict(backend=self.backend, interpret=self.interpret,
                        block=self.block, permute=self.permute)
        lanes = ("bfs", "dynamic", "edge", "hash", "intersection",
                 "intersection_distributed", "matrix", "matrix_distributed",
                 "subgraph")
        raise ValueError(
            f"unknown engine lane {lane!r}; expected one of {lanes}"
        )
