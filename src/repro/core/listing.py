"""Triangle enumeration and the paper's downstream applications.

The paper stresses that all three TC methods *enumerate* triangles as a side
product, enabling k-truss, clustering coefficient, and transitivity (§1).
This module provides those on top of the forward-oriented intersection
machinery: the (E, W_u, W_v) match tensor that the counting kernels reduce is
instead materialized per bucket and scattered into triple lists / per-vertex
and per-edge accumulators.

These are host-side *enumeration* paths (they materialize triangle lists).
Every downstream application now has a device-resident facade route that
replays cached engine buffers instead of re-running this module's numpy
enumeration: per-vertex analysis (``TriangleCounter.triangles_per_vertex`` /
``clustering_coefficients`` / ``transitivity``) and, since the edge lane,
per-edge analytics too (``TriangleCounter.edge_support`` / ``k_truss`` /
``truss_decomposition``, backed by ``repro.core.engine.TrussPlan``).
``edge_support`` and ``k_truss`` here are therefore DeprecationWarning shims
around the retained numpy implementations — which stay, verbatim, as the
parity oracle the differential tests (``tests/test_truss.py``) compare the
device peel against.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.graphs.formats import (
    Graph,
    bucket_edges_by_degree,
    csr_to_padded_neighbors,
    edges_to_csr,
    orient_forward,
)

__all__ = [
    "enumerate_triangles",
    "triangles_per_vertex",
    "clustering_coefficients",
    "transitivity",
    "edge_support",
    "k_truss",
]


def enumerate_triangles(g: Graph) -> np.ndarray:
    """All triangles as an (Δ, 3) int32 array with rank(a) < rank(b) < rank(c)
    in forward order (each triangle listed exactly once)."""
    dag = orient_forward(g)
    src = np.repeat(np.arange(dag.n, dtype=np.int32), dag.degrees)
    dst = dag.col_idx
    if src.size == 0:
        return np.zeros((0, 3), dtype=np.int32)
    buckets = bucket_edges_by_degree(src, dst, dag.degrees)
    out = []
    for b in buckets:
        w = b["width"]
        nbrs = csr_to_padded_neighbors(dag, pad_to=w, fill=g.n)
        u_lists = nbrs[b["src"]]
        v_lists = nbrs[b["dst"]].copy()
        v_lists[v_lists == g.n] = g.n + 1
        eq = jnp.asarray(u_lists)[:, :, None] == jnp.asarray(v_lists)[:, None, :]
        matched = np.asarray(eq.any(axis=2))  # (E, W): u-list entries in both
        e_idx, w_idx = np.nonzero(matched)
        tri_w = u_lists[e_idx, w_idx]
        out.append(
            np.stack([b["src"][e_idx], b["dst"][e_idx], tri_w], axis=1)
        )
    if not out:
        return np.zeros((0, 3), dtype=np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)


def triangles_per_vertex(g: Graph) -> np.ndarray:
    tris = enumerate_triangles(g)
    return np.bincount(tris.ravel(), minlength=g.n).astype(np.int64)


def clustering_coefficients(g: Graph) -> np.ndarray:
    """cc[v] = 2·t(v) / (d(v)·(d(v)−1)); 0 where degree < 2."""
    t = triangles_per_vertex(g).astype(np.float64)
    d = g.degrees.astype(np.float64)
    denom = d * (d - 1.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        cc = np.where(denom > 0, 2.0 * t / denom, 0.0)
    return cc


def transitivity(g: Graph) -> float:
    """3 · #triangles / #wedges."""
    tris = enumerate_triangles(g).shape[0]
    d = g.degrees.astype(np.int64)
    wedges = int((d * (d - 1) // 2).sum())
    return 3.0 * tris / wedges if wedges else 0.0


def edge_support(g: Graph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deprecated shim: per-undirected-edge triangle membership count.

    Use ``TriangleCounter(g).edge_support()`` — same (src, dst, support)
    triple with src < dst, replayed through the engine's cached edge
    executables instead of this host enumeration. The numpy implementation
    is retained as ``_edge_support_host``, the differential-test oracle.
    """
    from repro.core.api import warn_deprecated

    warn_deprecated("edge_support(g)", "TriangleCounter(g).edge_support()")
    return _edge_support_host(g)


def _edge_support_host(g: Graph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-undirected-edge triangle membership count (numpy parity oracle).

    Returns (src, dst, support) with src < dst.
    """
    su, sv = g.edge_list_unique()
    key = su.astype(np.int64) * g.n + sv
    order = np.argsort(key)
    key_sorted = key[order]
    support = np.zeros(su.shape[0], dtype=np.int64)
    tris = enumerate_triangles(g)
    if tris.shape[0]:
        for a, b in ((0, 1), (0, 2), (1, 2)):
            lo = np.minimum(tris[:, a], tris[:, b]).astype(np.int64)
            hi = np.maximum(tris[:, a], tris[:, b]).astype(np.int64)
            ek = lo * g.n + hi
            pos = np.searchsorted(key_sorted, ek)
            np.add.at(support, order[pos], 1)
    return su, sv, support


def k_truss(g: Graph, k: int, max_iters: int = 1000) -> Graph:
    """Deprecated shim: maximal subgraph where every edge is in ≥ k−2
    triangles.

    Use ``TriangleCounter(g).k_truss(k)`` — the device peel loop produces a
    bit-identical surviving edge set. The numpy peel is retained as
    ``_k_truss_host``, the differential-test oracle.
    """
    from repro.core.api import warn_deprecated

    warn_deprecated("k_truss(g, k)", "TriangleCounter(g).k_truss(k)")
    return _k_truss_host(g, k, max_iters=max_iters)


def _k_truss_host(g: Graph, k: int, max_iters: int = 1000) -> Graph:
    """Iterative numpy edge peel re-using triangle enumeration each round —
    the paper's motivating TC application (§1: 'enumerating triangles is
    useful as a subroutine in solving k-truss') and the parity oracle for
    the engine's device peel."""
    cur = g
    for _ in range(max_iters):
        if cur.m_undirected == 0:
            return cur
        su, sv, supp = _edge_support_host(cur)
        keep = supp >= (k - 2)
        if keep.all():
            return cur
        cur = edges_to_csr(su[keep], sv[keep], n=cur.n, name=g.name + f"+truss{k}")
    return cur
