"""Per-lane prep stages: device-resident (jitted) with host parity paths.

The paper's pipeline for every method splits into a *prep stage* (filtering,
orientation, degree-class grouping, tile scheduling) and a *count stage* (the
kernels §4 measures). PR 1 made the split explicit (plan/execute); this
module moves the prep stage itself onto the device: the intersection and
subgraph lanes' orientation, bucketing, padded gathers, 2-core peel, and
induced-subgraph reform all run as the jitted stages in
``repro.graphs.device``, orchestrated here per lane. The only host↔device
traffic during planning is a handful of scalar syncs (per-bucket counts, the
max forward degree, the peel's survivor count) needed to pick static shapes —
which a ``ShapePolicy`` rounds to powers of two so same-policy graphs share
every traced stage.

Lanes:

* ``prepare_intersection_buckets_device`` — orientation + bucket layout +
  padded gathers for the intersection lane (and the subgraph lane's join),
  returning device-resident ``DeviceBucket``s.
* ``peel_to_two_core_device`` / ``induced_device_graph`` — the subgraph
  lane's FILTER + RECONSTRUCT as device stages (vertex ids are kept, not
  renumbered: dead vertices just lose their rows).
* ``build_tile_schedule`` / ``choose_block`` — the matrix lane's prep. The
  BSR triple join's output size is data-dependent in a way static shapes
  can't express cheaply, so this stage stays host-side (documented in
  ``docs/ARCHITECTURE.md``); it lives here so every lane's prep has one
  home.
* ``prepare_intersection_buckets_host`` / ``peel_to_two_core`` — the
  original numpy paths, kept as parity references (``prep_backend="host"``
  and ``tests/test_prep_parity.py`` compare the device stages against them)
  and for host-side consumers of bucket dicts (the strat benchmark sweep,
  labeled subgraph queries).

``repro.core.engine`` re-exports the historical names
(``prepare_intersection_buckets``, ``build_tile_schedule``,
``peel_to_two_core``, ``choose_block``) as thin wrappers over this module.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.graphs.formats import (
    Graph,
    apply_permutation,
    bucket_edges_by_degree,
    csr_to_padded_neighbors,
    degree_order_permutation,
    orient_forward,
    to_block_sparse,
)
from repro.graphs.device import (
    DEFAULT_SHAPE_POLICY,
    DeviceCSR,
    DeviceGraph,
    ShapePolicy,
    next_pow2,
    _bucket_sort_dev,
    _gather_bucket_dev,
    _induced_compact_dev,
    _sorted_edge_keys_dev,
    _two_core_peel_dev,
    edge_key_context,
    edge_key_dtype,
    edge_key_sentinel,
    fits_int32_pair_keys,
    resolve_edge_key_mode,
)
from repro.core.options import DEFAULT_WIDTHS

__all__ = [
    "DeviceBucket",
    "build_tile_schedule",
    "check_edge_key_range",
    "choose_block",
    "delta_update_buckets",
    "forward_edge_keys_device",
    "forward_edge_keys_host",
    "induced_device_graph",
    "peel_to_two_core",
    "peel_to_two_core_device",
    "prepare_intersection_buckets_device",
    "prepare_intersection_buckets_host",
]


# ---------------------------------------------------------------------------
# Device prep — the intersection/subgraph lanes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceBucket:
    """One degree-class bucket, device-resident and statically shaped.

    ``u_lists``/``v_lists`` are (e_pad, width) int32 sorted neighbor lists;
    the first ``edges`` rows are real, the rest whole-row padding (u = -1,
    v = -2 ⇒ zero matches). ``src``/``dst`` are the per-row edge endpoints
    (padding rows carry 0, harmless because their match counts are zero).
    """

    width: int
    edges: int
    u_lists: jnp.ndarray
    v_lists: jnp.ndarray
    src: jnp.ndarray
    dst: jnp.ndarray

    @property
    def e_pad(self) -> int:
        return int(self.u_lists.shape[0])

    @property
    def shape(self) -> tuple:
        return (self.e_pad, self.width)


def _as_device_graph(g: Union[Graph, DeviceGraph],
                     policy: Optional[ShapePolicy]) -> DeviceGraph:
    if isinstance(g, DeviceGraph):
        return g
    return DeviceGraph.from_graph(g, policy or DEFAULT_SHAPE_POLICY)


def prepare_intersection_buckets_device(
    g: Union[Graph, DeviceGraph],
    *,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
    policy: Optional[ShapePolicy] = None,
) -> List[DeviceBucket]:
    """Device-resident intersection prep: orientation + bucket layout +
    padded neighbor gathers, all jitted.

    Args:
      g: a host ``Graph`` (uploaded once) or an existing ``DeviceGraph``.
      variant: "filtered" (forward orientation; each triangle found once) or
        "full" (all directed edges with full lists; each found 6×).
      widths: ascending degree-class bucket widths; wider edges land in a
        final next-pow2 bucket, exactly as the host path.
      policy: the ``ShapePolicy`` rounding per-bucket extents (ignored when
        ``g`` is already a ``DeviceGraph``, which carries its own).

    Returns:
      A list of ``DeviceBucket``; empty degree classes are dropped. Host
      syncs: one small transfer for the per-bucket counts and max degree —
      everything else stays on device.
    """
    if variant not in ("filtered", "full"):
        raise ValueError(
            f"unknown variant {variant!r}; expected 'filtered' or 'full'"
        )
    dg = _as_device_graph(g, policy)
    n = dg.n
    if dg.m == 0:
        return []

    if variant == "filtered":
        fwd = dg.forward()
        src, dst, valid = fwd.src, fwd.dst, fwd.kvalid
        deg = fwd.degrees
    else:
        src, dst, valid = dg.edge_sources(), dg.csr.col_idx, dg.edge_valid()
        deg = dg.csr.degrees

    # one scalar sync to pick the static top-bucket width
    dmax = int(jnp.max(deg))
    bounds = [int(w) for w in widths]
    if dmax > bounds[-1]:
        bounds.append(next_pow2(dmax))
    ssrc, sdst, counts, starts = _bucket_sort_dev(
        src, dst, valid, deg, jnp.asarray(bounds, jnp.int32),
        n=n, num_bounds=len(bounds),
    )
    counts_h = np.asarray(counts)  # one small sync for static extents
    nbrs = dg.padded_neighbors(bounds[-1], oriented=(variant == "filtered"))

    out = []
    for i, w in enumerate(bounds):
        c = int(counts_h[i])
        if c == 0:
            continue
        e_pad = dg.policy.round_edges(c)
        u, v, sb, db = _gather_bucket_dev(
            ssrc, sdst, starts[i], counts[i], nbrs,
            n=n, e_pad=e_pad, width=w,
        )
        out.append(DeviceBucket(width=w, edges=c, u_lists=u, v_lists=v,
                                src=sb, dst=db))
    return out


def delta_update_buckets(lo_rows: jnp.ndarray, hi_rows: jnp.ndarray,
                         lo_deg: jnp.ndarray, hi_deg: jnp.ndarray,
                         lo: jnp.ndarray, hi: jnp.ndarray,
                         valid: jnp.ndarray, *, n: int,
                         bounds: Sequence[int]) -> list:
    """Incremental re-bucketing of one update batch's anchor edges (traced;
    called from inside the engine's jitted delta executables).

    The dynamic lane's analogue of ``prepare_intersection_buckets_device``,
    restricted to the update batch: each masked anchor edge is assigned to
    the first degree-class bound >= max(deg(lo), deg(hi)), then every class
    is gathered to a **fixed** (ub, width) layout where ub = the batch row
    extent. The adjacency source is the step's slot-indexed anchor-row
    block — ``lo_rows[i]`` / ``hi_rows[i]`` are the endpoint rows of anchor
    edge i, gathered straight from the sorted key orderings — so the whole
    pass touches O(batch · width) data, never the full graph. Unlike the
    static prep there is NO host sync and NO data-dependent extent — empty
    classes are materialized as all-padding rows (u = -1 / v = -2, zero
    matches in every core) — so the whole re-bucketing lives inside one
    cached executable and updates never recompile within a shape class.

    Args:
      lo_rows, hi_rows: (ub, bounds[-1]) padded adjacency rows (in-row
        sentinel ``n``, ascending) of each anchor edge's endpoints against
        the graph side being counted.
      lo_deg, hi_deg: (ub,) the matching endpoint degrees.
      lo, hi: (ub,) anchor edge endpoints (lo < hi on valid rows).
      valid: (ub,) mask of live anchor rows.
      n: vertex count (static).
      bounds: ascending degree-class bounds; ``bounds[-1]`` must be >= the
        graph's max degree (the session maintains this monotonically).

    Returns:
      One ``(width, u_lists, v_lists, src, dst)`` tuple per bound, each
      (ub, width)-shaped with the repo-wide sentinel conventions.
    """
    ub = int(lo.shape[0])
    num_bounds = len(bounds)
    barr = jnp.asarray(list(bounds), jnp.int32)
    w = jnp.maximum(lo_deg, hi_deg)
    b = jnp.searchsorted(barr, w, side="left")
    b = jnp.where(valid, b, num_bounds).astype(jnp.int32)
    order = jnp.argsort(b)  # stable: batch order preserved within a class
    counts = jnp.bincount(b, length=num_bounds + 1)[:num_bounds]
    starts = jnp.concatenate(
        [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])[:num_bounds]
    rows = jnp.arange(ub)
    out = []
    for i, width in enumerate(bounds):
        width = int(width)
        bvalid = rows < counts[i]
        slot = order[jnp.clip(starts[i] + rows, 0, max(ub - 1, 0))]
        sb = jnp.where(bvalid, lo[slot], 0).astype(jnp.int32)
        db = jnp.where(bvalid, hi[slot], 0).astype(jnp.int32)
        u = jnp.where(bvalid[:, None], lo_rows[slot, :width],
                      -1).astype(jnp.int32)
        vfull = hi_rows[slot, :width]
        v = jnp.where(bvalid[:, None],
                      jnp.where(vfull == n, n + 1, vfull),
                      -2).astype(jnp.int32)
        out.append((width, u, v, sb, db))
    return out


def check_edge_key_range(n: int, key_mode: str = "auto", *,
                         lane: str = "edge-support") -> str:
    """Resolve the edge lane's packed-key mode for a graph.

    The edge-support executables address undirected edges through sorted
    ``lo * (n + 1) + hi`` keys — int32 on the ``fits_int32_pair_keys`` fast
    path, wide (x64 int64) past it. Delegates to the repo's single capacity
    checkpoint, ``repro.graphs.device.resolve_edge_key_mode``.

    Returns:
      The resolved concrete key mode: "int32" or "wide".

    Raises:
      GraphTooLargeError: the requested mode cannot represent the graph.
    """
    return resolve_edge_key_mode(n, key_mode, lane=lane)


def forward_edge_keys_device(
    g: Union[Graph, DeviceGraph],
    *,
    policy: Optional[ShapePolicy] = None,
    key_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """The edge lane's undirected-edge addressing structure, on device.

    The forward orientation keeps exactly one directed copy of every
    undirected edge, so a forward CSR *slot* IS an undirected edge id. The
    engine's edge executables accumulate support in slot order (which makes
    the side-edge scatters dense per-row adds); this function supplies the
    conversion to the canonical order: each slot's packed
    ``min·(n+1)+max`` key, sorted (= ``edge_list_unique``'s (lo, hi) lex
    order), plus the sort permutation mapping sorted positions back to
    slots. Padding slots carry the key-dtype max sentinel and sort to the
    end.

    Args:
      g: a host ``Graph`` (uploaded once) or an existing ``DeviceGraph``.
      policy: extent-rounding policy (ignored when ``g`` is a
        ``DeviceGraph``, which carries its own).
      key_mode: "auto" promotes int32 keys to wide (int64) keys past
        ``fits_int32_pair_keys``; "int32"/"wide" force a mode.

    Returns:
      (keys, perm, row_ptr, m): the (mk_pad,) sorted keys (int32 or int64
      per the resolved mode), the (mk_pad,) slot permutation
      (``supp_slots[perm]`` is support in key order), the forward (n+1,)
      row_ptr the executables scatter through, and the true undirected edge
      count occupying the leading key slots.
    """
    dg = _as_device_graph(g, policy)
    mode = check_edge_key_range(dg.n, key_mode)
    kdt = edge_key_dtype(mode)
    if dg.m == 0:
        mk = dg.policy.round_edges(0)
        with edge_key_context(mode):
            return (jnp.full(mk, edge_key_sentinel(mode), jnp.dtype(kdt)),
                    jnp.arange(mk, dtype=jnp.int32),
                    jnp.zeros(dg.n + 1, jnp.int32), 0)
    fwd = dg.forward()
    with edge_key_context(mode):
        keys, perm = _sorted_edge_keys_dev(fwd.src, fwd.dst, fwd.kvalid,
                                           n1=dg.n + 1,
                                           wide=(mode == "wide"))
    return keys, perm, fwd.row_ptr, dg.m // 2


def forward_edge_keys_host(
    g: Graph, key_mode: str = "auto",
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Numpy parity path of ``forward_edge_keys_device``.

    Host slots are the oriented DAG's CSR positions (``orient_forward``),
    so keys per slot need an explicit lex sort into (lo, hi) order.

    Returns:
      (keys, perm, row_ptr, m): unpadded (m,) sorted keys (int32 fast path,
      int64 wide mode), the (m,) slot permutation, the oriented (n+1,)
      row_ptr, and m itself.
    """
    mode = check_edge_key_range(g.n, key_mode)
    dag = orient_forward(g)
    src, dst = dag.edge_endpoints()
    lo = np.minimum(src, dst).astype(np.int64)
    hi = np.maximum(src, dst).astype(np.int64)
    key = (lo * (g.n + 1) + hi).astype(edge_key_dtype(mode))
    perm = np.argsort(key, kind="stable").astype(np.int32)
    return key[perm], perm, dag.row_ptr.astype(np.int32), int(key.shape[0])


def peel_to_two_core_device(dg: DeviceGraph) -> jnp.ndarray:
    """Device 2-core peel (the subgraph lane's FILTER taken to fixed point).

    Returns the (n,) bool alive mask as a device array.
    """
    if dg.m == 0:
        return jnp.zeros(dg.n, dtype=bool)
    return _two_core_peel_dev(
        dg.edge_sources(), dg.csr.col_idx, dg.edge_valid(),
        jnp.ones(dg.n, dtype=bool), n=dg.n,
    )


def induced_device_graph(dg: DeviceGraph, alive: jnp.ndarray) -> DeviceGraph:
    """RECONSTRUCT on device: keep edges with both endpoints alive.

    Vertex ids are preserved (dead vertices keep ids but lose their rows),
    so per-vertex scatters downstream stay in original-id space — the
    renumbering the host path does is an artifact of compact numpy arrays,
    not of the algorithm. One scalar sync (the survivor edge count) picks
    the policy-rounded static extent of the compacted arrays.
    """
    row_ptr_sub, col, kept_dev = _induced_compact_dev(
        dg.csr.row_ptr, dg.csr.col_idx, alive, dg.m,
        n=dg.n, m_pad=dg.csr.m_pad,
    )
    kept = int(kept_dev)
    m_pad_sub = dg.policy.round_edges(kept)
    csr = DeviceCSR(n=dg.n, m=kept, row_ptr=row_ptr_sub,
                    col_idx=col[:m_pad_sub])
    return DeviceGraph(csr, policy=dg.policy, name=dg.name + "+sub")


# ---------------------------------------------------------------------------
# Host parity paths (numpy) — prep_backend="host" and the parity tests
# ---------------------------------------------------------------------------

def prepare_intersection_buckets_host(
    g: Graph,
    variant: str = "filtered",
    widths: Sequence[int] = DEFAULT_WIDTHS,
) -> list:
    """The original numpy intersection prep, kept as the parity reference.

    Args:
      g: undirected simple ``Graph``.
      variant: "filtered" — forward orientation (rank = (degree, id)), the
        paper's "filter out half of the edges by degree order"; the oriented
        rows double as the reformed induced subgraph's neighbor lists.
        "full" — all directed edges with full neighbor lists (each triangle
        found 6×), the tc-intersection-full ablation.
      widths: ascending degree-class bucket widths; edges wider than
        ``widths[-1]`` land in a final next-pow2 bucket.

    Returns:
      A list of dicts ``{u_lists, v_lists, src, dst, width}``, one per
      non-empty degree-class bucket. ``u_lists``/``v_lists`` are (E_b, W_b)
      int32 numpy arrays of sorted neighbor lists; ``src``/``dst`` are the
      (E_b,) edge endpoints each row belongs to (per-vertex analysis scatters
      through them). Sentinel-padding rule: u rows pad with ``n``, v rows
      with ``n + 1`` (never equal ⇒ padding contributes zero matches); both
      sentinels sort above every real id, keeping rows sorted.
    """
    if variant == "filtered":
        dag = orient_forward(g)
        src, dst = dag.edge_endpoints()
        deg = dag.degrees
        base = dag
    elif variant == "full":
        src, dst = g.edge_endpoints()
        deg = g.degrees
        base = g
    else:
        raise ValueError(
            f"unknown variant {variant!r}; expected 'filtered' or 'full'"
        )

    buckets = bucket_edges_by_degree(src, dst, deg, widths=widths)
    out = []
    for b in buckets:
        w = b["width"]
        nbrs = csr_to_padded_neighbors(base, pad_to=max(w, 1), fill=g.n)
        u_lists = nbrs[b["src"]]
        v_lists = nbrs[b["dst"]].copy()
        v_lists[v_lists == g.n] = g.n + 1  # disjoint sentinel
        out.append(dict(u_lists=u_lists, v_lists=v_lists,
                        src=b["src"], dst=b["dst"], width=w))
    return out


def peel_to_two_core(g: Graph, labels: Optional[np.ndarray] = None,
                     query_label: Optional[int] = None) -> np.ndarray:
    """INITIALIZE_CANDIDATE_SET + iterated filter, to fixed point (host API).

    Args:
      g: undirected simple ``Graph``.
      labels: optional (n,) vertex labels for labeled subgraph queries.
      query_label: with ``labels``, prune vertices whose label cannot match
        any query vertex before the degree peel.

    Returns:
      Bool (n,) numpy mask of vertices surviving the 2-core peel (every
      triangle vertex has ≥ 2 alive neighbors, so counting on the induced
      subgraph is exact).
    """
    src, dst = g.edge_endpoints()
    init = np.ones(g.n, dtype=bool)
    if labels is not None and query_label is not None:
        init &= np.asarray(labels) == query_label
    if g.m_directed == 0:
        return np.zeros(g.n, dtype=bool)
    alive = _two_core_peel(jnp.asarray(src), jnp.asarray(dst),
                           jnp.asarray(init), n=g.n)
    return np.asarray(alive)


def _two_core_peel(src: jnp.ndarray, dst: jnp.ndarray,
                   init_alive: jnp.ndarray, *, n: int) -> jnp.ndarray:
    """Unmasked fixed-point peel over a concrete edge list (host callers)."""
    valid = jnp.ones(src.shape[0], dtype=bool)
    return _two_core_peel_dev(src, dst, valid, init_alive, n=n)


# ---------------------------------------------------------------------------
# Matrix lane prep (host stage — see module docstring)
# ---------------------------------------------------------------------------

def choose_block(g: Graph) -> int:
    """Adaptive tile size (§Perf hillclimb, beyond-paper): degree-permuted
    scale-free graphs densify the bottom-right tile cluster, so 128 (MXU
    native) wins; mesh-like graphs (low, uniform degree) never fill tiles —
    measured 40,000× MXU-flop waste and 25× wall-time regression at 128 vs
    32 on road-like — so low-avg-degree graphs get small tiles."""
    avg_deg = 2.0 * g.m_undirected / max(g.n, 1)
    return 128 if avg_deg >= 8.0 else 32


def build_tile_schedule(
    g: Graph, block: int = 128, permute: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Host-side stage of the matrix method: degree permutation + BSR tiling +
    the L/U/A triple schedule.

    Args:
      g: undirected simple ``Graph``.
      block: dense tile edge length B (128 = MXU native).
      permute: apply the degree-order permutation first (the paper's
        tc-matrix step 1).

    Returns:
      (l_tiles, u_tiles, a_tiles, stats): three stacked (T, B, B) float32
      arrays — the L tile, U tile, and A mask tile of each scheduled triple —
      plus a stats dict (num_triples, tile counts, grid, block, tile_flops).
      Triples are sorted heavy-first (by block density product); that order is
      the unit of distribution for multi-device TC (core/distributed.py deals
      it round-robin for static load balance — the TPU analogue of
      merge-path's equal-work splitting).
    """
    if permute:
        perm = degree_order_permutation(g)
        g = apply_permutation(g, perm)
    a_bsr = to_block_sparse(g, block=block, part="upper")  # mask: strict upper
    l_bsr = to_block_sparse(g, block=block, part="lower")
    u_bsr = to_block_sparse(g, block=block, part="upper")

    # block-row index of L: row -> list of (K, tile_id); block-col index of U
    l_rows: dict = {}
    for t in range(l_bsr.num_blocks):
        l_rows.setdefault(int(l_bsr.block_row[t]), []).append(
            (int(l_bsr.block_col[t]), t)
        )
    u_cols: dict = {}
    for t in range(u_bsr.num_blocks):
        u_cols.setdefault(int(u_bsr.block_col[t]), []).append(
            (int(u_bsr.block_row[t]), t)
        )

    trip_l, trip_u, trip_a = [], [], []
    for t in range(a_bsr.num_blocks):
        bi, bj = int(a_bsr.block_row[t]), int(a_bsr.block_col[t])
        lk = dict(l_rows.get(bi, ()))
        uk = dict(u_cols.get(bj, ()))
        for k in lk.keys() & uk.keys():
            trip_a.append(t)
            trip_l.append(lk[k])
            trip_u.append(uk[k])

    T = len(trip_a)
    stats = dict(
        num_triples=T,
        a_tiles=a_bsr.num_blocks,
        l_tiles=l_bsr.num_blocks,
        u_tiles=u_bsr.num_blocks,
        grid=a_bsr.grid,
        block=block,
        tile_flops=2 * T * block**3,
    )
    if T == 0:
        z = np.zeros((0, block, block), dtype=np.float32)
        return z, z, z, stats

    l_sel = l_bsr.blocks[np.asarray(trip_l)]
    u_sel = u_bsr.blocks[np.asarray(trip_u)]
    a_sel = a_bsr.blocks[np.asarray(trip_a)]
    # heavy-first ordering by nnz(L)·nnz(U) so chunked execution and
    # round-robin sharding see a monotone work profile
    work = l_sel.sum(axis=(1, 2)) * u_sel.sum(axis=(1, 2))
    order = np.argsort(-work, kind="stable")
    return l_sel[order], u_sel[order], a_sel[order], stats
