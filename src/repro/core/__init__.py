"""Core library: the paper's three exact triangle-counting formulations
behind one front door.

Public API:
    TriangleCounter / CountOptions / CountResult — the session facade: one
        typed options bag, one cached plan, cross-lane ``algorithm="auto"``
    register_algorithm / available_algorithms / choose_algorithm /
        set_auto_chooser — the algorithm registry + auto cost model
    plan_triangle_count / TrianglePlan — the plan/execute engine underneath:
        device-resident prep (see ``repro.core.prep``), device buffers +
        cached compiled kernels
    GraphBatch — same-policy graphs stacked into one vmapped device
        dispatch (the ``count_many`` fast path)
    TrussPlan / plan_edge_support — the edge lane (``algorithm="edge"``):
        cached per-edge support executables + the device k-truss peel loop
        (surfaced as ``TriangleCounter.edge_support`` / ``k_truss`` /
        ``truss_decomposition``)
    DEFAULT_INTERPRET / resolve_interpret — the single interpret-mode default
        (``TC_INTERPRET`` env var)
    enumerate_triangles — host-side triangle enumeration
    k_truss / edge_support — DEPRECATED shims over the retained numpy parity
        oracle; use the ``TriangleCounter`` methods
    triangle_count_scipy / triangle_count_brute / triangle_count_forward_cpu
        — oracles
    triangle_count_* (+ ``*_distributed``) — DEPRECATED one-shot shims over
        the facade; signatures and return values unchanged
"""

from repro.core.options import (
    CountOptions,
    DEFAULT_INTERPRET,
    DEFAULT_WIDTHS,
    resolve_interpret,
)
from repro.core.registry import (
    available_algorithms,
    choose_algorithm,
    register_algorithm,
    set_auto_chooser,
)
from repro.core.engine import (
    STRATEGIES,
    GraphBatch,
    TrianglePlan,
    TrussPlan,
    choose_strategy,
    clear_executable_cache,
    executable_cache_info,
    plan_edge_support,
    plan_triangle_count,
    resolve_strategy,
)
from repro.core.api import CountResult, TriangleCounter
from repro.core.tc_intersection import (
    triangle_count_intersection,
    prepare_intersection_buckets,
)
from repro.core.tc_matrix import triangle_count_matrix, build_tile_schedule
from repro.core.tc_subgraph import (
    triangle_count_subgraph,
    subgraph_match_triangle,
    peel_to_two_core,
)
from repro.core.listing import (
    enumerate_triangles,
    triangles_per_vertex,
    clustering_coefficients,
    transitivity,
    edge_support,
    k_truss,
)
from repro.core.distributed import (
    triangle_count_matrix_distributed,
    triangle_count_intersection_distributed,
)
from repro.core.oracle import (
    triangle_count_scipy,
    triangle_count_brute,
    triangle_count_forward_cpu,
)

__all__ = [
    "CountOptions",
    "CountResult",
    "TriangleCounter",
    "DEFAULT_INTERPRET",
    "DEFAULT_WIDTHS",
    "resolve_interpret",
    "register_algorithm",
    "available_algorithms",
    "choose_algorithm",
    "set_auto_chooser",
    "STRATEGIES",
    "GraphBatch",
    "TrianglePlan",
    "TrussPlan",
    "plan_edge_support",
    "plan_triangle_count",
    "choose_strategy",
    "resolve_strategy",
    "executable_cache_info",
    "clear_executable_cache",
    "triangle_count_intersection",
    "prepare_intersection_buckets",
    "triangle_count_matrix",
    "build_tile_schedule",
    "triangle_count_subgraph",
    "subgraph_match_triangle",
    "peel_to_two_core",
    "enumerate_triangles",
    "triangles_per_vertex",
    "clustering_coefficients",
    "transitivity",
    "edge_support",
    "k_truss",
    "triangle_count_matrix_distributed",
    "triangle_count_intersection_distributed",
    "triangle_count_scipy",
    "triangle_count_brute",
    "triangle_count_forward_cpu",
]
