"""Core library: the paper's three exact triangle-counting formulations.

Public API:
    plan_triangle_count / TrianglePlan — plan/execute engine: host prep once,
        device-resident buffers + cached compiled kernels, replayable count()
    triangle_count_intersection  — forward algorithm, bucketed batch intersection
    triangle_count_matrix        — masked block-SpGEMM (MXU tile schedule)
    triangle_count_subgraph      — filter(2-core) + join subgraph matching
    subgraph_match_triangle      — labeled triangle queries (SM generality)
    enumerate_triangles / k_truss / clustering_coefficients / transitivity
    triangle_count_*_distributed — shard_map multi-pod variants
"""

from repro.core.engine import (
    STRATEGIES,
    TrianglePlan,
    choose_strategy,
    clear_executable_cache,
    executable_cache_info,
    plan_triangle_count,
    resolve_strategy,
)
from repro.core.tc_intersection import (
    triangle_count_intersection,
    prepare_intersection_buckets,
)
from repro.core.tc_matrix import triangle_count_matrix, build_tile_schedule
from repro.core.tc_subgraph import (
    triangle_count_subgraph,
    subgraph_match_triangle,
    peel_to_two_core,
)
from repro.core.listing import (
    enumerate_triangles,
    triangles_per_vertex,
    clustering_coefficients,
    transitivity,
    edge_support,
    k_truss,
)
from repro.core.distributed import (
    triangle_count_matrix_distributed,
    triangle_count_intersection_distributed,
)
from repro.core.oracle import (
    triangle_count_scipy,
    triangle_count_brute,
    triangle_count_forward_cpu,
)

__all__ = [
    "STRATEGIES",
    "TrianglePlan",
    "plan_triangle_count",
    "choose_strategy",
    "resolve_strategy",
    "executable_cache_info",
    "clear_executable_cache",
    "triangle_count_intersection",
    "prepare_intersection_buckets",
    "triangle_count_matrix",
    "build_tile_schedule",
    "triangle_count_subgraph",
    "subgraph_match_triangle",
    "peel_to_two_core",
    "enumerate_triangles",
    "triangles_per_vertex",
    "clustering_coefficients",
    "transitivity",
    "edge_support",
    "k_truss",
    "triangle_count_matrix_distributed",
    "triangle_count_intersection_distributed",
    "triangle_count_scipy",
    "triangle_count_brute",
    "triangle_count_forward_cpu",
]
