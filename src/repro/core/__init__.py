"""Core library: the paper's three exact triangle-counting formulations
behind one front door.

Public API:
    TriangleCounter / CountOptions / CountResult — the session facade: one
        typed options bag, one cached plan, cross-lane ``algorithm="auto"``
    CounterSession — the shared session base (``count()`` /
        ``count_with_stats()`` / ``cache_stats()``) both session types
        expose
    DynamicTriangleCounter / DynamicPlan / plan_dynamic_count /
        EdgeUpdate / normalize_edge_updates — the dynamic lane
        (``algorithm="dynamic"``): batched edge updates applied to the
        device-resident CSR in place, incremental exact counts via cached
        delta executables, periodic full-recount parity oracle
    register_algorithm / available_algorithms / choose_algorithm /
        set_auto_chooser — the algorithm registry + the heuristic auto
        cost model
    CalibrationTable / calibrate / analytic_seed / choose_measured /
        install_measured_chooser / save_table / load_table /
        set_default_table — the measured ``algorithm="auto"`` chooser
        (``CountOptions(chooser="measured")``): per-device calibration
        tables built from timed micro-runs, cold-started by HLO/roofline
        pricing, persisted as ``CALIB_<device>.json`` sidecars
    plan_hash_count / plan_bfs_count — direct planners for the two newest
        lanes ("hash": TRUST-style per-vertex hash probing; "bfs":
        level-ordered forward-edge closure over the shared intersection
        executables)
    available_strategies — the valid intersection-strategy names (the
        discovery twin of ``available_algorithms`` /
        ``repro.graphs.available_datasets``)
    plan_triangle_count / TrianglePlan — the plan/execute engine underneath:
        device-resident prep (see ``repro.core.prep``), device buffers +
        cached compiled kernels
    GraphBatch — same-policy graphs stacked into one vmapped device
        dispatch (the ``count_many`` fast path)
    TrussPlan / plan_edge_support — the edge lane (``algorithm="edge"``):
        cached per-edge support executables + the device k-truss peel loop
        (surfaced as ``TriangleCounter.edge_support`` / ``k_truss`` /
        ``truss_decomposition``)
    cache_info / clear_caches / set_cache_limit (+ the original
        executable_cache_info / clear_executable_cache pair) — the
        process-wide executable cache, since PR 8 a thread-safe bounded LRU
        (default 512 entries, ``TC_EXEC_CACHE_SIZE`` env var) with
        hit/miss/eviction counters, shared by every session and the
        ``repro.serve`` front end
    graph_fingerprint — stable CSR content hash; with
        ``CountOptions.key()`` it forms ``CounterSession.session_key()``,
        the serving layer's session-reuse identity
    DEFAULT_INTERPRET / resolve_interpret — the single interpret-mode default
        (``TC_INTERPRET`` env var)
    enumerate_triangles — host-side triangle enumeration
    k_truss / edge_support — DEPRECATED shims over the retained numpy parity
        oracle; use the ``TriangleCounter`` methods
    triangle_count_scipy / triangle_count_brute / triangle_count_forward_cpu
        — oracles
    triangle_count_* (+ ``*_distributed``) — DEPRECATED one-shot shims over
        the facade; signatures and return values unchanged
"""

from repro.core.options import (
    CHOOSERS,
    CountOptions,
    DEFAULT_INTERPRET,
    DEFAULT_WIDTHS,
    resolve_interpret,
)
from repro.core.registry import (
    available_algorithms,
    choose_algorithm,
    register_algorithm,
    set_auto_chooser,
)
from repro.core.engine import (
    DISTRIBUTED_ALGORITHMS,
    STRATEGIES,
    DynamicPlan,
    GraphBatch,
    TrianglePlan,
    TrussPlan,
    cache_info,
    choose_strategy,
    clear_caches,
    clear_executable_cache,
    executable_cache_info,
    mesh_cache_component,
    set_cache_limit,
    plan_bfs_count,
    plan_dynamic_count,
    plan_edge_support,
    plan_hash_count,
    plan_triangle_count,
    resolve_strategy,
)
from repro.core.calibrate import (
    CalibrationTable,
    analytic_seed,
    calibrate,
    choose_measured,
    install_measured_chooser,
    load_table,
    save_table,
    set_default_table,
)
from repro.core.api import (
    CounterSession,
    CountResult,
    DynamicTriangleCounter,
    TriangleCounter,
    graph_fingerprint,
)
from repro.graphs.device import GraphTooLargeError
from repro.graphs.formats import EdgeUpdate, normalize_edge_updates
from repro.kernels.intersect.ops import available_strategies
from repro.core.tc_intersection import (
    triangle_count_intersection,
    prepare_intersection_buckets,
)
from repro.core.tc_matrix import triangle_count_matrix, build_tile_schedule
from repro.core.tc_subgraph import (
    triangle_count_subgraph,
    subgraph_match_triangle,
    peel_to_two_core,
)
from repro.core.listing import (
    enumerate_triangles,
    triangles_per_vertex,
    clustering_coefficients,
    transitivity,
    edge_support,
    k_truss,
)
from repro.core.distributed import (
    triangle_count_matrix_distributed,
    triangle_count_intersection_distributed,
)
from repro.core.oracle import (
    triangle_count_scipy,
    triangle_count_brute,
    triangle_count_forward_cpu,
)

__all__ = [
    "CHOOSERS",
    "CalibrationTable",
    "CountOptions",
    "CountResult",
    "CounterSession",
    "TriangleCounter",
    "DynamicTriangleCounter",
    "DynamicPlan",
    "EdgeUpdate",
    "GraphTooLargeError",
    "normalize_edge_updates",
    "DEFAULT_INTERPRET",
    "DEFAULT_WIDTHS",
    "resolve_interpret",
    "register_algorithm",
    "available_algorithms",
    "available_strategies",
    "choose_algorithm",
    "set_auto_chooser",
    "DISTRIBUTED_ALGORITHMS",
    "STRATEGIES",
    "GraphBatch",
    "TrianglePlan",
    "TrussPlan",
    "analytic_seed",
    "calibrate",
    "choose_measured",
    "install_measured_chooser",
    "load_table",
    "save_table",
    "set_default_table",
    "plan_bfs_count",
    "plan_dynamic_count",
    "plan_edge_support",
    "plan_hash_count",
    "plan_triangle_count",
    "choose_strategy",
    "resolve_strategy",
    "executable_cache_info",
    "clear_executable_cache",
    "cache_info",
    "clear_caches",
    "mesh_cache_component",
    "set_cache_limit",
    "graph_fingerprint",
    "triangle_count_intersection",
    "prepare_intersection_buckets",
    "triangle_count_matrix",
    "build_tile_schedule",
    "triangle_count_subgraph",
    "subgraph_match_triangle",
    "peel_to_two_core",
    "enumerate_triangles",
    "triangles_per_vertex",
    "clustering_coefficients",
    "transitivity",
    "edge_support",
    "k_truss",
    "triangle_count_matrix_distributed",
    "triangle_count_intersection_distributed",
    "triangle_count_scipy",
    "triangle_count_brute",
    "triangle_count_forward_cpu",
]
