"""Ground-truth triangle counters used only by tests and benchmarks.

``triangle_count_scipy`` doubles as the sequential CPU baseline in the
Fig. 5 analogue benchmark (the paper normalizes to Schank & Wagner's forward
algorithm on one core; trace(A³)/6 via scipy CSR matmul is the same O(Σd²)
work expressed through a mature sequential sparse kernel).
"""

from __future__ import annotations

import numpy as np

from repro.graphs.formats import Graph, orient_forward

__all__ = ["triangle_count_scipy", "triangle_count_brute", "triangle_count_forward_cpu"]


def triangle_count_scipy(g: Graph) -> int:
    a = g.to_scipy()
    a2 = a @ a
    # trace(A^3) = sum over nonzero (i,j) of A of A2[i,j]
    tri6 = a2.multiply(a).sum()
    return int(tri6) // 6


def triangle_count_brute(g: Graph) -> int:
    """O(n^3) — tiny fixtures only."""
    a = g.to_scipy().toarray().astype(bool)
    n = g.n
    count = 0
    for i in range(n):
        for j in range(i + 1, n):
            if a[i, j]:
                count += int((a[i] & a[j])[j + 1 :].sum())
    return count


def triangle_count_forward_cpu(g: Graph) -> int:
    """Sequential forward algorithm (Schank & Wagner) in pure numpy —
    the paper's CPU baseline implementation."""
    dag = orient_forward(g)
    count = 0
    rp, ci = dag.row_ptr, dag.col_idx
    for u in range(g.n):
        nu = ci[rp[u] : rp[u + 1]]
        for v in nu:
            nv = ci[rp[v] : rp[v + 1]]
            count += np.intersect1d(nu, nv, assume_unique=True).shape[0]
    return int(count)
