"""One front door for triangle counting: ``TriangleCounter`` + ``CountResult``.

The paper's central result is comparative — three formulations with different
winners per graph shape — so the public API is a single session object over a
typed options bag rather than three differently-shaped free functions:

    from repro.core import TriangleCounter, CountOptions

    tc = TriangleCounter(g)                      # algorithm="auto"
    res = tc.count()                             # CountResult
    res.count, res.algorithm                     # count + the lane chosen
    res.bucket_strategies                        # per-bucket kernel picks
    tc.count()                                   # replays the cached plan

``TriangleCounter`` owns ONE ``TrianglePlan`` (built lazily through the
algorithm registry, ``repro.core.registry``): every ``count()`` is a device
replay, ``count_many()`` maps the same options over a graph batch (same-shaped
graphs share the process-wide executable cache), and the analysis surfaces
replay cached device buffers instead of ``listing.py``'s engine-bypassing
host enumeration: per-vertex (``triangles_per_vertex`` /
``clustering_coefficients`` / ``transitivity``, the "vertex" executables)
and per-edge (``edge_support`` / ``k_truss`` / ``truss_decomposition``, the
"edge" executables plus the device k-truss peel loop — see
``repro.core.engine.TrussPlan``).

``CountResult`` replaces the ``(int, dict)`` tuple of the old
``count_with_stats()``: the count plus which lane ran, per-bucket strategies,
prep/exec timings, and the live plan handle. It compares equal to plain ints
(``res == triangle_count_scipy(g)``) so oracle checks read naturally.
(``count_with_stats()`` survives on every session as a thin ``(int, dict)``
view over the same result.)

Both session types share the ``CounterSession`` base — one graph, one
``CountOptions``, one lazily built plan, the process-wide executable cache:

* ``TriangleCounter`` — the static session above.
* ``DynamicTriangleCounter`` — the dynamic-graph session: seed it with a
  ``Graph``, stream batched ``EdgeUpdate`` lists through
  ``apply_updates()``, and the exact triangle count is maintained
  incrementally on the device (``repro.core.engine.DynamicPlan``): updates
  mutate the device-resident CSR in place inside ``ShapePolicy`` shape
  classes (zero recompiles until an extent overflows its class, then
  exactly one re-bucket), deltas come from cached executables that
  intersect only the adjacency lists the batch touched, and a periodic
  full recount asserts bit-exact parity.

The legacy one-shot functions (``triangle_count_intersection`` /
``triangle_count_matrix`` / ``triangle_count_subgraph`` and the
``*_distributed`` pair) are deprecated shims over this facade — signatures
preserved, same return values, plus a ``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
import warnings
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import registry
from repro.core.engine import (GraphBatch, executable_cache_info,
                               plan_triangle_count)
from repro.core.options import CountOptions
from repro.graphs.formats import Graph, normalize_edge_updates

__all__ = ["CountResult", "CounterSession", "DynamicTriangleCounter",
           "TriangleCounter", "graph_fingerprint", "warn_deprecated"]


def graph_fingerprint(g: Graph) -> str:
    """A stable content hash of a graph's CSR (32 hex chars).

    Two ``Graph`` objects with identical ``(n, row_ptr, col_idx)`` — the
    arrays every plan is built from — fingerprint identically regardless of
    ``name`` or object identity. The serving layer keys its session and
    prepped-plan caches on ``(graph_fingerprint(g), options.key())`` so
    repeat requests for the same graph reuse device prep instead of
    redoing it. Cost is one pass over the CSR (no device work).
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(str(int(g.n)).encode())
    h.update(np.ascontiguousarray(g.row_ptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.col_idx, dtype=np.int64).tobytes())
    return h.hexdigest()


def warn_deprecated(old: str, new: str) -> None:
    """Emit the facade's standard DeprecationWarning (used by the legacy
    ``triangle_count_*`` shims; stacklevel points at the shim's caller)."""
    warnings.warn(
        f"{old} is deprecated; use {new} (see README.md §Migration)",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass(eq=False)
class CountResult:
    """One triangle count plus everything about how it was produced.

    Attributes:
      count: the exact triangle count.
      algorithm: the lane that ran — the resolved choice when the session's
        options said ``algorithm="auto"``.
      options: the ``CountOptions`` the session was built from (``auto``
        preserved as written; ``algorithm`` above is the resolution).
      bucket_strategies: intersection/subgraph lanes — the per-degree-bucket
        ``(width, strategy)`` picks; None for lanes without buckets.
      prep_seconds: the plan's one-time host stage (0.0 for one-shot lanes).
      exec_seconds: this count's device replay, measured around ``count()``.
      plan: the live plan handle (``TrianglePlan`` or ``OneShotPlan``) —
        replay it directly, inspect ``plan.meta``, or time ``plan.count``.
      meta: the plan's statistics dict (prune fractions, tile schedule
        sizes, bucket shapes, ``num_embeddings`` on the subgraph lane).

    Compares equal to ints via ``count`` (and coerces with ``int()``), so
    ``result == triangle_count_scipy(g)`` is the natural oracle check.
    """

    count: int
    algorithm: str
    options: CountOptions
    bucket_strategies: Optional[List[Tuple[int, str]]]
    prep_seconds: float
    exec_seconds: float
    plan: Any
    meta: Dict[str, Any]

    def __int__(self) -> int:
        return self.count

    def __index__(self) -> int:
        return self.count

    def __eq__(self, other) -> bool:
        if isinstance(other, CountResult):
            return self.count == other.count
        if isinstance(other, (int, np.integer)):
            return self.count == int(other)
        return NotImplemented

    def __repr__(self) -> str:
        return (f"CountResult(count={self.count}, "
                f"algorithm={self.algorithm!r}, "
                f"prep_seconds={self.prep_seconds:.4f}, "
                f"exec_seconds={self.exec_seconds:.4f})")


class CounterSession:
    """Shared machinery for every counting session type.

    One graph, one typed ``CountOptions`` bag, one lazily built plan. Both
    the static session (``TriangleCounter``) and the dynamic one
    (``DynamicTriangleCounter``) expose the same core surface —
    ``count()`` → ``CountResult``, ``count_with_stats()`` → ``(int,
    dict)``, and the ``cache_stats()`` view of the engine's process-wide
    executable cache — so callers can swap session types without touching
    the measurement code around them.

    Args:
      g: the input ``Graph`` (undirected simple CSR).
      options: a ``CountOptions``; None builds one from ``**overrides``.
      mesh: jax device mesh, consumed by the distributed lanes only.
      **overrides: ``CountOptions`` field overrides, applied on top of
        ``options`` (or the defaults) — ``TriangleCounter(g,
        algorithm="matrix", block=64)`` reads like the old free functions.

    Subclasses pick their registry lane via ``_resolve_algorithm`` (called
    ONCE at construction; the choice is exposed as ``.algorithm`` and in
    every ``CountResult``). The plan builds lazily on first use — equal
    options over same-shaped graphs share the engine's process-wide
    executable cache, so a second session compiles nothing new.
    """

    def __init__(self, g: Graph, options: Optional[CountOptions] = None,
                 *, mesh=None, **overrides):
        if options is None:
            options = CountOptions(**overrides)
        elif overrides:
            options = options.replace(**overrides)
        if not isinstance(options, CountOptions):
            raise TypeError(
                f"options must be a CountOptions, got {type(options).__name__}"
            )
        self.graph = g
        self.options = options
        self.mesh = mesh
        self.algorithm = self._resolve_algorithm()
        self._plan = None

    def _resolve_algorithm(self) -> str:
        """Map the session's options to its registry lane (subclass hook)."""
        if self.options.algorithm != "auto":
            return self.options.algorithm
        return self._choose_auto(self.graph)

    def _choose_auto(self, g: Graph) -> str:
        """Resolve ``algorithm="auto"`` per ``options.chooser``: "measured"
        consults the calibration table (``core.calibrate``, heuristic
        fallback built in), "heuristic" keeps the registry's shape rules.
        Either way the session's mesh rides along, so a multi-device session
        promotes the pick to the matching distributed lane."""
        if self.options.chooser == "measured":
            from repro.core.calibrate import choose_measured
            return choose_measured(g, mesh=self.mesh)
        return registry.choose_algorithm(g, mesh=self.mesh)

    @property
    def plan(self):
        """The session's plan, built on first access via the registry."""
        if self._plan is None:
            planner = registry.get_algorithm(self.algorithm)
            self._plan = planner(self.graph, self.options, mesh=self.mesh)
        return self._plan

    def count(self) -> CountResult:
        """Count triangles (device replay after the first call)."""
        plan = self.plan
        t0 = time.perf_counter()
        c = plan.count()
        exec_seconds = time.perf_counter() - t0
        meta = dict(getattr(plan, "meta", None) or {})
        if self.algorithm == "subgraph":
            meta["num_embeddings"] = 6 * c  # all |Aut(K3)| automorphisms
        return CountResult(
            count=c,
            algorithm=self.algorithm,
            options=self.options,
            bucket_strategies=meta.get("bucket_strategies"),
            prep_seconds=float(getattr(plan, "prep_seconds", 0.0)),
            exec_seconds=exec_seconds,
            plan=plan,
            meta=meta,
        )

    def count_with_stats(self) -> Tuple[int, Dict[str, Any]]:
        """The classic ``(count, stats)`` pair: the ``CountResult``'s count
        and its meta dict, with the resolved lane under ``"algorithm"``."""
        res = self.count()
        stats = dict(res.meta)
        stats["algorithm"] = res.algorithm
        return res.count, stats

    @staticmethod
    def cache_stats() -> Dict[str, int]:
        """Process-wide executable-cache statistics — a live ``{"size",
        "hits", "misses"}`` snapshot of ``engine.executable_cache_info()``
        (every session shares one cache, so deltas across calls measure
        compilations caused in between)."""
        return executable_cache_info()

    def session_key(self) -> tuple:
        """The session's reuse identity: ``(graph_fingerprint(graph),
        options.key())``. Two sessions with equal keys are interchangeable —
        same graph content, same resolved options — which is exactly what
        the serving layer's bounded session cache needs to hand concurrent
        tenants a shared session instead of re-prepping per request."""
        return (graph_fingerprint(self.graph), self.options.key())


class TriangleCounter(CounterSession):
    """A static counting session: one graph, one options bag, one cached
    plan (see ``CounterSession`` for the shared surface and constructor).

    On top of the shared surface, this session batches (``count_many`` /
    ``iter_counts``) and carries the per-vertex / per-edge analysis
    accessors, all routed through the cached plan and the engine's
    executable cache.
    """

    def __init__(self, g: Graph, options: Optional[CountOptions] = None,
                 *, mesh=None, **overrides):
        super().__init__(g, options, mesh=mesh, **overrides)
        self._vertex_counts: Optional[np.ndarray] = None
        self._edge_sidecar = None

    def count_many(self, graphs: Iterable[Graph],
                   *, batch_size: int = 8) -> List[CountResult]:
        """Count a batch of graphs under this session's options.

        The input is consumed LAZILY, ``batch_size`` graphs at a time —
        generators are never materialized up front. Within each chunk, every
        graph whose lane resolves to the batchable regime (``intersection``,
        ``backend="jnp"``, ``prep_backend="device"`` — the defaults) is
        device-prepped and stacked into one ``GraphBatch``, so the whole
        chunk is counted by ONE vmapped device dispatch instead of a Python
        loop of per-graph plans. The stacked executable comes from the
        engine's shape-policy-keyed batch-plan cache, so successive chunks
        whose policy-rounded layouts collide compile nothing new.

        Graphs outside the batchable regime (other lanes under
        ``algorithm="auto"``, pallas backends, host prep) fall back to a
        per-graph session; the session's own graph reuses the session plan.
        In particular, a multi-device ``mesh`` promotes lanes to their
        distributed variants, which are NOT batchable — every graph then
        takes the per-graph fallback (results stay correct, but the one
        stacked dispatch is lost). That fallback emits a ``UserWarning``
        once per session; a sharded ``GraphBatch`` is ROADMAP-tracked
        follow-up work.
        Results come back in input order. Batched results share one
        ``GraphBatch`` as their ``plan`` handle, and their
        ``prep_seconds`` / ``exec_seconds`` are the WHOLE chunk's figures
        (``meta["batched"]`` / ``meta["batch_size"]`` mark them) — don't sum
        them across a chunk.

        ``iter_counts`` is the generator twin: identical semantics and the
        same ``batch_size`` chunking kwarg, but it yields results as each
        chunk lands instead of materializing the list.
        """
        return list(self.iter_counts(graphs, batch_size=batch_size))

    def iter_counts(self, graphs: Iterable[Graph],
                    *, batch_size: int = 8) -> Iterator[CountResult]:
        """Generator form of ``count_many``: yields ``CountResult``s in input
        order while pulling at most ``batch_size`` graphs ahead of the
        consumer (the streaming surface for unbounded graph sources)."""
        if batch_size < 1:
            raise ValueError(f"batch_size must be ≥ 1, got {batch_size}")
        it = iter(graphs)
        while True:
            chunk = list(itertools.islice(it, batch_size))
            if not chunk:
                return
            yield from self._count_chunk(chunk)

    def _batchable(self, lane: str) -> bool:
        return (lane == "intersection"
                and self.options.backend == "jnp"
                and self.options.prep_backend == "device")

    def _count_chunk(self, chunk: List[Graph]) -> List[CountResult]:
        results: List[Optional[CountResult]] = [None] * len(chunk)
        batchable: List[Tuple[int, Graph]] = []
        for pos, g in enumerate(chunk):
            if g is self.graph:
                results[pos] = self.count()
                continue
            lane = (self.options.algorithm
                    if self.options.algorithm != "auto"
                    else self._choose_auto(g))
            if self._batchable(lane):
                batchable.append((pos, g))
            else:
                if self.mesh is not None and \
                        not getattr(self, "_warned_mesh_fallback", False):
                    self._warned_mesh_fallback = True
                    warnings.warn(
                        f"count_many: lane {lane!r} under a mesh is not "
                        f"batchable — counting graph {g.name!r} (and any "
                        f"other non-batchable member) in a per-graph "
                        f"session instead of one stacked dispatch; a "
                        f"sharded GraphBatch is tracked follow-up work",
                        UserWarning, stacklevel=4)
                results[pos] = TriangleCounter(
                    g, self.options, mesh=self.mesh
                ).count()
        if len(batchable) == 1:  # nothing to stack; a plain session is cheaper
            pos, g = batchable[0]
            results[pos] = TriangleCounter(g, self.options,
                                           mesh=self.mesh).count()
        elif batchable:
            opts = self.options if self.options.algorithm == "intersection" \
                else self.options.replace(algorithm="intersection")
            batch = GraphBatch.from_graphs([g for _, g in batchable], opts)
            t0 = time.perf_counter()
            counts = batch.counts()
            exec_seconds = time.perf_counter() - t0
            for (pos, g), c in zip(batchable, counts):
                results[pos] = CountResult(
                    count=int(c),
                    algorithm="intersection",
                    options=self.options,
                    bucket_strategies=batch.meta["bucket_strategies"],
                    prep_seconds=batch.prep_seconds,
                    exec_seconds=exec_seconds,
                    plan=batch,
                    meta=dict(batch.meta, graph=g.name, n=g.n,
                              m=g.m_undirected, batched=True),
                )
        return results

    # -- per-vertex analysis, routed through the cached plan ---------------

    def triangles_per_vertex(self) -> np.ndarray:
        """(n,) int64 per-vertex triangle counts.

        Replays the session plan's device buffers when the lane supports it
        (filtered intersection, subgraph); other lanes fall back to a
        filtered-intersection sidecar over the same widths. Either way the
        result is memoized on the session and the executables live in the
        engine's shared cache — no host-side re-enumeration per call.
        """
        if self._vertex_counts is None:
            plan = self.plan
            if not hasattr(plan, "triangles_per_vertex"):
                t = _vertex_counts_sidecar(self.graph, self.options)
            else:
                try:
                    t = plan.triangles_per_vertex()
                except NotImplementedError:
                    t = _vertex_counts_sidecar(self.graph, self.options)
            self._vertex_counts = t
        return self._vertex_counts.copy()

    # -- per-edge analysis (support / k-truss), routed through the engine --

    def _edge_plan(self):
        """The session's edge-lane plan (``TrussPlan``): the session plan
        itself when ``algorithm="edge"``, else a memoized sidecar built from
        the same options — so equal options share the engine's cached edge
        executables either way."""
        if self.algorithm == "edge":
            return self.plan
        if self._edge_sidecar is None:
            planner = registry.get_algorithm("edge")
            self._edge_sidecar = planner(self.graph, self.options,
                                         mesh=self.mesh)
        return self._edge_sidecar

    def edge_support(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, support) with src < dst: per-undirected-edge triangle
        membership counts, replayed through the engine's cached edge
        executables (same order and dtypes as the deprecated
        ``repro.core.listing.edge_support``)."""
        return self._edge_plan().edge_support()

    def k_truss(self, k: int, *, max_iters: Optional[int] = None):
        """Maximal subgraph where every edge is in ≥ k − 2 triangles.

        Runs the device peel loop (support recompute → filter → re-orient
        until fixpoint or ``max_iters``, default the session's
        ``max_peel_iters``); the surviving edge set is bit-identical to the
        deprecated host path ``repro.core.listing.k_truss``. Returns a
        ``Graph``.
        """
        return self._edge_plan().k_truss(k, max_iters=max_iters)

    def truss_decomposition(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, trussness) with src < dst: for every edge, the largest
        k such that it survives the k-truss (2 for edges in no triangle).
        Raises ValueError if ``max_peel_iters`` truncates any level's peel
        before its fixpoint (trussness is only defined at the fixpoint)."""
        return self._edge_plan().truss_decomposition()

    def clustering_coefficients(self) -> np.ndarray:
        """cc[v] = 2·t(v) / (d(v)·(d(v)−1)); 0 where degree < 2."""
        t = self.triangles_per_vertex().astype(np.float64)
        d = self.graph.degrees.astype(np.float64)
        denom = d * (d - 1.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(denom > 0, 2.0 * t / denom, 0.0)

    def transitivity(self) -> float:
        """3 · #triangles / #wedges (= Σ t(v) / #wedges)."""
        t = int(self.triangles_per_vertex().sum())
        d = self.graph.degrees.astype(np.int64)
        wedges = int((d * (d - 1) // 2).sum())
        return float(t) / wedges if wedges else 0.0

    def __repr__(self) -> str:
        return (f"TriangleCounter(graph={self.graph.name!r}, "
                f"algorithm={self.algorithm!r}, "
                f"planned={self._plan is not None})")


class DynamicTriangleCounter(CounterSession):
    """A dynamic-graph session: batched edge updates, incremental count.

    Seed it with a ``Graph`` (possibly empty — ``edges_to_csr([], [],
    n=...)``), then stream update batches through ``apply_updates``::

        from repro.core import DynamicTriangleCounter, EdgeUpdate

        dc = DynamicTriangleCounter(g, update_batch_size=256)
        dc.count()                                   # seed count
        dc.apply_updates([EdgeUpdate(0, 1),          # insert (default)
                          EdgeUpdate(2, 3, insert=False),
                          (4, 5)])                   # bare pair = insert
        dc.count()                                   # maintained count

    Updates are normalized on the host (oriented, self-loops dropped,
    last-wins per edge within a batch — exact under set semantics), then
    applied ``update_batch_size`` at a time by the cached device step +
    delta executables of ``repro.core.engine.DynamicPlan``. ``count()`` is
    O(1): the count is maintained, not recomputed. Duplicate inserts and
    deletes of absent edges are no-ops. Every ``recount_interval`` batches
    (a ``CountOptions`` knob; 0 disables) a full from-scratch recount
    asserts the maintained count bit-exactly; ``recount()`` runs the same
    oracle on demand and ``snapshot()`` materializes the current edge set
    as a host ``Graph``.

    The session always runs the "dynamic" registry lane: constructing it
    with ``algorithm`` set to any other lane raises ``ValueError``.
    """

    def _resolve_algorithm(self) -> str:
        if self.options.algorithm not in ("auto", "dynamic"):
            raise ValueError(
                f"DynamicTriangleCounter always runs the dynamic lane; "
                f"got algorithm={self.options.algorithm!r} "
                f"(expected one of ('auto', 'dynamic'))")
        return "dynamic"

    def apply_updates(self, updates) -> CountResult:
        """Apply one batch of edge updates and return the refreshed count.

        ``updates`` is any iterable of ``EdgeUpdate`` named tuples,
        ``(u, v)`` pairs (implicit insert), or ``(u, v, insert)`` triples;
        vertex ids must lie in ``[0, n)``. The returned ``CountResult``'s
        ``exec_seconds`` covers the whole batch (update chunks + delta
        passes), and its ``meta`` reflects the post-update session state.
        """
        lo, hi, ins = normalize_edge_updates(updates, self.graph.n)
        plan = self.plan
        t0 = time.perf_counter()
        plan.apply_updates(lo, hi, ins)
        res = self.count()
        res.exec_seconds = time.perf_counter() - t0
        return res

    def recount(self) -> int:
        """Run the full-recount parity oracle now (raises on drift)."""
        return self.plan.recount()

    def snapshot(self) -> Graph:
        """The current device edge set as a host ``Graph``."""
        return self.plan.snapshot()

    @property
    def m_undirected(self) -> int:
        """The current number of live undirected edges."""
        return self.plan.m

    def __repr__(self) -> str:
        return (f"DynamicTriangleCounter(graph={self.graph.name!r}, "
                f"planned={self._plan is not None})")


def _vertex_counts_sidecar(g: Graph, options: CountOptions) -> np.ndarray:
    """Per-vertex counts for lanes whose plans carry no edge endpoints
    (matrix, full-variant intersection, custom lanes): a filtered-intersection
    plan over the same widths, sharing the cached ``"vertex"`` executables.
    The plan's count executables are jit-lazy, so none compile here."""
    plan = plan_triangle_count(
        g, "intersection", variant="filtered", backend="jnp",
        widths=options.widths,
    )
    return plan.triangles_per_vertex()
