"""Matrix-multiplication triangle counting (Azad/Buluç/Gilbert via the paper
§3.3/§4.3), reformulated as a *masked block-SpGEMM* for the TPU MXU.

Algorithm 3 of the paper:
  1. permute A by increasing degree,
  2. split A = L + U (strict lower/upper),
  3. B = L·U,  C = A ∘ B,  count = ½·ΣΣ C.

The paper calls cuSPARSE csrgemm and shows the unmasked SpGEMM is the
bottleneck (intermediate B hits global memory; multiplications run where A is
known zero). Here the host builds a *tile schedule* instead
(:func:`repro.core.engine.build_tile_schedule`):

  * A (permuted) is tiled into dense 128×128 blocks (BSR); only nonzero tiles
    exist.
  * For every strict-upper nonzero tile A[I,J] and every K present in both
    block-row I of L and block-col J of U, emit the triple (A[I,J], L[I,K],
    U[K,J]).  Block-level scheduling = the paper's optimization (2) "avoid
    multiplications where A is known to be zero a priori", lifted to tiles.
  * The fused kernel computes sum(A_IJ ∘ (L_IK @ U_KJ)) per triple and never
    materializes L·U — optimizations (1) upper-only and (3) no-global-output.

count = Σ_t partial_t  exactly (each triangle counted once at its min-rank
wedge, which lands in the strict upper triangle after the degree permutation).

Degenerate diagonal tiles (I == J) carry both L and U nonzeros; they are
handled naturally because L/U tiles are built from the strict parts.

This module registers the ``"matrix"`` lane with the algorithm registry; the
front door is ``TriangleCounter(g, CountOptions(algorithm="matrix", ...))``.
The one-shot ``triangle_count_matrix`` below is a deprecated shim kept for
source compatibility.
"""

from __future__ import annotations

from typing import Optional

from repro.graphs.formats import Graph
from repro.core.engine import (
    build_tile_schedule,  # re-export (prep lives in repro.core.prep)
    choose_block,  # re-export
    plan_triangle_count,
)
from repro.core.registry import register_algorithm

__all__ = ["triangle_count_matrix", "build_tile_schedule", "choose_block"]


def _planner(g: Graph, options, *, mesh=None):
    """Registry planner: CountOptions → matrix-lane TrianglePlan."""
    return plan_triangle_count(g, "matrix", **options.plan_kwargs("matrix"))


register_algorithm("matrix", _planner)


def triangle_count_matrix(
    g: Graph,
    *,
    block=128,  # int or "auto" (adaptive — see choose_block)
    permute: bool = True,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
) -> int:
    """Deprecated shim: exact triangle count via fused masked block-SpGEMM.

    Use ``TriangleCounter(g, CountOptions(algorithm="matrix", ...))``
    instead; ``interpret=None`` now means the process-wide
    ``DEFAULT_INTERPRET``. Returns the exact count as a Python int
    (unchanged behavior).
    """
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_matrix(g, ...)",
        'TriangleCounter(g, CountOptions(algorithm="matrix", ...)).count()',
    )
    opts = CountOptions(
        algorithm="matrix", block=block, permute=permute, backend=backend,
        interpret=interpret,
    )
    return int(TriangleCounter(g, opts).count())
