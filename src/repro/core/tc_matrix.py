"""Matrix-multiplication triangle counting (Azad/Buluç/Gilbert via the paper
§3.3/§4.3), reformulated as a *masked block-SpGEMM* for the TPU MXU.

Algorithm 3 of the paper:
  1. permute A by increasing degree,
  2. split A = L + U (strict lower/upper),
  3. B = L·U,  C = A ∘ B,  count = ½·ΣΣ C.

The paper calls cuSPARSE csrgemm and shows the unmasked SpGEMM is the
bottleneck (intermediate B hits global memory; multiplications run where A is
known zero). Here the host builds a *tile schedule* instead:

  * A (permuted) is tiled into dense 128×128 blocks (BSR); only nonzero tiles
    exist.
  * For every strict-upper nonzero tile A[I,J] and every K present in both
    block-row I of L and block-col J of U, emit the triple (A[I,J], L[I,K],
    U[K,J]).  Block-level scheduling = the paper's optimization (2) "avoid
    multiplications where A is known to be zero a priori", lifted to tiles.
  * The fused kernel computes sum(A_IJ ∘ (L_IK @ U_KJ)) per triple and never
    materializes L·U — optimizations (1) upper-only and (3) no-global-output.

count = Σ_t partial_t  exactly (each triangle counted once at its min-rank
wedge, which lands in the strict upper triangle after the degree permutation).

Degenerate diagonal tiles (I == J) carry both L and U nonzeros; they are
handled naturally because L/U tiles are built from the strict parts.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.graphs.formats import (
    BlockSparse,
    Graph,
    apply_permutation,
    degree_order_permutation,
    to_block_sparse,
)
from repro.kernels.masked_spgemm.ops import masked_spgemm_counts

__all__ = ["triangle_count_matrix", "build_tile_schedule", "choose_block"]


def choose_block(g: Graph) -> int:
    """Adaptive tile size (§Perf hillclimb, beyond-paper): degree-permuted
    scale-free graphs densify the bottom-right tile cluster, so 128 (MXU
    native) wins; mesh-like graphs (low, uniform degree) never fill tiles —
    measured 40,000× MXU-flop waste and 25× wall-time regression at 128 vs
    32 on road-like — so low-avg-degree graphs get small tiles."""
    avg_deg = 2.0 * g.m_undirected / max(g.n, 1)
    return 128 if avg_deg >= 8.0 else 32


def build_tile_schedule(
    g: Graph, block: int = 128, permute: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Host scheduler: returns stacked (T,B,B) L/U/A tile triples + stats.

    The returned triples are sorted heavy-first (by block density product) and
    are the unit of distribution for multi-device TC (core/distributed.py uses
    a snake round-robin over this order for static load balance — the TPU
    analogue of merge-path's equal-work splitting).
    """
    if permute:
        perm = degree_order_permutation(g)
        g = apply_permutation(g, perm)
    a_bsr = to_block_sparse(g, block=block, part="upper")  # mask: strict upper
    l_bsr = to_block_sparse(g, block=block, part="lower")
    u_bsr = to_block_sparse(g, block=block, part="upper")

    # block-row index of L: row -> list of (K, tile_id); block-col index of U
    l_rows: dict = {}
    for t in range(l_bsr.num_blocks):
        l_rows.setdefault(int(l_bsr.block_row[t]), []).append(
            (int(l_bsr.block_col[t]), t)
        )
    u_cols: dict = {}
    for t in range(u_bsr.num_blocks):
        u_cols.setdefault(int(u_bsr.block_col[t]), []).append(
            (int(u_bsr.block_row[t]), t)
        )

    trip_l, trip_u, trip_a = [], [], []
    for t in range(a_bsr.num_blocks):
        bi, bj = int(a_bsr.block_row[t]), int(a_bsr.block_col[t])
        lk = dict(l_rows.get(bi, ()))
        uk = dict(u_cols.get(bj, ()))
        for k in lk.keys() & uk.keys():
            trip_a.append(t)
            trip_l.append(lk[k])
            trip_u.append(uk[k])

    T = len(trip_a)
    stats = dict(
        num_triples=T,
        a_tiles=a_bsr.num_blocks,
        l_tiles=l_bsr.num_blocks,
        u_tiles=u_bsr.num_blocks,
        grid=a_bsr.grid,
        block=block,
        tile_flops=2 * T * block**3,
    )
    if T == 0:
        z = np.zeros((0, block, block), dtype=np.float32)
        return z, z, z, stats

    l_sel = l_bsr.blocks[np.asarray(trip_l)]
    u_sel = u_bsr.blocks[np.asarray(trip_u)]
    a_sel = a_bsr.blocks[np.asarray(trip_a)]
    # heavy-first ordering by nnz(L)·nnz(U) so chunked execution and
    # round-robin sharding see a monotone work profile
    work = l_sel.sum(axis=(1, 2)) * u_sel.sum(axis=(1, 2))
    order = np.argsort(-work, kind="stable")
    return l_sel[order], u_sel[order], a_sel[order], stats


def triangle_count_matrix(
    g: Graph,
    *,
    block=128,  # int or "auto" (adaptive — see choose_block)
    permute: bool = True,
    backend: str = "jnp",
    interpret: bool = True,
) -> int:
    """Exact triangle count via fused masked block-SpGEMM."""
    if block == "auto":
        block = choose_block(g)
    l_sel, u_sel, a_sel, _ = build_tile_schedule(g, block=block, permute=permute)
    if l_sel.shape[0] == 0:
        return 0
    partials = masked_spgemm_counts(
        jnp.asarray(l_sel),
        jnp.asarray(u_sel),
        jnp.asarray(a_sel),
        backend=backend,
        interpret=interpret,
    )
    return int(round(float(jnp.sum(partials))))
