"""Algorithm registry + the cross-lane ``algorithm="auto"`` cost model.

The paper's central result is *comparative*: no formulation wins everywhere,
so lane choice is a tunable of one system (as TRUST, arXiv:2103.08053, and
the GraphChallenge survey, arXiv:2003.09269, treat it), not three separate
entry points. Each lane registers a *planner* here —
``planner(g, options, *, mesh=None) -> plan-like`` where plan-like exposes
``count()``, ``meta``, and ``prep_seconds`` (normally a ``TrianglePlan``;
``OneShotPlan`` remains as an adapter for external lanes that wrap a bare
callable) — and the facade (``repro.core.api.TriangleCounter``) looks lanes
up by name.

Builtin lanes: the five engine counting lanes ("intersection" / "matrix" /
"subgraph" / "hash" — TRUST-style per-vertex hash probing — / "bfs" —
level-ordered forward-edge closure), the dynamic lane ("dynamic"), the
edge-analytics lane ("edge" — per-edge support and the device k-truss
peel, ``repro.core.engine.TrussPlan``), and the two mesh-planned
distributed lanes ("intersection_distributed" / "matrix_distributed" —
first-class ``TrianglePlan``s over dealt shards, see
``repro.core.distributed``).

``choose_algorithm(g)`` is the documented heuristic ``algorithm="auto"``
cost model, anchored to the paper's figures and calibrated on this repo's
dataset registry (see the rule list on ``_default_chooser``). It is
overridable two ways: ``set_auto_chooser(fn)`` swaps the heuristic
process-wide (returning the previous one), and
``CountOptions(chooser="measured")`` routes "auto" through the per-device
calibration table in ``repro.core.calibrate`` instead (measured micro-run
timings, analytically seeded cold start, heuristic fallback). The chosen
lane is always surfaced in ``CountResult.algorithm``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

__all__ = [
    "OneShotPlan",
    "available_algorithms",
    "choose_algorithm",
    "get_algorithm",
    "register_algorithm",
    "set_auto_chooser",
]

_REGISTRY: Dict[str, Callable] = {}


def register_algorithm(name: str, planner: Callable, *,
                       overwrite: bool = False) -> None:
    """Register a lane under ``name``.

    Args:
      name: lane name ``CountOptions(algorithm=...)`` selects.
      planner: ``planner(g, options, *, mesh=None)`` returning a plan-like
        object (``count()`` + ``meta`` + ``prep_seconds``).
      overwrite: allow replacing an existing registration (default False —
        accidental double registration raises).
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"algorithm name must be a non-empty str, got {name!r}")
    if not callable(planner):
        raise ValueError(f"planner for {name!r} must be callable")
    if not overwrite and name in _REGISTRY and _REGISTRY[name] is not planner:
        raise ValueError(f"algorithm {name!r} is already registered; "
                         f"pass overwrite=True to replace it")
    _REGISTRY[name] = planner


def _ensure_builtin() -> None:
    """Import the builtin lane modules so their registrations have run
    (each registers at import; ``repro.core`` imports them all, but the
    registry must also work when imported standalone)."""
    import repro.core.engine  # noqa: F401  (registers the "edge" lane)
    import repro.core.tc_intersection  # noqa: F401
    import repro.core.tc_matrix  # noqa: F401
    import repro.core.tc_subgraph  # noqa: F401
    import repro.core.distributed  # noqa: F401


def get_algorithm(name: str) -> Callable:
    """The registered planner for ``name``; ValueError lists what exists."""
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {available_algorithms()}"
        ) from None


def available_algorithms() -> tuple:
    """Sorted names of every registered lane."""
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


@dataclasses.dataclass
class OneShotPlan:
    """Adapter giving non-engine lanes (the distributed variants) the
    ``TrianglePlan`` surface the facade consumes: ``count()`` re-runs the
    wrapped callable each time (host stage included — these lanes shard the
    host-built schedule fresh per count), ``meta``/``prep_seconds``/
    ``executions`` mirror the plan fields."""

    fn: Callable[[], int]
    algorithm: str
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    prep_seconds: float = 0.0
    executions: int = 0

    def count(self) -> int:
        out = int(self.fn())
        self.executions += 1
        return out


# ---------------------------------------------------------------------------
# The algorithm="auto" cost model
# ---------------------------------------------------------------------------

# Calibrated on the Table-1 analogue registry (graphs/datasets.py) and the
# generator suite: mesh-like graphs (road-like, grids) sit at max degree ≤ 10
# with skew (= max/avg degree) ≤ ~2; scale-free graphs (R-MAT families) at
# skew ≥ 12; only the dense complete-graph fixtures reach density ≥ 0.25.
MESH_MAX_DEGREE = 12
MESH_MAX_SKEW = 3.0
DENSE_MIN_DENSITY = 0.25
DENSE_MAX_N = 512


def _default_chooser(g) -> str:
    """Pick a lane from graph shape. Documented contract:

    1. **matrix** when the graph is small and dense (density ≥ 0.25,
       n ≤ 512): the degree-permuted adjacency fills whole MXU tiles, the
       one regime where the paper's ~20× SpGEMM constant (Fig. 6) is paid
       over saturated matmuls instead of empty lanes.
    2. **subgraph** when the graph is mesh-like — max degree ≤ 12 AND
       degree skew (max/avg) ≤ 3 — the paper's 'rm' class (road_central),
       where Fig. 5 shows the SM filter winning: leaf cascades collapse
       under the 2-core peel before any intersection runs.
    3. **intersection** otherwise — the paper's overall winner (Fig. 5:
       fastest on every scale-free graph, thanks to its filtering steps).

    The id-range heuristic the bitmap core depends on operates one level
    down: *within* the intersection/subgraph lanes, ``choose_strategy``
    hands dense-id buckets to the packed-bitmap kernel (see
    ``repro.kernels.intersect.ops``), so lane choice here never needs it.

    The chooser itself is mesh-blind — it names the *formulation*. When the
    session carries a multi-device mesh, ``choose_algorithm(g, mesh=mesh)``
    promotes the pick to the matching distributed lane afterwards (see
    ``_promote_distributed``), so a sharded session's ``algorithm="auto"``
    lands on the planned distributed lanes automatically.
    """
    n, m, dmax = g.n, g.m_undirected, g.max_degree
    if n < 3 or m == 0:
        return "intersection"
    avg_deg = 2.0 * m / n
    density = 2.0 * m / (n * (n - 1)) if n > 1 else 0.0
    skew = dmax / max(avg_deg, 1e-9)
    if density >= DENSE_MIN_DENSITY and n <= DENSE_MAX_N:
        return "matrix"
    if dmax <= MESH_MAX_DEGREE and skew <= MESH_MAX_SKEW:
        return "subgraph"
    return "intersection"


_CHOOSER: Callable = _default_chooser


def _promote_distributed(lane: str, mesh) -> str:
    """Map a chooser's single-host pick to its distributed counterpart when a
    multi-device mesh is present.

    ``mesh is None`` or a 1-device mesh leaves the pick unchanged (a trivial
    mesh gains nothing from the psum lanes). Otherwise "matrix" promotes to
    "matrix_distributed" and every other counting formulation rides the
    dealt degree-class buckets as "intersection_distributed" (the subgraph /
    hash / bfs formulations have no sharded build yet — the intersection
    deal is the closest-cost distributed plan for their graphs). A pick that
    is already distributed passes through.
    """
    if mesh is None or int(mesh.devices.size) <= 1:
        return lane
    if lane.endswith("_distributed"):
        return lane
    if lane == "matrix":
        return "matrix_distributed"
    return "intersection_distributed"


def choose_algorithm(g, mesh=None) -> str:
    """Resolve ``algorithm="auto"`` for graph ``g`` via the current chooser
    (the documented ``_default_chooser`` unless ``set_auto_chooser`` swapped
    it). With a multi-device ``mesh``, the pick is promoted to the matching
    distributed lane (``_promote_distributed``). Always returns a registered
    lane name."""
    lane = _promote_distributed(_CHOOSER(g), mesh)
    _ensure_builtin()
    if lane not in _REGISTRY:
        raise ValueError(
            f"auto chooser returned unregistered lane {lane!r}; "
            f"registered: {available_algorithms()}"
        )
    return lane


def set_auto_chooser(chooser: Optional[Callable] = None) -> Callable:
    """Override the ``algorithm="auto"`` heuristic process-wide.

    Args:
      chooser: ``chooser(g) -> lane name``, or None to restore the default.

    Returns:
      The previously active chooser (so callers can restore it).
    """
    global _CHOOSER
    previous = _CHOOSER
    _CHOOSER = chooser if chooser is not None else _default_chooser
    return previous
