"""Measured ``algorithm="auto"``: per-device calibration tables.

The paper's central result is comparative — the winning formulation depends
on the graph — and with five registered counting lanes the hand-written
shape rules on ``registry._default_chooser`` stop being credible. This
module replaces guessing with measurement:

* ``graph_features`` / ``feature_key`` reduce a graph to a coarse bin:
  its degree-class **bucket width** (the dominant static shape the engine
  compiles for), a **degree-skew** band, and a **density** band — the same
  axes the heuristic rules used, now indexing data instead of if-chains.
* ``calibrate`` builds a :class:`CalibrationTable` by timing warm
  ``plan.count()`` micro-runs per lane per feature bin (best-of-k, prep
  excluded — plans are cached per session, so steady-state cost is the
  count replay).
* **Cold start is analytic, not blind**: ``analytic_seed`` prices each
  lane's compiled stage executables with ``launch.hlo_cost.analyze_hlo`` +
  ``launch.roofline.roofline_terms`` (AOT ``.lower().compile()``, no
  execution), so a table can rank lanes for a bin no timing has visited.
  Analytic entries never overwrite measured ones.
* Tables persist as a ``CALIB_<device>.json`` sidecar (schema below) next
  to the ``BENCH_*.json`` files; ``benchmarks/run.py --figures fig_auto``
  writes one and ``tests/test_bench_sidecar.py`` gates the schema.

Sidecar schema (``CALIB_SCHEMA_VERSION = 1``)::

    {
      "schema": 1,
      "device": "<sanitized device kind>",
      "created_unix": <float>,
      "entries": [
        {"key": ["w:32", "skew:low", "dens:sparse"],
         "timings": {"intersection": 1.2e-4, "hash": 9.8e-5, ...},
         "source": "measured" | "analytic"},
        ...
      ]
    }

Wiring: ``CountOptions(chooser="measured")`` makes the facade resolve
``algorithm="auto"`` through ``choose_measured`` (exact bin hit, else the
nearest measured bin, else the heuristic fallback), and
``install_measured_chooser(table)`` swaps the process-wide chooser via
``registry.set_auto_chooser`` for code that never touches options.
Invalidation is by construction: the device label is part of the sidecar
name, the schema version is checked on load, and a corrupt or mismatched
sidecar silently falls back to the heuristic (the chooser must never be a
crash surface).
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core import registry
from repro.core.options import CountOptions, DEFAULT_WIDTHS
from repro.graphs.device import next_pow2

__all__ = [
    "CALIB_SCHEMA_VERSION",
    "CHOOSER_LANES",
    "CalibrationTable",
    "analytic_seed",
    "calib_path",
    "calibrate",
    "choose_measured",
    "device_label",
    "feature_key",
    "graph_features",
    "install_measured_chooser",
    "load_table",
    "measure_lanes",
    "price_plan",
    "save_table",
    "set_default_table",
]

CALIB_SCHEMA_VERSION = 1

# The single-host counting lanes the measured chooser ranks. With a
# multi-device mesh the ranked pick is promoted to its distributed
# counterpart afterwards (``registry._promote_distributed``) — the table
# ranks formulations, not shardings, so its schema stays mesh-free.
CHOOSER_LANES = ("intersection", "matrix", "subgraph", "hash", "bfs")

# feature-bin thresholds — shared with the heuristic rules they replace
_SKEW_BANDS = ((3.0, "low"), (12.0, "mid"), (float("inf"), "high"))
_DENSITY_BANDS = ((0.01, "thin"), (0.25, "sparse"), (float("inf"), "dense"))


def device_label() -> str:
    """Sanitized identity of the device the table is valid for.

    Derived from the default device's ``device_kind`` (platform as a
    fallback) with non-filename characters collapsed — it names the
    ``CALIB_<device>.json`` sidecar, so a table can never be loaded onto a
    different device kind by accident.
    """
    dev = jax.devices()[0]
    raw = getattr(dev, "device_kind", "") or dev.platform
    return re.sub(r"[^A-Za-z0-9._-]+", "-", str(raw)).strip("-") or "unknown"


def calib_path(json_dir: str = ".", device: Optional[str] = None) -> str:
    """The sidecar path for ``device`` (default: the current device)."""
    return os.path.join(json_dir, f"CALIB_{device or device_label()}.json")


def graph_features(g) -> dict:
    """Raw chooser features of one graph (the bins hash ``feature_key``).

    ``bucket_width`` is the degree-class width the engine would compile the
    widest bucket at — the smallest ``DEFAULT_WIDTHS`` class covering the
    max degree, or the next pow2 beyond the last class — i.e. the dominant
    static shape, which is what actually prices a lane.
    """
    n, m, dmax = int(g.n), int(g.m_undirected), int(g.max_degree)
    avg = 2.0 * m / n if n else 0.0
    density = 2.0 * m / (n * (n - 1)) if n > 1 else 0.0
    skew = dmax / avg if avg > 0 else 0.0
    if m == 0 or dmax == 0:
        width = 0
    else:
        width = next(
            (w for w in DEFAULT_WIDTHS if dmax <= w), next_pow2(dmax)
        )
    return dict(n=n, m=m, max_degree=dmax, avg_degree=avg, density=density,
                skew=skew, bucket_width=int(width))


def _band(value: float, bands) -> str:
    for bound, name in bands:
        if value <= bound:
            return name
    return bands[-1][1]


def feature_key(feats: dict) -> Tuple[str, str, str]:
    """The coarse bin a graph's timings are filed under:
    ``("w:<bucket_width>", "skew:<low|mid|high>", "dens:<thin|sparse|dense>")``.
    """
    return (
        f"w:{feats['bucket_width']}",
        f"skew:{_band(feats['skew'], _SKEW_BANDS)}",
        f"dens:{_band(feats['density'], _DENSITY_BANDS)}",
    )


_SKEW_ORD = {"low": 0, "mid": 1, "high": 2}
_DENS_ORD = {"thin": 0, "sparse": 1, "dense": 2}


def _key_distance(a: Tuple[str, str, str], b: Tuple[str, str, str]) -> float:
    """Ordinal distance between feature bins (nearest-bin fallback)."""
    wa, wb = int(a[0][2:]), int(b[0][2:])
    dw = abs(max(wa, 1).bit_length() - max(wb, 1).bit_length())
    ds = abs(_SKEW_ORD[a[1][5:]] - _SKEW_ORD[b[1][5:]])
    dd = abs(_DENS_ORD[a[2][5:]] - _DENS_ORD[b[2][5:]])
    return dw + ds + dd


@dataclasses.dataclass
class CalibrationTable:
    """Per-device lane timings, keyed by feature bin.

    ``entries[key][lane]`` is the lane's representative seconds for that
    bin (best observed across the calibration graphs landing in it);
    ``sources[key]`` records whether the bin is "measured" (timed
    micro-runs) or "analytic" (HLO/roofline pricing, the cold-start seed).
    """

    device: str
    entries: Dict[Tuple[str, str, str], Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    sources: Dict[Tuple[str, str, str], str] = \
        dataclasses.field(default_factory=dict)
    schema: int = CALIB_SCHEMA_VERSION

    def record(self, key: Tuple[str, str, str], timings: Dict[str, float],
               source: str) -> None:
        """Merge one bin's timings. Measured beats analytic; two measured
        visits keep the per-lane minimum (best-case representative)."""
        have = self.sources.get(key)
        if have == "measured" and source == "analytic":
            return
        if have is None or (have == "analytic" and source == "measured"):
            self.entries[key] = dict(timings)
            self.sources[key] = source
            return
        merged = self.entries[key]
        for lane, t in timings.items():
            merged[lane] = min(merged.get(lane, float("inf")), float(t))

    def lookup(self, g) -> Optional[Dict[str, float]]:
        """The exact-bin timings for ``g``, or None."""
        return self.entries.get(feature_key(graph_features(g)))

    def choose(self, g) -> Optional[str]:
        """The fastest lane for ``g``'s bin (nearest bin on a miss), or
        None when the table is empty. Ties break lexicographically so the
        choice is deterministic."""
        if not self.entries:
            return None
        key = feature_key(graph_features(g))
        timings = self.entries.get(key)
        if timings is None:
            key = min(self.entries, key=lambda k: (_key_distance(k, key), k))
            timings = self.entries[key]
        if not timings:
            return None
        return min(sorted(timings), key=lambda lane: timings[lane])


# ---------------------------------------------------------------------------
# Analytic seeding — price compiled executables without running them
# ---------------------------------------------------------------------------

def price_plan(plan) -> float:
    """Analytic seconds for one plan's count stage.

    Each stage executable is AOT-lowered and compiled (never executed); the
    optimized HLO is priced by ``launch.hlo_cost.analyze_hlo`` with XLA's
    own ``cost_analysis`` as the fallback, and ``launch.roofline`` turns
    bytes/flops into time. The estimate is the sum over stages of the
    max(compute, memory, collective) roofline term — a lower bound that is
    nonetheless monotone in the work a lane dispatches, which is all a
    *ranking* needs.
    """
    from repro.launch.roofline import roofline_terms

    total = 0.0
    for st in plan.stages:
        compiled = st.executable.lower(*st.args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        terms = roofline_terms(dict(cost or {}), compiled.as_text(),
                               model_flops_per_chip=0.0)
        total += max(terms.t_compute, terms.t_memory, terms.t_collective)
    return total


def _build_plan(g, lane: str, options: CountOptions):
    planner = registry.get_algorithm(lane)
    return planner(g, options.replace(algorithm=lane))


def analytic_seed(g, lanes: Sequence[str] = CHOOSER_LANES,
                  options: Optional[CountOptions] = None) -> Dict[str, float]:
    """Cold-start lane pricing for one graph: {lane: analytic seconds}.

    Deterministic for equal ``CountOptions`` — planning, lowering, and the
    HLO cost walk are all pure functions of (graph, options, jax version) —
    which is what lets a freshly seeded table make stable choices before
    any timing exists (and what the invariance test in
    ``tests/test_hlo_pricing.py`` asserts).
    """
    options = options if options is not None else CountOptions()
    return {lane: price_plan(_build_plan(g, lane, options)) for lane in lanes}


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def measure_lanes(g, lanes: Sequence[str] = CHOOSER_LANES,
                  options: Optional[CountOptions] = None, *,
                  iters: int = 2, warmup: int = 1) -> Dict[str, float]:
    """Steady-state count seconds per lane: {lane: best-of-``iters``}.

    Times the warm ``plan.count()`` replay only (prep excluded — a session
    plans once and counts many times), after ``warmup`` untimed runs to
    absorb compilation.
    """
    options = options if options is not None else CountOptions()
    out: Dict[str, float] = {}
    for lane in lanes:
        plan = _build_plan(g, lane, options)
        for _ in range(max(0, warmup)):
            plan.count()
        best = float("inf")
        for _ in range(max(1, iters)):
            t0 = time.perf_counter()
            plan.count()
            best = min(best, time.perf_counter() - t0)
        out[lane] = best
    return out


def calibrate(graphs: Sequence, *, lanes: Sequence[str] = CHOOSER_LANES,
              options: Optional[CountOptions] = None, iters: int = 2,
              warmup: int = 1, measure: bool = True,
              device: Optional[str] = None) -> CalibrationTable:
    """Build a :class:`CalibrationTable` from a sweep of graphs.

    Args:
      graphs: the calibration fixtures; each lands in its feature bin.
      lanes: lanes to rank (default ``CHOOSER_LANES``).
      options: the ``CountOptions`` the plans are built with (default
        ``CountOptions()`` — the production defaults).
      iters / warmup: micro-run shape for the measured path.
      measure: True times micro-runs (source "measured"); False prices
        executables analytically instead (source "analytic") — the
        cold-start mode, no kernel ever executes.
      device: override the device label (tests); default the real one.
    """
    table = CalibrationTable(device=device or device_label())
    for g in graphs:
        key = feature_key(graph_features(g))
        if measure:
            timings = measure_lanes(g, lanes, options,
                                    iters=iters, warmup=warmup)
            table.record(key, timings, "measured")
        else:
            table.record(key, analytic_seed(g, lanes, options), "analytic")
    return table


# ---------------------------------------------------------------------------
# Persistence — the CALIB_<device>.json sidecar
# ---------------------------------------------------------------------------

def save_table(table: CalibrationTable, path: str) -> str:
    """Write the sidecar (schema above); returns ``path``."""
    doc = {
        "schema": table.schema,
        "device": table.device,
        "created_unix": time.time(),
        "entries": [
            {"key": list(key), "timings": dict(table.entries[key]),
             "source": table.sources.get(key, "measured")}
            for key in sorted(table.entries)
        ],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path


def load_table(path: str) -> CalibrationTable:
    """Read and validate a sidecar.

    Raises:
      ValueError: unknown schema version or malformed entries — callers
        that must never crash (the default-table search) catch this and
        fall back to the heuristic chooser.
    """
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != CALIB_SCHEMA_VERSION:
        raise ValueError(
            f"calibration sidecar {path!r} has schema {doc.get('schema')!r}; "
            f"this build reads schema {CALIB_SCHEMA_VERSION}"
        )
    table = CalibrationTable(device=str(doc.get("device", "unknown")))
    for ent in doc.get("entries", []):
        key = tuple(ent["key"])
        if len(key) != 3:
            raise ValueError(f"malformed entry key {key!r} in {path!r}")
        timings = {str(k): float(v) for k, v in ent["timings"].items()}
        table.record(key, timings, str(ent.get("source", "measured")))
    return table


# ---------------------------------------------------------------------------
# Chooser wiring
# ---------------------------------------------------------------------------

_DEFAULT_TABLE: Optional[CalibrationTable] = None
_DEFAULT_LOADED = False


def set_default_table(table: Optional[CalibrationTable]
                      ) -> Optional[CalibrationTable]:
    """Install the process-wide table ``chooser="measured"`` consults.

    Passing None clears it AND re-arms the disk search (``TC_CALIB`` env
    path, else ``./CALIB_<device>.json``). Returns the previous table so
    callers can restore it.
    """
    global _DEFAULT_TABLE, _DEFAULT_LOADED
    previous = _DEFAULT_TABLE
    _DEFAULT_TABLE = table
    _DEFAULT_LOADED = table is not None
    return previous


def get_default_table() -> Optional[CalibrationTable]:
    """The process-wide table, loading the sidecar lazily on first use."""
    global _DEFAULT_TABLE, _DEFAULT_LOADED
    if not _DEFAULT_LOADED:
        path = os.environ.get("TC_CALIB") or calib_path(".")
        if os.path.exists(path):
            try:
                _DEFAULT_TABLE = load_table(path)
            except (ValueError, OSError, KeyError, TypeError):
                _DEFAULT_TABLE = None  # corrupt sidecar ⇒ heuristic fallback
        _DEFAULT_LOADED = True
    return _DEFAULT_TABLE


def choose_measured(g, table: Optional[CalibrationTable] = None, *,
                    mesh=None) -> str:
    """Resolve ``algorithm="auto"`` through a calibration table.

    Exact feature-bin hit → fastest measured lane; miss → nearest bin;
    no table / empty table / stale lane name → the heuristic
    ``registry._default_chooser``. With a multi-device ``mesh`` the pick is
    promoted to its distributed counterpart
    (``registry._promote_distributed``). Always returns a registered lane.
    """
    table = table if table is not None else get_default_table()
    lane = None
    if table is not None:
        lane = table.choose(g)
        if lane is not None and lane not in registry.available_algorithms():
            lane = None
    if lane is None:
        lane = registry._default_chooser(g)
    return registry._promote_distributed(lane, mesh)


def install_measured_chooser(table: Optional[CalibrationTable] = None
                             ) -> Callable:
    """Swap the process-wide ``algorithm="auto"`` chooser to the measured
    one (for callers that never touch ``CountOptions``). Returns the
    previous chooser — pass it back to ``registry.set_auto_chooser`` to
    restore."""
    return registry.set_auto_chooser(lambda g: choose_measured(g, table))
