"""Multi-device / multi-pod triangle counting — the mesh-planned lanes.

TPU adaptation of Azad/Buluç's distributed masked SpGEMM (the paper cites
the distributed-masking variant as promising future work, §5), promoted into
the plan/execute engine (PR 9): distribution is no longer a one-shot
``shard_map`` bolted on beside the sessions, it is a *plan* —
``repro.core.engine.plan_triangle_count(g, "<lane>_distributed",
mesh=mesh)`` runs device prep once, deals the work round-robin across the
mesh's shards (``repro.graphs.device.ShardedDeviceCSR`` /
``deal_across_shards``), and binds each work unit to a per-shard executable
cached in the engine's process-wide LRU under a mesh-extended key. The
partition scheme:

  * degree-class buckets (intersection) or the heavy-first tile schedule
    (matrix) are dealt round-robin — shard ``s`` gets rows ``s``,
    ``s + P``, ``s + 2P``, … — so every shard receives an equal mix of
    dense and sparse work: static straggler mitigation, the multi-device
    analogue of the paper's TwoSmall/TwoLarge workload grouping,
  * per-shard padding is *length-gated* inside the executables (dynamic
    chunk-loop trip counts + a masked tail), so dealt padding contributes
    zero to the count and (on the matrix lane) zero FLOPs,
  * each shard reduces locally; ONE scalar ``psum`` over all mesh axes per
    stage yields the global count. Communication volume is O(P) scalars —
    triangle counting at 512 chips is bandwidth-free by construction, which
    the multi-pod dry-run (``launch/dryrun.py --tc``) verifies structurally
    against the same cached executable builder.

Because the lanes are ordinary ``TrianglePlan``s, everything the engine
gives single-host lanes now holds with a mesh present: warm sessions replay
with zero recompiles (cache-stats-asserted in ``tests/test_distributed.py``),
``TriangleCounter`` / ``count_many`` route through them, and both choosers
(heuristic and measured) promote their pick to the matching distributed lane
whenever the session carries a multi-device mesh.

This module only registers the planners. The legacy
``triangle_count_*_distributed`` functions below are deprecated shims kept
for source compatibility — they route through the facade and the planned
lanes (bit-identical results, one ``DeprecationWarning``).
"""

from __future__ import annotations

from typing import Optional, Sequence

from jax.sharding import Mesh

from repro.graphs.formats import Graph
from repro.core.engine import plan_triangle_count
from repro.core.options import DEFAULT_WIDTHS
from repro.core.registry import register_algorithm

__all__ = [
    "triangle_count_matrix_distributed",
    "triangle_count_intersection_distributed",
]


def _planner_matrix(g: Graph, options, *, mesh=None):
    """Registry planner for the ``"matrix_distributed"`` lane: a first-class
    ``TrianglePlan`` over the dealt tile schedule (prep once, cached
    per-shard executable, scalar psum)."""
    return plan_triangle_count(
        g, "matrix_distributed", mesh=mesh,
        **options.plan_kwargs("matrix_distributed"),
    )


def _planner_intersection(g: Graph, options, *, mesh=None):
    """Registry planner for the ``"intersection_distributed"`` lane."""
    return plan_triangle_count(
        g, "intersection_distributed", mesh=mesh,
        **options.plan_kwargs("intersection_distributed"),
    )


register_algorithm("matrix_distributed", _planner_matrix)
register_algorithm("intersection_distributed", _planner_intersection)


def triangle_count_matrix_distributed(
    g: Graph,
    mesh: Optional[Mesh] = None,
    *,
    block: int = 128,
) -> int:
    """Deprecated shim: use ``TriangleCounter(g,
    CountOptions(algorithm="matrix_distributed", block=...), mesh=mesh)``.
    Returns the exact count as a Python int (unchanged behavior)."""
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_matrix_distributed(g, mesh, ...)",
        'TriangleCounter(g, CountOptions(algorithm="matrix_distributed", '
        "...), mesh=mesh).count()",
    )
    opts = CountOptions(algorithm="matrix_distributed", block=block)
    return int(TriangleCounter(g, opts, mesh=mesh).count())


def triangle_count_intersection_distributed(
    g: Graph,
    mesh: Optional[Mesh] = None,
    *,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
) -> int:
    """Deprecated shim: use ``TriangleCounter(g,
    CountOptions(algorithm="intersection_distributed", ...), mesh=mesh)``.
    Returns the exact count as a Python int (unchanged behavior)."""
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_intersection_distributed(g, mesh, ...)",
        'TriangleCounter(g, CountOptions(algorithm="intersection_distributed"'
        ", ...), mesh=mesh).count()",
    )
    opts = CountOptions(algorithm="intersection_distributed",
                        widths=tuple(widths), strategy=strategy)
    return int(TriangleCounter(g, opts, mesh=mesh).count())
