"""Multi-device / multi-pod triangle counting via shard_map.

TPU adaptation of Azad/Buluç's distributed masked SpGEMM (the paper cites the
distributed-masking variant as promising future work, §5): the host-built tile
schedule is already a communication-free decomposition of C = A ∘ (L·U) —
every triple is independent — so the distribution strategy is:

  * pad the heavy-first triple list to a multiple of the device count,
  * deal triples round-robin (device d gets triples d, d+P, d+2P, …): because
    the list is sorted heavy-first, every device receives an equal mix of
    dense and sparse tiles — static straggler mitigation, the multi-device
    analogue of the paper's TwoSmall/TwoLarge workload grouping,
  * each device reduces its partial counts locally; one scalar `psum` over
    all mesh axes yields the global count.

The same scheme shards the intersection method over edges. Communication
volume is O(P) scalars total — triangle counting at 512 chips is bandwidth-
free by construction, which the multi-pod dry-run (launch/dryrun.py --arch tc)
verifies structurally.

Both variants register with the algorithm registry as the
``"matrix_distributed"`` / ``"intersection_distributed"`` lanes; the front
door is ``TriangleCounter(g, CountOptions(algorithm="..._distributed"),
mesh=mesh)``. The legacy ``triangle_count_*_distributed`` functions below are
deprecated shims kept for source compatibility.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax ≥ 0.6 exports shard_map at top level
    from jax import shard_map
except ImportError:  # older jax ships it under experimental
    from jax.experimental.shard_map import shard_map

from repro.graphs.formats import Graph
from repro.graphs.device import DEFAULT_SHAPE_POLICY
from repro.core import prep
from repro.core.engine import (
    DEFAULT_WIDTHS,
    build_tile_schedule,
    choose_block,
)
from repro.core.registry import OneShotPlan, register_algorithm
from repro.kernels.intersect.ops import intersect_counts, resolve_strategy

__all__ = [
    "triangle_count_matrix_distributed",
    "triangle_count_intersection_distributed",
]


def _deal(arr: np.ndarray, ndev: int) -> np.ndarray:
    """Pad with zeros then round-robin deal axis 0 into (ndev, T/ndev, ...)."""
    t = arr.shape[0]
    pad = (-t) % ndev
    if pad:
        arr = np.concatenate([arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])
    tt = arr.shape[0]
    idx = np.arange(tt).reshape(tt // ndev, ndev).T.reshape(-1)  # deal
    return arr[idx].reshape(ndev, tt // ndev, *arr.shape[1:])


def _matrix_distributed(
    g: Graph,
    mesh: Optional[Mesh] = None,
    *,
    block: int = 128,
) -> int:
    """Masked block-SpGEMM TC sharded over every axis of ``mesh``."""
    if mesh is None:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((jax.device_count(),), ("data",))
    ndev = int(np.prod(mesh.devices.shape))
    l_sel, u_sel, a_sel, _ = build_tile_schedule(g, block=block)
    if l_sel.shape[0] == 0:
        return 0
    l_d, u_d, a_d = (_deal(x, ndev) for x in (l_sel, u_sel, a_sel))
    axes = tuple(mesh.axis_names)
    spec = P(axes)  # shard leading (device) axis across all mesh axes

    @jax.jit
    def count(l, u, a):
        def local(l, u, a):
            l, u, a = l[0], u[0], a[0]  # drop unit device dim
            prod = jnp.einsum("tik,tkj->tij", l, u,
                              preferred_element_type=jnp.float32)
            part = (prod * a).sum()
            return jax.lax.psum(part, axes)

        return shard_map(
            local, mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=P(),
        )(l, u, a)

    # reshape so axis 0 == ndev factors over every mesh axis
    shape = mesh.devices.shape
    l_d = l_d.reshape(shape + l_d.shape[1:])
    u_d = u_d.reshape(shape + u_d.shape[1:])
    a_d = a_d.reshape(shape + a_d.shape[1:])
    # flatten mesh axes back into one leading axis for PartitionSpec((axes,))
    l_d = l_d.reshape((ndev,) + l_d.shape[len(shape):])
    u_d = u_d.reshape((ndev,) + u_d.shape[len(shape):])
    a_d = a_d.reshape((ndev,) + a_d.shape[len(shape):])
    out = count(jnp.asarray(l_d), jnp.asarray(u_d), jnp.asarray(a_d))
    return int(round(float(out)))


def _intersection_distributed(
    g: Graph,
    mesh: Optional[Mesh] = None,
    *,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
) -> int:
    """Forward-algorithm TC with each degree bucket's edges sharded.

    The prep stage is the device-resident pipeline (``repro.core.prep``):
    orientation, bucketing, and the padded gathers run as jitted stages and
    the resulting ``DeviceBucket`` arrays are resharded directly — no
    per-graph host numpy beyond the schedule scalars.

    Args:
      g: undirected simple ``Graph``.
      mesh: jax device mesh (defaults to a 1-D mesh over all devices); the
        bucket's edge axis is sharded over every mesh axis.
      widths: degree-class bucket widths.
      strategy: per-bucket set-intersection core, resolved on the host with
        the same ``resolve_strategy`` cost model the plan stage uses — each
        shard then runs the strategy's jnp core locally, so the sharded path
        and the single-device engine pick identical per-bucket kernels.

    Returns:
      The exact triangle count as a Python int (one scalar psum per bucket).
    """
    if mesh is None:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((jax.device_count(),), ("data",))
    ndev = int(np.prod(mesh.devices.shape))
    axes = tuple(mesh.axis_names)
    buckets = prep.prepare_intersection_buckets_device(
        g, variant="filtered", widths=widths, policy=DEFAULT_SHAPE_POLICY,
    )
    id_range = g.n + 2  # real ids plus the n / n+1 in-row sentinels
    total = 0
    for b in buckets:
        u, v = b.u_lists, b.v_lists
        strat, bits = resolve_strategy(b.width, id_range, strategy=strategy)
        # pad rows with disjoint sentinels so padding contributes 0
        pad = (-u.shape[0]) % ndev
        if pad:
            u = jnp.concatenate(
                [u, jnp.full((pad, u.shape[1]), -1, u.dtype)])
            v = jnp.concatenate(
                [v, jnp.full((pad, v.shape[1]), -2, v.dtype)])
        u = u.reshape(ndev, -1, u.shape[1])
        v = v.reshape(ndev, -1, v.shape[1])
        spec = P(axes)

        @jax.jit
        def count(u, v, strat=strat, bits=bits):
            def local(u, v):
                u, v = u[0], v[0]
                counts = intersect_counts(
                    u, v, strategy=strat, backend="jnp", bitmap_bits=bits
                )
                return jax.lax.psum(counts.sum(), axes)

            return shard_map(local, mesh=mesh, in_specs=(spec, spec),
                             out_specs=P())(u, v)

        total += int(count(jnp.asarray(u), jnp.asarray(v)))
    return total


# ---------------------------------------------------------------------------
# Registry planners + deprecated one-shot shims
# ---------------------------------------------------------------------------

def _planner_matrix(g: Graph, options, *, mesh=None) -> OneShotPlan:
    """Registry planner for the ``"matrix_distributed"`` lane. Each count
    re-shards the host-built schedule (one-shot semantics)."""
    block = choose_block(g) if options.block == "auto" else int(options.block)
    return OneShotPlan(
        fn=lambda: _matrix_distributed(g, mesh, block=block),
        algorithm="matrix_distributed",
        meta=dict(graph=g.name, n=g.n, m=g.m_undirected, block=block),
    )


def _planner_intersection(g: Graph, options, *, mesh=None) -> OneShotPlan:
    """Registry planner for the ``"intersection_distributed"`` lane."""
    return OneShotPlan(
        fn=lambda: _intersection_distributed(
            g, mesh, widths=options.widths, strategy=options.strategy
        ),
        algorithm="intersection_distributed",
        meta=dict(graph=g.name, n=g.n, m=g.m_undirected,
                  widths=tuple(options.widths), strategy=options.strategy),
    )


register_algorithm("matrix_distributed", _planner_matrix)
register_algorithm("intersection_distributed", _planner_intersection)


def triangle_count_matrix_distributed(
    g: Graph,
    mesh: Optional[Mesh] = None,
    *,
    block: int = 128,
) -> int:
    """Deprecated shim: use ``TriangleCounter(g,
    CountOptions(algorithm="matrix_distributed", block=...), mesh=mesh)``.
    Returns the exact count as a Python int (unchanged behavior)."""
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_matrix_distributed(g, mesh, ...)",
        'TriangleCounter(g, CountOptions(algorithm="matrix_distributed", '
        "...), mesh=mesh).count()",
    )
    opts = CountOptions(algorithm="matrix_distributed", block=block)
    return int(TriangleCounter(g, opts, mesh=mesh).count())


def triangle_count_intersection_distributed(
    g: Graph,
    mesh: Optional[Mesh] = None,
    *,
    widths: Sequence[int] = DEFAULT_WIDTHS,
    strategy: str = "auto",
) -> int:
    """Deprecated shim: use ``TriangleCounter(g,
    CountOptions(algorithm="intersection_distributed", ...), mesh=mesh)``.
    Returns the exact count as a Python int (unchanged behavior)."""
    from repro.core.api import TriangleCounter, warn_deprecated
    from repro.core.options import CountOptions

    warn_deprecated(
        "triangle_count_intersection_distributed(g, mesh, ...)",
        'TriangleCounter(g, CountOptions(algorithm="intersection_distributed"'
        ", ...), mesh=mesh).count()",
    )
    opts = CountOptions(algorithm="intersection_distributed",
                        widths=tuple(widths), strategy=strategy)
    return int(TriangleCounter(g, opts, mesh=mesh).count())
