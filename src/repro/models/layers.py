"""Neural-net layer primitives shared by all 10 architecture families.

Pure-JAX (no framework dependency): parameters are nested dicts of arrays;
every layer is an ``init_*``/``apply`` function pair. Models keep no mesh
references — distribution is injected externally through in_shardings on the
jitted step functions (GSPMD propagates from parameter shardings).

Attention is computed with a chunked-KV online-softmax scan (never
materializes the full S×T logit matrix), which is both the memory-sane path
for 32k prefill and the structure a TPU flash kernel tiles; the Pallas
flash_attention kernel in repro.kernels is the drop-in MXU version of the
same math and is validated against ``attention_ref``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.meshctx import constrain

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "rope",
    "attention",
    "decode_attention",
    "init_attention_block",
    "init_mlp",
    "mlp",
    "init_moe",
    "moe",
    "softcap",
]


def _he(key, shape, dtype, fan_in=None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.bfloat16):
    p = {"w": _he(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def quantize_kv(x):
    """Symmetric int8 over the head_dim axis. x: (..., hd) →
    (int8 (..., hd), scale (...,) f32·bf16-safe)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32)
            * scale[..., None].astype(jnp.float32)).astype(dtype)


def mask_padded_vocab(logits, vocab: int):
    """Kill padded-vocab logits (embed tables are padded so the vocab dim
    shards evenly; see ModelConfig.padded_vocab)."""
    if logits.shape[-1] == vocab:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
    return jnp.where(ids < vocab, logits, -1e30)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
#
# ``window`` is a TRACED int32 scalar everywhere (use NO_WINDOW = 2**30 for
# global attention) so heterogeneous local/global layer stacks scan over a
# per-layer window vector with homogeneous code. Padded key slots use
# k_pos = -1, which every mask rejects via k_pos >= 0.

NO_WINDOW = 1 << 30


def _mask(q_pos, k_pos, window, causal: bool, prefix_len: int):
    """(S, C) boolean validity mask from absolute positions."""
    qk = q_pos[:, None] - k_pos[None, :]
    if causal:
        valid = (qk >= 0) & (qk < window)
    else:
        valid = jnp.abs(qk) < window
    if prefix_len:
        valid = valid | (k_pos[None, :] < prefix_len)
    return valid & (k_pos[None, :] >= 0)


def attention(
    q: jnp.ndarray,  # (B, S, Hq, hd)
    k: jnp.ndarray,  # (B, T, Hkv, hd)
    v: jnp.ndarray,  # (B, T, Hkv, hd)
    *,
    q_pos: jnp.ndarray,  # (S,)
    k_pos: jnp.ndarray,  # (T,)
    window=NO_WINDOW,  # traced int32 scalar
    causal: bool = True,
    prefix_len: int = 0,
    cap: Optional[float] = None,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Chunked-KV online-softmax attention (GQA-aware). Returns (B,S,Hq,hd).

    Never materializes the S×T logit matrix: the KV axis is scanned in
    ``chunk``-sized tiles with a running (max, sumexp, out) accumulator —
    the jnp expression of the flash-attention schedule, and the oracle for
    kernels/flash_attention.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    qg = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(hd)
    window = jnp.asarray(window, jnp.int32)

    chunk = min(chunk, t)
    nchunks = -(-t // chunk)
    pad = nchunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    kc = k.reshape(b, nchunks, chunk, hkv, hd)
    vc = v.reshape(b, nchunks, chunk, hkv, hd)
    pc = k_pos.reshape(nchunks, chunk)

    def step(carry, xs):
        m_run, l_run, o_run = carry  # (B,S,Hkv,G), same, (B,S,Hkv,G,hd)
        kci, vci, pci = xs
        logits = jnp.einsum("bshgd,bchd->bshgc", qg, kci.astype(jnp.float32))
        logits = logits * scale
        if cap is not None:
            logits = softcap(logits, cap)
        valid = _mask(q_pos, pci, window, causal, prefix_len)  # (S, C)
        logits = jnp.where(valid[None, :, None, None, :], logits, -1e30)
        m_new = jnp.maximum(m_run, logits.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        o_new = o_run * alpha[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p, vci.astype(jnp.float32)
        )
        return (m_new, l_new, o_new), None

    init = (
        jnp.full((b, s, hkv, g), -1e30, jnp.float32),
        jnp.zeros((b, s, hkv, g), jnp.float32),
        jnp.zeros((b, s, hkv, g, hd), jnp.float32),
    )
    (m_f, l_f, o_f), _ = jax.lax.scan(
        step, init, (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc)
    )
    out = o_f / jnp.maximum(l_f[..., None], 1e-30)
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, hd)
    k_cache: jnp.ndarray,  # (B, T, Hkv, hd)
    v_cache: jnp.ndarray,
    *,
    cur_pos: jnp.ndarray,  # scalar: index of the new token
    window=NO_WINDOW,
    cap: Optional[float] = None,
) -> jnp.ndarray:
    """Single-step attention against the KV cache."""
    b, _, hq, hd = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    window = jnp.asarray(window, jnp.int32)
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache.astype(jnp.float32))
    logits = logits / jnp.sqrt(hd)
    if cap is not None:
        logits = softcap(logits, cap)
    pos = jnp.arange(t)
    valid = (pos <= cur_pos) & (pos > cur_pos - window)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def init_attention_block(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype=dtype),
    }


# ---------------------------------------------------------------- MLP / MoE


def init_mlp(key, d: int, ff: int, *, gated: bool = True, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, ff, dtype=dtype),
         "wo": dense_init(ks[1], ff, d, dtype=dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d, ff, dtype=dtype)
    return p


def mlp(p, x, act: str = "silu"):
    h = dense(p["wi"], x)
    if "wg" in p:
        gate = dense(p["wg"], x)
        h = (jax.nn.silu(gate.astype(jnp.float32)) * h.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], h)


def init_moe(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    e, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": _he(ks[0], (d, e), jnp.float32),
        "wi": _he(ks[1], (e, d, ff), dtype),
        "wg": _he(ks[2], (e, d, ff), dtype),
        "wo": _he(ks[3], (e, ff, d), dtype, fan_in=ff),
    }
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[4], d, cfg.dense_residual_ff, dtype=dtype)
    return p


def moe(p, x, cfg):
    """Grouped capacity-based top-k MoE (Mesh-TF/Switch dispatch). x: (B,S,d).

    Dispatch is GROUPED per sequence: capacity is enforced within each batch
    row, so the dispatch one-hot is (B, S, E, C_g) with C_g = S·k/E·cf — its
    size scales with the *local* sequence, not the global batch. (An
    ungrouped dispatch materialized a (N_global, E, C_global) tensor: 43 GB
    per chip for arctic train_4k — see EXPERIMENTS.md §Perf iteration 0.)
    The batch/group dim is data-sharded and experts are EP-sharded over
    "model", so dispatch/combine einsums lower to all-to-alls under GSPMD.
    Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(s * k / e * cfg.moe_capacity_factor))
    logits = x.astype(jnp.float32) @ p["router"]  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue, per group
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (B, S, k, E)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # rank among same-expert slots
    pos = (pos * flat).sum(-1).reshape(b, s, k)  # (B, S, k)
    keep = pos < cap
    # dispatch/combine (B, S, E, C): contract the k slots without ever
    # materializing the (B,S,k,E,C) outer product
    oh_e = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (B, S, k, E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                          dtype=jnp.float32)[..., :cap]  # (B, S, k, C)
    disp = jnp.einsum("bske,bskc->bsec", oh_e, oh_c).astype(x.dtype)
    comb = jnp.einsum("bske,bskc,bsk->bsec", oh_e, oh_c,
                      gate_vals).astype(x.dtype)

    # dispatch/combine in activation dtype: the combine contraction over the
    # EP-sharded expert dim is the layer's model-axis all-reduce — bf16 here
    # halves arctic's dominant collective term (EXPERIMENTS.md §Perf iter 2)
    ex_in = jnp.einsum("bsec,bsd->becd", disp, x)
    ex_in = constrain(ex_in, "batch", "model", None, None)
    h = jnp.einsum("becd,edf->becf", ex_in, p["wi"])
    gth = jnp.einsum("becd,edf->becf", ex_in, p["wg"])
    h = (jax.nn.silu(gth.astype(jnp.float32)) * h.astype(jnp.float32)
         ).astype(x.dtype)
    ex_out = jnp.einsum("becf,efd->becd", h, p["wo"])
    out = jnp.einsum("bsec,becd->bsd", comb, ex_out)
    if "dense" in p:
        out = out + mlp(p["dense"], x)
    # load-balance aux loss (Switch): e * sum_e f_e * P_e
    density = flat.astype(jnp.float32).mean(axis=(0, 1))
    router_prob = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(density * router_prob)
    return out, aux
