"""Context-scoped activation sharding constraints.

Models are mesh-free, but GSPMD propagation alone can pick pathological
layouts: with FSDP-sharded weights the (d_model over data) parameter sharding
propagates into activations and REPLICATES the batch — observed as 16×
redundant attention compute and 15 GB softmax buffers on arctic-480b
(EXPERIMENTS.md §Perf iteration 0). MaxText solves this with explicit
activation constraints; we do the same behind a context so tests/benches
(no mesh) are unaffected.

Axis aliases: "batch" → all data-carrying mesh axes (("pod","data") on the
multi-pod mesh), "model" → "model". Constraints are divisibility-sanitized,
so batch=1 decode cells silently replicate.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_mesh", "constrain"]

_ACTIVE: Optional[Mesh] = None


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh]):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = mesh
    try:
        yield
    finally:
        _ACTIVE = prev


def _resolve(axis, mesh: Mesh):
    if axis == "batch":
        ax = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        return ax if ax else None
    if axis == "model":
        return "model" if "model" in mesh.axis_names else None
    return axis


def constrain(x, *spec):
    """No-op without an active mesh. spec entries: "batch", "model", None."""
    if _ACTIVE is None:
        return x
    from repro.train.sharding import sanitize_spec

    entries = tuple(_resolve(a, _ACTIVE) for a in spec)
    entries = entries + (None,) * (x.ndim - len(entries))
    s = sanitize_spec(P(*entries), x.shape, _ACTIVE)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_ACTIVE, s))
