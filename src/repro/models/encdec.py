"""Whisper-style encoder-decoder [arXiv:2212.04356].

The audio frontend (log-mel + 2×conv) is a STUB per the assignment brief:
``input_specs`` feeds precomputed frame embeddings (B, T_enc, d_model)
directly into the transformer encoder. Encoder layers are non-causal
self-attention; decoder layers are causal self-attention + cross-attention
into the encoder memory + (non-gated, GELU) MLP. Sinusoidal positions for the
encoder, learned-position-free rope-less decoder would be unfaithful, so the
decoder uses learned positions as in the original.

Serving: ``prefill`` encodes once and precomputes per-layer cross-attention
K/V (the standard whisper serving optimization); ``decode_step`` touches the
encoder memory only through those cached projections.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.meshctx import constrain

__all__ = ["WhisperModel"]

_MAX_DECODE_POS = 65536  # learned decoder position table size


def _sinusoid(t: int, d: int):
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _init_enc_layer(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": L.init_attention_block(ks[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=False,
                              dtype=dtype),
        }

    def _init_dec_layer(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": L.init_attention_block(ks[0], cfg, dtype),
            "ln_x": L.rmsnorm_init(cfg.d_model, dtype),
            "xattn": L.init_attention_block(ks[1], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, gated=False,
                              dtype=dtype),
        }

    def init(self, key, dtype=jnp.bfloat16):
        cfg = self.cfg
        k_emb, k_pos, k_enc, k_dec = jax.random.split(key, 4)
        enc = jax.vmap(lambda k: self._init_enc_layer(k, dtype))(
            jax.random.split(k_enc, cfg.encoder_layers))
        dec = jax.vmap(lambda k: self._init_dec_layer(k, dtype))(
            jax.random.split(k_dec, cfg.num_layers))
        return {
            "embed": (jax.random.normal(
                k_emb, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dtype),
            "dec_pos": (jax.random.normal(k_pos, (_MAX_DECODE_POS, cfg.d_model))
                        * 0.01).astype(dtype),
            "enc_layers": enc,
            "dec_layers": dec,
            "enc_norm": L.rmsnorm_init(cfg.d_model, dtype),
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        }

    # ------------------------------------------------------------ encoder

    def encode(self, params, frames):
        """frames: (B, T_enc, d_model) stub embeddings → memory."""
        cfg = self.cfg
        t = frames.shape[1]
        x = frames + _sinusoid(t, cfg.d_model).astype(frames.dtype)[None]
        pos = jnp.arange(t)

        def body(x, p):
            b, s, d = x.shape
            hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.kv_heads
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            q = L.dense(p["attn"]["wq"], h).reshape(b, s, hq, hd)
            k = L.dense(p["attn"]["wk"], h).reshape(b, s, hkv, hd)
            v = L.dense(p["attn"]["wv"], h).reshape(b, s, hkv, hd)
            att = L.attention(q, k, v, q_pos=pos, k_pos=pos, causal=False)
            x = x + L.dense(p["attn"]["wo"], att.reshape(b, s, hq * hd))
            x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps),
                          "gelu")
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
        return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------ decoder

    def _dec_layer(self, p, x, memory, q_pos, mem_pos, *, self_cache=None,
                   cross_kv=None, cur_pos=None):
        cfg = self.cfg
        b, s, d = x.shape
        hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.kv_heads
        x = constrain(x, "batch", None, None)
        # causal self-attention
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        q = L.dense(p["attn"]["wq"], h).reshape(b, s, hq, hd)
        k = L.dense(p["attn"]["wk"], h).reshape(b, s, hkv, hd)
        v = L.dense(p["attn"]["wv"], h).reshape(b, s, hkv, hd)
        new_self = None
        if self_cache is not None:
            ck, cv = self_cache
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, cur_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, cur_pos, 0, 0))
            att = L.decode_attention(q, ck, cv, cur_pos=cur_pos)
            new_self = (ck, cv)
        else:
            att = L.attention(q, k, v, q_pos=q_pos, k_pos=q_pos)
        x = x + L.dense(p["attn"]["wo"], att.reshape(b, s, hq * hd))
        # cross-attention into encoder memory
        h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
        qx = L.dense(p["xattn"]["wq"], h).reshape(b, s, hq, hd)
        if cross_kv is None:
            tm = memory.shape[1]
            kx = L.dense(p["xattn"]["wk"], memory).reshape(b, tm, hkv, hd)
            vx = L.dense(p["xattn"]["wv"], memory).reshape(b, tm, hkv, hd)
        else:
            kx, vx = cross_kv
        attx = L.attention(qx, kx, vx, q_pos=q_pos, k_pos=mem_pos,
                           causal=False)
        x = x + L.dense(p["xattn"]["wo"], attx.reshape(b, s, hq * hd))
        x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), "gelu")
        return x, new_self, (kx, vx)

    def apply_train(self, params, batch):
        """batch: {frames (B,T,d_model), tokens (B,S)} → (logits, aux)."""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = params["embed"][tokens] + params["dec_pos"][:s][None]
        q_pos = jnp.arange(s)
        mem_pos = jnp.arange(memory.shape[1])

        def body(x, p):
            x, _, _ = self._dec_layer(p, x, memory, q_pos, mem_pos)
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return logits, jnp.float32(0)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        kv = (cfg.num_layers, batch, max_len, cfg.kv_heads, cfg.head_dim)
        xkv = (cfg.num_layers, batch, cfg.encoder_seq, cfg.kv_heads,
               cfg.head_dim)
        return {
            "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch, max_len: int):
        """Encode + teacher-forced decode over the prompt, emitting caches."""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["embed"][tokens] + params["dec_pos"][:s][None]
        q_pos = jnp.arange(s)
        mem_pos = jnp.arange(memory.shape[1])

        def body(x, p):
            bsz, sl, d = x.shape
            hd, hkv = cfg.head_dim, cfg.kv_heads
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            k = L.dense(p["attn"]["wk"], h).reshape(bsz, sl, hkv, hd)
            v = L.dense(p["attn"]["wv"], h).reshape(bsz, sl, hkv, hd)
            x, _, (kx, vx) = self._dec_layer(p, x, memory, q_pos, mem_pos)
            return x, (k, v, kx, vx)

        x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        pad = max_len - s
        cache = {
            "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "xk": xks, "xv": xvs,
            "pos": jnp.asarray(s, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"][tokens] + jax.lax.dynamic_slice(
            params["dec_pos"], (pos, 0), (1, cfg.d_model))[None]
        q_pos = pos[None]
        mem_pos = jnp.arange(cache["xk"].shape[2])

        def body(x, xs):
            p, ck, cv, xk, xv = xs
            x, (nk, nv), _ = self._dec_layer(
                p, x, None, q_pos, mem_pos, self_cache=(ck, cv),
                cross_kv=(xk, xv), cur_pos=pos)
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache["k"], cache["v"], cache["xk"],
             cache["xv"]))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return logits, {**cache, "k": nk, "v": nv, "pos": pos + 1}
