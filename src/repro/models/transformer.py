"""Decoder-only transformer covering the dense, MoE, and VLM families
(gemma2, qwen1.5, minicpm, arctic, dbrx, paligemma).

Layers are scan-stacked (params carry a leading (L, ...) dim) with per-layer
remat, so compiled HLO is O(1) in depth — required for 40–64-layer dry-run
compiles. Heterogeneity across layers (gemma2's local/global alternation) is
expressed as *data* (a per-layer window-size vector fed to the scan), never as
per-layer code, keeping the stack homogeneous.

Three entry points per model: ``apply_train`` (full causal forward, returns
logits + aux), ``prefill`` (forward + KV-cache emission), ``decode_step``
(one token against the cache). PaliGemma reuses this model with a
patch-embedding prefix and prefix-bidirectional masking.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.meshctx import constrain

__all__ = ["TransformerLM"]

_NO_WINDOW = L.NO_WINDOW


def _layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window sizes; _NO_WINDOW = global attention."""
    if cfg.local_global_pattern and cfg.sliding_window:
        w = [cfg.sliding_window if i % 2 == 0 else _NO_WINDOW
             for i in range(cfg.num_layers)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * cfg.num_layers
    else:
        w = [_NO_WINDOW] * cfg.num_layers
    return jnp.asarray(w, dtype=jnp.int32)


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params

    def _init_layer(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": L.init_attention_block(ks[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.family == "moe":
            p["moe"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                  gated=(cfg.act == "silu"), dtype=dtype)
        if cfg.post_norms:
            p["ln1_post"] = L.rmsnorm_init(cfg.d_model, dtype)
            p["ln2_post"] = L.rmsnorm_init(cfg.d_model, dtype)
        return p

    def init(self, key, dtype=jnp.bfloat16):
        cfg = self.cfg
        k_emb, k_layers, k_vis = jax.random.split(key, 3)
        layer_keys = jax.random.split(k_layers, cfg.num_layers)
        stacked = jax.vmap(lambda k: self._init_layer(k, dtype))(layer_keys)
        params = {
            "embed": (jax.random.normal(
                k_emb, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dtype),
            "layers": stacked,
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.family == "vlm":
            params["vision_proj"] = L.dense_init(
                k_vis, cfg.vision_dim, cfg.d_model, dtype=dtype)
        return params

    # ------------------------------------------------------------ helpers

    def _embed(self, params, tokens, patches=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.scale_embedding:
            x = (x.astype(jnp.float32) * jnp.sqrt(cfg.d_model)).astype(x.dtype)
        prefix_len = 0
        if cfg.family == "vlm":
            assert patches is not None
            vis = L.dense(params["vision_proj"], patches.astype(x.dtype))
            x = jnp.concatenate([vis, x], axis=1)
            prefix_len = patches.shape[1]
        return x, prefix_len

    def _unembed(self, params, x):
        cfg = self.cfg
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
        return L.mask_padded_vocab(L.softcap(logits, cfg.final_softcap),
                                   cfg.vocab)

    def _layer_fwd(self, p, x, window, *, q_pos, k_pos, prefix_len,
                   kv_override=None, cache=None, cur_pos=None):
        """One block. Returns (x, aux, (k, v)) — k/v for cache emission."""
        cfg = self.cfg
        b, s, d = x.shape
        hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.kv_heads
        x = constrain(x, "batch", None, None)
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        q = L.dense(p["attn"]["wq"], h).reshape(b, s, hq, hd)
        k = L.dense(p["attn"]["wk"], h).reshape(b, s, hkv, hd)
        v = L.dense(p["attn"]["wv"], h).reshape(b, s, hkv, hd)
        q = L.rope(q, q_pos[None, :], cfg.rope_theta)
        k = L.rope(k, q_pos[None, :], cfg.rope_theta)
        if cache is not None:
            if cfg.kv_cache_dtype == "int8":
                ck, cv, ks, vs = cache
                kq, ks_new = L.quantize_kv(k)
                vq, vs_new = L.quantize_kv(v)
                ck = jax.lax.dynamic_update_slice(ck, kq, (0, cur_pos, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, vq, (0, cur_pos, 0, 0))
                ks = jax.lax.dynamic_update_slice(ks, ks_new, (0, cur_pos, 0))
                vs = jax.lax.dynamic_update_slice(vs, vs_new, (0, cur_pos, 0))
                att = L.decode_attention(
                    q, L.dequantize_kv(ck, ks, k.dtype),
                    L.dequantize_kv(cv, vs, v.dtype), cur_pos=cur_pos,
                    window=window, cap=cfg.logit_softcap)
                newkv = (ck, cv, ks, vs)
            else:
                ck, cv = cache
                ck = jax.lax.dynamic_update_slice(ck, k, (0, cur_pos, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v, (0, cur_pos, 0, 0))
                att = L.decode_attention(q, ck, cv, cur_pos=cur_pos,
                                         window=window, cap=cfg.logit_softcap)
                newkv = (ck, cv)
        else:
            att = L.attention(q, k, v, q_pos=q_pos, k_pos=q_pos,
                              window=window, cap=cfg.logit_softcap,
                              prefix_len=prefix_len)
            newkv = (k, v)
        att = L.dense(p["attn"]["wo"], att.reshape(b, s, hq * hd))
        if cfg.post_norms:
            att = L.rmsnorm(p["ln1_post"], att, cfg.norm_eps)
        x = x + att * cfg.residual_scale
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            f, aux = L.moe(p["moe"], h2, cfg)
        else:
            f, aux = L.mlp(p["mlp"], h2, cfg.act), jnp.float32(0)
        if cfg.post_norms:
            f = L.rmsnorm(p["ln2_post"], f, cfg.norm_eps)
        x = constrain(x + f * cfg.residual_scale, "batch", None, None)
        return x, aux, newkv

    # ----------------------------------------------------------- forwards

    def apply_train(self, params, batch):
        """batch: {tokens (B,S)[, patches (B,P,Dv)]} → (logits, aux)."""
        cfg = self.cfg
        x, prefix_len = self._embed(params, batch["tokens"],
                                    batch.get("patches"))
        s = x.shape[1]
        q_pos = jnp.arange(s)
        windows = _layer_windows(cfg)

        def body(carry, xs):
            x, aux = carry
            p, w = xs
            x, a, _ = self._layer_fwd(p, x, w, q_pos=q_pos, k_pos=q_pos,
                                      prefix_len=prefix_len)
            return (x, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)),
                                   (params["layers"], windows))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x)
        if prefix_len:
            logits = logits[:, prefix_len:]
        return logits, aux

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        shape = (cfg.num_layers, batch, max_len, cfg.kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                    "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                    "pos": jnp.zeros((), jnp.int32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.zeros((), jnp.int32)}

    def decode_step(self, params, cache, tokens):
        """tokens (B, 1); cache from init_cache/prefill. One new token."""
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"][tokens]
        if cfg.scale_embedding:
            x = (x.astype(jnp.float32) * jnp.sqrt(cfg.d_model)).astype(x.dtype)
        q_pos = pos[None]
        windows = _layer_windows(cfg)
        quant = cfg.kv_cache_dtype == "int8"

        def body(x, xs):
            if quant:
                p, w, ck, cv, ks, vs = xs
                x, _, newkv = self._layer_fwd(
                    p, x, w, q_pos=q_pos, k_pos=None, prefix_len=0,
                    cache=(ck, cv, ks, vs), cur_pos=pos)
            else:
                p, w, ck, cv = xs
                x, _, newkv = self._layer_fwd(
                    p, x, w, q_pos=q_pos, k_pos=None, prefix_len=0,
                    cache=(ck, cv), cur_pos=pos)
            return x, newkv

        if quant:
            x, (nk, nv, nks, nvs) = jax.lax.scan(
                body, x, (params["layers"], windows, cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]))
            new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs,
                         "pos": pos + 1}
        else:
            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], windows, cache["k"], cache["v"]))
            new_cache = {"k": nk, "v": nv, "pos": pos + 1}
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x)
        return logits, new_cache

    def prefill(self, params, batch, max_len: int):
        """Full forward over the prompt, emitting the KV cache."""
        cfg = self.cfg
        x, prefix_len = self._embed(params, batch["tokens"],
                                    batch.get("patches"))
        b, s, _ = x.shape
        q_pos = jnp.arange(s)
        windows = _layer_windows(cfg)

        quant = cfg.kv_cache_dtype == "int8"

        def body(x, xs):
            p, w = xs
            x, _, (k, v) = self._layer_fwd(p, x, w, q_pos=q_pos, k_pos=q_pos,
                                           prefix_len=prefix_len)
            if quant:  # per-layer quantization: never stacks an f32 cache
                kq, kscale = L.quantize_kv(k)
                vq, vscale = L.quantize_kv(v)
                return x, (kq, vq, kscale, vscale)
            return x, (k, v)

        x, kvs = jax.lax.scan(body, x, (params["layers"], windows))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x)
        pad = max_len - s
        pad5 = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        if quant:
            kq, vq, kscale, vscale = kvs
            cache = {
                "k": jnp.pad(kq, pad5), "v": jnp.pad(vq, pad5),
                "k_scale": jnp.pad(kscale, pad5[:-1]),
                "v_scale": jnp.pad(vscale, pad5[:-1]),
                "pos": jnp.asarray(s, jnp.int32),
            }
        else:
            ks, vs = kvs
            cache = {
                "k": jnp.pad(ks, pad5), "v": jnp.pad(vs, pad5),
                "pos": jnp.asarray(s, jnp.int32),
            }
        return logits, cache
