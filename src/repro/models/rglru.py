"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention,
repeating pattern (rec, rec, attn) [arXiv:2402.19427].

Structure choices and their rationale:
  * The layer stack is heterogeneous, so a single homogeneous scan is
    impossible. We scan over *groups* of (rec, rec, attn) — group params are
    stacked (G, ...) — and unroll the remainder layers (38 = 12·3 + 2 for the
    9b config) explicitly. HLO stays O(1) in group count.
  * RG-LRU gates are per-channel diagonal (RecurrentGemma uses block-diagonal
    per-head gates; diagonal is the head-count→width limit and keeps the gate
    params O(w) — noted in DESIGN.md as an adaptation).
  * The recurrence h_t = a_t·h_{t-1} + sqrt(1−a_t²)·(i_t⊙x_t) is evaluated
    with `lax.associative_scan` (log-depth — the TPU-friendly form) for
    train/prefill and as a 1-step update for decode.
  * Local-attention KV caches are RING BUFFERS of window size with an
    explicit per-slot position array — decode memory is O(window), which is
    what makes the long_500k cell runnable for this family.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.meshctx import constrain

__all__ = ["GriffinLM", "rglru_scan", "rglru_step"]

_C = 8.0  # RG-LRU recurrence sharpness constant


def rglru_scan(x, r, i, lam):
    """x, r, i: (b, s, w); lam: (w,) recurrence param. Associative scan."""
    log_a = -_C * jax.nn.softplus(lam)[None, None, :] * r  # (b,s,w), a=exp(log_a)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def rglru_step(hprev, x_t, r_t, i_t, lam):
    """One step. hprev/x_t/r_t/i_t: (b, w)."""
    a = jnp.exp(-_C * jax.nn.softplus(lam)[None, :] * r_t)
    return a * hprev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i_t * x_t)


def _causal_conv(x, w, cache=None):
    width = w.shape[0]
    if cache is not None:
        win = jnp.concatenate([cache, x], axis=1)
        return (win * w[None]).sum(axis=1, keepdims=True), win[:, 1:]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1]] * w[i][None, None] for i in range(width))
    return y, pad[:, -(width - 1) :]


class GriffinLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.w = cfg.lru_width or cfg.d_model
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        self.pattern = pat
        self.groups = cfg.num_layers // len(pat)
        self.remainder = tuple(
            pat[i] for i in range(cfg.num_layers - self.groups * len(pat))
        )

    # ------------------------------------------------------------- params

    def _init_rec_block(self, key, dtype):
        cfg, d, w = self.cfg, self.cfg.d_model, self.w
        ks = jax.random.split(key, 6)
        return {
            "ln": L.rmsnorm_init(d, dtype),
            "in_x": L.dense_init(ks[0], d, w, dtype=dtype),
            "in_gate": L.dense_init(ks[1], d, w, dtype=dtype),
            "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1
                       ).astype(dtype),
            "gate_r_w": (jax.random.normal(ks[3], (w,)) * 0.1).astype(jnp.float32),
            "gate_r_b": jnp.zeros((w,), jnp.float32),
            "gate_i_w": (jax.random.normal(ks[4], (w,)) * 0.1).astype(jnp.float32),
            "gate_i_b": jnp.zeros((w,), jnp.float32),
            "lam": jnp.full((w,), 1.0, jnp.float32),
            "out": L.dense_init(ks[5], w, d, dtype=dtype),
            "ln2": L.rmsnorm_init(d, dtype),
        }

    def _init_attn_block(self, key, dtype):
        cfg = self.cfg
        return {
            "ln": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": L.init_attention_block(key, cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        }

    def _init_mlp(self, key, dtype):
        return L.init_mlp(key, self.cfg.d_model, self.cfg.d_ff, dtype=dtype)

    def _init_group(self, key, dtype):
        ks = jax.random.split(key, 2 * len(self.pattern))
        out = {}
        for j, kind in enumerate(self.pattern):
            blk = (self._init_rec_block if kind == "rec" else
                   self._init_attn_block)(ks[2 * j], dtype)
            blk["mlp"] = self._init_mlp(ks[2 * j + 1], dtype)
            out[f"b{j}"] = blk
        return out

    def init(self, key, dtype=jnp.bfloat16):
        cfg = self.cfg
        k_emb, k_g, k_r = jax.random.split(key, 3)
        stacked = jax.vmap(lambda k: self._init_group(k, dtype))(
            jax.random.split(k_g, self.groups))
        params = {
            "embed": (jax.random.normal(
                k_emb, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dtype),
            "groups": stacked,
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        }
        rks = jax.random.split(k_r, max(1, 2 * len(self.remainder)))
        for j, kind in enumerate(self.remainder):
            blk = (self._init_rec_block if kind == "rec" else
                   self._init_attn_block)(rks[2 * j], dtype)
            blk["mlp"] = self._init_mlp(rks[2 * j + 1], dtype)
            params[f"rem{j}"] = blk
        return params

    # ------------------------------------------------------------ blocks

    def _rec_fwd(self, p, x, *, cache=None):
        """cache: (h_state (b,w), conv_state (b,cw-1,w)) or None."""
        cfg = self.cfg
        x = constrain(x, "batch", None, None)
        h_in = L.rmsnorm(p["ln"], x, cfg.norm_eps)
        xb = L.dense(p["in_x"], h_in)
        gb = jax.nn.gelu(L.dense(p["in_gate"], h_in).astype(jnp.float32))
        new_cache = None
        if cache is None:
            xb, _ = _causal_conv(xb, p["conv_w"])
        else:
            h_state, conv_state = cache
            xb, conv_state = _causal_conv(xb, p["conv_w"], conv_state)
        xf = xb.astype(jnp.float32)
        r = jax.nn.sigmoid(xf * p["gate_r_w"] + p["gate_r_b"])
        i = jax.nn.sigmoid(xf * p["gate_i_w"] + p["gate_i_b"])
        if cache is None:
            h = rglru_scan(xf, r, i, p["lam"])
        else:
            h = rglru_step(h_state, xf[:, 0], r[:, 0], i[:, 0], p["lam"])[:, None]
            new_cache = (h[:, 0], conv_state)
        y = (h * gb).astype(x.dtype)
        x = x + L.dense(p["out"], y)
        x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
        return x, new_cache

    def _attn_fwd(self, p, x, q_pos, *, cache=None, cur_pos=None):
        """cache: (k (b,W,kv,hd), v, kpos (W,)) ring buffer, or None."""
        cfg = self.cfg
        b, s, d = x.shape
        hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.kv_heads
        win = cfg.sliding_window or L.NO_WINDOW
        h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
        q = L.dense(p["attn"]["wq"], h).reshape(b, s, hq, hd)
        k = L.dense(p["attn"]["wk"], h).reshape(b, s, hkv, hd)
        v = L.dense(p["attn"]["wv"], h).reshape(b, s, hkv, hd)
        q = L.rope(q, q_pos[None, :], cfg.rope_theta)
        k = L.rope(k, q_pos[None, :], cfg.rope_theta)
        new_cache = None
        if cache is None:
            att = L.attention(q, k, v, q_pos=q_pos, k_pos=q_pos, window=win)
        else:
            ck, cv, kpos = cache
            wslots = ck.shape[1]
            slot = cur_pos % wslots
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
            kpos = jax.lax.dynamic_update_slice(kpos, cur_pos[None], (slot,))
            logits = jnp.einsum(
                "bhgd,bthd->bhgt",
                q.reshape(b, hkv, hq // hkv, hd).astype(jnp.float32),
                ck.astype(jnp.float32)) / jnp.sqrt(hd)
            valid = (kpos >= 0) & (kpos > cur_pos - win) & (kpos <= cur_pos)
            logits = jnp.where(valid[None, None, None, :], logits, -1e30)
            pr = jax.nn.softmax(logits, axis=-1)
            att = jnp.einsum("bhgt,bthd->bhgd", pr, cv.astype(jnp.float32))
            att = att.reshape(b, 1, hq, hd).astype(x.dtype)
            new_cache = (ck, cv, kpos)
        att = L.dense(p["attn"]["wo"], att.reshape(b, s, hq * hd))
        x = x + att
        x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
        return x, new_cache

    # ----------------------------------------------------------- forwards

    def apply_train(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        if cfg.scale_embedding:
            x = (x.astype(jnp.float32) * jnp.sqrt(cfg.d_model)).astype(x.dtype)
        s = x.shape[1]
        q_pos = jnp.arange(s)

        def group_fwd(x, gp):
            for j, kind in enumerate(self.pattern):
                if kind == "rec":
                    x, _ = self._rec_fwd(gp[f"b{j}"], x)
                else:
                    x, _ = self._attn_fwd(gp[f"b{j}"], x, q_pos)
            return x, None

        body = jax.checkpoint(group_fwd) if cfg.remat else group_fwd
        x, _ = jax.lax.scan(body, x, params["groups"])
        for j, kind in enumerate(self.remainder):
            fn = self._rec_fwd if kind == "rec" else (
                lambda p, x: self._attn_fwd(p, x, q_pos))
            x, _ = fn(params[f"rem{j}"], x)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return L.softcap(logits, cfg.final_softcap), jnp.float32(0)

    # decode: flat per-layer caches (python-level layer list — G groups are
    # unrolled here; decode HLO is small because S=1)

    def _layer_list(self, params):
        out = []
        for gi in range(self.groups):
            gp = jax.tree.map(lambda a: a[gi], params["groups"])
            for j, kind in enumerate(self.pattern):
                out.append((kind, gp[f"b{j}"]))
        for j, kind in enumerate(self.remainder):
            out.append((kind, params[f"rem{j}"]))
        return out

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        win = min(cfg.sliding_window or max_len, max_len)
        caches = []
        for gi in range(self.groups):
            for kind in self.pattern:
                caches.append(self._empty_block_cache(kind, batch, win, dtype))
        for kind in self.remainder:
            caches.append(self._empty_block_cache(kind, batch, win, dtype))
        return {"blocks": caches, "pos": jnp.zeros((), jnp.int32)}

    def _empty_block_cache(self, kind, batch, win, dtype):
        cfg = self.cfg
        if kind == "rec":
            return (
                jnp.zeros((batch, self.w), jnp.float32),
                jnp.zeros((batch, cfg.conv_width - 1, self.w), dtype),
            )
        return (
            jnp.zeros((batch, win, cfg.kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, win, cfg.kv_heads, cfg.head_dim), dtype),
            jnp.full((win,), -1, jnp.int32),
        )

    def prefill(self, params, batch, max_len: int):
        """Forward over the prompt, emitting decode caches: final RG-LRU
        states + conv tails for recurrent blocks, ring-buffer KV of the last
        `window` positions for local-attention blocks."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["embed"][tokens]
        if cfg.scale_embedding:
            x = (x.astype(jnp.float32) * jnp.sqrt(cfg.d_model)).astype(x.dtype)
        q_pos = jnp.arange(s)
        win = min(cfg.sliding_window or max_len, max_len)
        blocks = []
        for kind, p in self._layer_list(params):
            if kind == "rec":
                # rerun the block capturing (h_last, conv_tail)
                h_in = L.rmsnorm(p["ln"], x, cfg.norm_eps)
                xb = L.dense(p["in_x"], h_in)
                gb = jax.nn.gelu(
                    L.dense(p["in_gate"], h_in).astype(jnp.float32))
                xb, conv_tail = _causal_conv(xb, p["conv_w"])
                xf = xb.astype(jnp.float32)
                r = jax.nn.sigmoid(xf * p["gate_r_w"] + p["gate_r_b"])
                i = jax.nn.sigmoid(xf * p["gate_i_w"] + p["gate_i_b"])
                h = rglru_scan(xf, r, i, p["lam"])
                y = (h * gb).astype(x.dtype)
                x = x + L.dense(p["out"], y)
                x = x + L.mlp(p["mlp"],
                              L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
                blocks.append((h[:, -1], conv_tail.astype(x.dtype)))
            else:
                hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.kv_heads
                h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
                q = L.dense(p["attn"]["wq"], h).reshape(b, s, hq, hd)
                k = L.dense(p["attn"]["wk"], h).reshape(b, s, hkv, hd)
                v = L.dense(p["attn"]["wv"], h).reshape(b, s, hkv, hd)
                q = L.rope(q, q_pos[None, :], cfg.rope_theta)
                k = L.rope(k, q_pos[None, :], cfg.rope_theta)
                att = L.attention(q, k, v, q_pos=q_pos, k_pos=q_pos,
                                  window=cfg.sliding_window or L.NO_WINDOW)
                x = x + L.dense(p["attn"]["wo"], att.reshape(b, s, hq * hd))
                x = x + L.mlp(p["mlp"],
                              L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
                # ring-buffer layout for the last `win` positions
                ps = jnp.arange(max(s - win, 0), s)
                ck = jnp.zeros((b, win, hkv, hd), x.dtype)
                cv = jnp.zeros((b, win, hkv, hd), x.dtype)
                kpos = jnp.full((win,), -1, jnp.int32)
                ck = ck.at[:, ps % win].set(k[:, ps])
                cv = cv.at[:, ps % win].set(v[:, ps])
                kpos = kpos.at[ps % win].set(ps.astype(jnp.int32))
                blocks.append((ck, cv, kpos))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return (L.softcap(logits, cfg.final_softcap),
                {"blocks": blocks, "pos": jnp.asarray(s, jnp.int32)})

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"][tokens]
        if cfg.scale_embedding:
            x = (x.astype(jnp.float32) * jnp.sqrt(cfg.d_model)).astype(x.dtype)
        q_pos = pos[None]
        new_blocks = []
        for (kind, p), c in zip(self._layer_list(params), cache["blocks"]):
            if kind == "rec":
                x, nc = self._rec_fwd(p, x, cache=c)
            else:
                x, nc = self._attn_fwd(p, x, q_pos, cache=c, cur_pos=pos)
            new_blocks.append(nc)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return (L.softcap(logits, cfg.final_softcap),
                {"blocks": new_blocks, "pos": pos + 1})
