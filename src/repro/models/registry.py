"""Architecture registry: ``--arch <id>`` resolution for configs and models."""

from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

__all__ = ["get_config", "get_reduced_config", "get_model", "list_archs", "ARCHS"]

ARCHS = [
    "gemma2-2b",
    "qwen1.5-4b",
    "qwen1.5-32b",
    "minicpm-2b",
    "mamba2-780m",
    "arctic-480b",
    "dbrx-132b",
    "whisper-medium",
    "paligemma-3b",
    "recurrentgemma-9b",
]


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def list_archs() -> List[str]:
    return list(ARCHS)


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import TransformerLM

        return TransformerLM(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm import MambaLM

        return MambaLM(cfg)
    if cfg.family == "hybrid":
        from repro.models.rglru import GriffinLM

        return GriffinLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import WhisperModel

        return WhisperModel(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
