"""Model configuration shared by every architecture family.

One dataclass covers the 10 assigned architectures; family-specific knobs are
optional fields. Exact values live in ``repro/configs/<id>.py``; smoke tests
use ``reduced()`` scaled-down clones of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # dense-transformer options
    qkv_bias: bool = False  # qwen1.5
    logit_softcap: Optional[float] = None  # gemma2 (50.0 attn, 30.0 final)
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # local-attention window
    local_global_pattern: bool = False  # gemma2: alternate local/global layers
    tie_embeddings: bool = True
    post_norms: bool = False  # gemma2 sandwich norms
    scale_embedding: bool = False  # gemma: embed × sqrt(d_model)
    residual_scale: float = 1.0  # minicpm depth-scaled residuals
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_residual_ff: int = 0

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    conv_width: int = 4
    expand: int = 2

    # hybrid (recurrentgemma): layer pattern unit, e.g. ("rec","rec","attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: Optional[int] = None

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # 30 s of audio at 50 Hz after conv stub

    # VLM (paligemma)
    vision_tokens: int = 0  # prefix length of stub patch embeddings
    vision_dim: int = 0  # SigLIP output dim fed through projector stub

    # serving: KV cache dtype ("bfloat16" | "int8" — int8 stores a per
    # (layer, batch, pos, head) bf16 scale; ~2x cache HBM reduction)
    kv_cache_dtype: str = "bfloat16"

    # vocab padding: embedding rows padded so the vocab dim shards evenly;
    # padded logits are masked to -inf before loss/softmax (MaxText-style)
    pad_vocab_multiple: int = 256

    # training-time policy knobs (overridable per run)
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False  # shard params/opt over data axis too (ZeRO-3-ish)
    adam_dtype: str = "bfloat16"  # moment dtype; "float32" for small models
    grad_accum_dtype: str = "float32"  # bf16 halves the per-microbatch FSDP
    # gradient all-reduce + accumulator HBM (arctic: 3.0 TB/chip/step -> 1.5)
    microbatches: int = 1

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        m = max(self.pad_vocab_multiple, 1)
        return ((self.vocab + m - 1) // m) * m

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if serving 500k context is sub-quadratic (SSM / hybrid with
        local-window attention only)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----

    def param_count(self) -> int:
        """Total parameter count (embedding included once when tied)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        emb = self.vocab * d if self.tie_embeddings else 2 * self.vocab * d
        per_layer = 0
        if self.family == "ssm":
            d_in = self.expand * d
            per_layer = (
                d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads)  # in_proj
                + self.conv_width * (d_in + 2 * self.ssm_state)
                + self.ssm_heads  # A_log
                + self.ssm_heads  # D
                + d_in * d  # out_proj
                + 2 * d  # norms
            )
            return emb + L * per_layer + d
        attn = d * (self.num_heads * hd) + 2 * d * (self.kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        if self.family == "moe":
            ffp = self.num_experts * 3 * d * ff
            if self.dense_residual:
                ffp += 3 * d * self.dense_residual_ff
            ffp += d * self.num_experts  # router
        else:
            nm = 3 if self.act == "silu" else 2
            ffp = nm * d * ff
        per_layer = attn + ffp + 2 * d
        total = emb + L * per_layer + d
        if self.family == "hybrid":
            # recurrent blocks replace attention with RG-LRU temporal mix
            pat = self.block_pattern or ("rec", "rec", "attn")
            frac_rec = pat.count("rec") / len(pat)
            w = self.lru_width or d
            rec = 2 * d * w + 2 * w * self.conv_width + 4 * w + w * d
            total += int(L * frac_rec * (rec - attn))
        if self.family == "encdec":
            enc_layer = attn + 2 * d * ff + 2 * d
            total += self.encoder_layers * enc_layer + L * attn  # + cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        emb = self.vocab * d
        attn = d * (self.num_heads * hd) + 2 * d * (self.kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        ffp = self.top_k * 3 * d * ff + d * self.num_experts
        if self.dense_residual:
            ffp += 3 * d * self.dense_residual_ff
        return int(emb + L * (attn + ffp + 2 * d) + d)
