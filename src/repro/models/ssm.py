"""Mamba-2 (SSD — state-space duality) language model [arXiv:2405.21060].

Chunked SSD forward: within chunks of length Q the dual quadratic form runs
(MXU-friendly batched matmuls); across chunks a sequential `lax.scan` passes
the (H, P, N) state. Decode is the pure SSM recurrence — O(1) per token, which
is what makes the ``long_500k`` cell runnable where attention archs are
skipped.

Structure per block (simplified from the reference: ngroups=1, no bias):
  u → in_proj → [z, x, B, C, dt]
  conv1d(width 4) + silu over [x, B, C]
  y = SSD(x·dt, exp(dt·A), B, C) + D·x
  out = out_proj(rmsnorm(y · silu(z)))
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.meshctx import constrain

__all__ = ["MambaLM", "ssd_chunked", "ssd_decode_step"]


def _segsum(a):
    """a: (..., Q) log-decays → (..., Q, Q) lower-tri cumulative sums:
    out[i, j] = sum_{j < t <= i} a[t]  (i >= j), -inf above diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii, jj = jnp.meshgrid(jnp.arange(q), jnp.arange(q), indexing="ij")
    return jnp.where(ii >= jj, diff, -jnp.inf)


def ssd_chunked(x, a, Bm, Cm, chunk: int):
    """SSD scan. x: (b,s,h,p) pre-multiplied by dt; a: (b,s,h) log decay;
    Bm, Cm: (b,s,n). Returns y: (b,s,h,p), final_state: (b,h,p,n)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # zero-pad tail: a=0, x=0 ⇒ pads never influence real outputs
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    c = s_pad // q
    xr = x.reshape(b, c, q, h, p)
    ar = a.reshape(b, c, q, h)
    Br = Bm.reshape(b, c, q, n)
    Cr = Cm.reshape(b, c, q, n)

    a_cs = jnp.cumsum(ar, axis=2)  # (b,c,q,h)
    # intra-chunk (dual quadratic form)
    Lmat = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))  # (b,c,h,q,q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)  # (b,c,q,q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, Lmat, xr)
    # per-chunk end states
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)  # (b,c,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Br, decay_states, xr)
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])  # (b,c,h)

    def step(hprev, xs):
        st, dec = xs  # (b,h,p,n), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), x.dtype)
    hT, h_prevs = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_prevs = h_prevs.swapaxes(0, 1)  # (b,c,h,p,n): state entering chunk c
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr, h_prevs, jnp.exp(a_cs))
    y = (y_diag + y_off).reshape(b, s_pad, h, p)[:, :s]
    return y, hT


def ssd_decode_step(state, x_t, a_t, B_t, C_t):
    """One recurrence step. state: (b,h,p,n); x_t: (b,h,p) (pre-×dt);
    a_t: (b,h) log decay; B_t, C_t: (b,n)."""
    dec = jnp.exp(a_t)[..., None, None]
    state = state * dec + jnp.einsum("bhp,bn->bhpn", x_t, B_t)
    y = jnp.einsum("bhpn,bn->bhp", state, C_t)
    return state, y


def _causal_conv(x, w, cache=None):
    """Per-channel causal conv. x: (b,s,ch); w: (width, ch).
    With cache (b, width-1, ch): single-step path (s==1)."""
    width = w.shape[0]
    if cache is not None:
        window = jnp.concatenate([cache, x], axis=1)  # (b, width, ch)
        y = (window * w[None]).sum(axis=1, keepdims=True)
        return y, window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1]] * w[i][None, None] for i in range(width))
    return y, pad[:, -(width - 1) :] if width > 1 else None


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        d = cfg.d_model
        self.d_in = cfg.expand * d
        self.h = cfg.ssm_heads or (self.d_in // (cfg.ssm_head_dim or 64))
        self.p = self.d_in // self.h
        self.n = cfg.ssm_state
        self.conv_dim = self.d_in + 2 * self.n

    def _init_layer(self, key, dtype):
        cfg, d = self.cfg, self.cfg.d_model
        ks = jax.random.split(key, 4)
        return {
            "ln": L.rmsnorm_init(d, dtype),
            "in_proj": L.dense_init(
                ks[0], d, 2 * self.d_in + 2 * self.n + self.h, dtype=dtype),
            "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, self.conv_dim))
                       * 0.1).astype(dtype),
            "A_log": jnp.zeros((self.h,), jnp.float32),
            "D": jnp.ones((self.h,), jnp.float32),
            "dt_bias": jnp.zeros((self.h,), jnp.float32),
            "gate_ln": L.rmsnorm_init(self.d_in, dtype),
            "out_proj": L.dense_init(ks[2], self.d_in, d, dtype=dtype),
        }

    def init(self, key, dtype=jnp.bfloat16):
        cfg = self.cfg
        k_emb, k_layers = jax.random.split(key)
        stacked = jax.vmap(lambda k: self._init_layer(k, dtype))(
            jax.random.split(k_layers, cfg.num_layers))
        return {
            "embed": (jax.random.normal(
                k_emb, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dtype),
            "layers": stacked,
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        }

    def _split_proj(self, zxbcdt):
        di, n, h = self.d_in, self.n, self.h
        z = zxbcdt[..., :di]
        xbc = zxbcdt[..., di : di + di + 2 * n]
        dt = zxbcdt[..., di + di + 2 * n :]
        return z, xbc, dt

    def _layer_fwd(self, p, x, *, cache=None):
        """cache: (ssm_state (b,h,p,n), conv_state (b,w-1,conv_dim)) or None."""
        cfg = self.cfg
        b, s, d = x.shape
        x = constrain(x, "batch", None, None)
        h_in = L.rmsnorm(p["ln"], x, cfg.norm_eps)
        z, xbc, dt = self._split_proj(L.dense(p["in_proj"], h_in))
        new_cache = None
        if cache is None:
            xbc, _ = _causal_conv(xbc, p["conv_w"])
        else:
            ssm_state, conv_state = cache
            xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_state)
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
        xc = xbc[..., : self.d_in].reshape(b, s, self.h, self.p)
        Bm = xbc[..., self.d_in : self.d_in + self.n]
        Cm = xbc[..., self.d_in + self.n :]
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,s,h)
        A = -jnp.exp(p["A_log"])  # (h,)
        xdt = (xc.astype(jnp.float32) * dt[..., None])
        a = dt * A  # (b,s,h) log decay
        if cache is None:
            y, _ = ssd_chunked(xdt, a, Bm.astype(jnp.float32),
                               Cm.astype(jnp.float32), cfg.ssm_chunk)
        else:
            ssm_state, y = ssd_decode_step(
                ssm_state, xdt[:, 0], a[:, 0], Bm[:, 0].astype(jnp.float32),
                Cm[:, 0].astype(jnp.float32))
            y = y[:, None]
            new_cache = (ssm_state, conv_state)
        y = y + xc.astype(jnp.float32) * p["D"][None, None, :, None]
        y = y.reshape(b, s, self.d_in)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = L.rmsnorm(p["gate_ln"], y.astype(x.dtype), cfg.norm_eps)
        out = L.dense(p["out_proj"], y)
        return x + out, new_cache

    def apply_train(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]

        def body(x, p):
            x, _ = self._layer_fwd(p, x)
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return logits, jnp.float32(0)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        lcount = cfg.num_layers
        return {
            "ssm": jnp.zeros((lcount, batch, self.h, self.p, self.n), jnp.float32),
            "conv": jnp.zeros((lcount, batch, cfg.conv_width - 1, self.conv_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens]

        def body(x, xs):
            p, st, cv = xs
            x, (nst, ncv) = self._layer_fwd(p, x, cache=(st, cv))
            return x, (nst, ncv.astype(cv.dtype))

        x, (nst, ncv) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv"]))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        return logits, {"ssm": nst, "conv": ncv, "pos": cache["pos"] + 1}

    def prefill(self, params, batch, max_len: int):
        """Chunked-SSD forward that also returns the final recurrent state."""
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        b, s = x.shape[:2]

        states, convs = [], []

        def body(x, p):
            # recompute-free prefill: run layer, capture final state
            bsz, sl, d = x.shape
            h_in = L.rmsnorm(p["ln"], x, cfg.norm_eps)
            z, xbc, dt = self._split_proj(L.dense(p["in_proj"], h_in))
            xbc_c, conv_tail = _causal_conv(xbc, p["conv_w"])
            xbc_a = jax.nn.silu(xbc_c.astype(jnp.float32)).astype(x.dtype)
            xc = xbc_a[..., : self.d_in].reshape(bsz, sl, self.h, self.p)
            Bm = xbc_a[..., self.d_in : self.d_in + self.n].astype(jnp.float32)
            Cm = xbc_a[..., self.d_in + self.n :].astype(jnp.float32)
            dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
            A = -jnp.exp(p["A_log"])
            y, hT = ssd_chunked(xc.astype(jnp.float32) * dtf[..., None],
                                dtf * A, Bm, Cm, cfg.ssm_chunk)
            y = y + xc.astype(jnp.float32) * p["D"][None, None, :, None]
            y = y.reshape(bsz, sl, self.d_in) * jax.nn.silu(z.astype(jnp.float32))
            y = L.rmsnorm(p["gate_ln"], y.astype(x.dtype), cfg.norm_eps)
            return x + L.dense(p["out_proj"], y), (hT, conv_tail)

        x, (hTs, conv_tails) = jax.lax.scan(body, x, params["layers"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.mask_padded_vocab(
            x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32),
            cfg.vocab)
        cache = {"ssm": hTs, "conv": conv_tails,
                 "pos": jnp.asarray(s, jnp.int32)}
        return logits, cache
