"""Dataset registry mirroring the paper's Table 1 at CPU-tractable scale.

The paper benchmarks six graphs (coAuthorsCiteseer, coPapersDBLP,
road_central, soc-LJ, cit-Patents, com-Orkut) spanning scale-free ('rs') and
mesh-like ('rm') topologies. Offline we register synthetic analogues with the
same topology class and (scaled-down) degree skew, so every benchmark keyed to
a Table-1 row has a concrete runnable graph here. Scale factors chosen for a
single-core CPU budget; the generators accept larger scales unchanged.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.graphs.formats import Graph
from repro.graphs import generators as gen

# name -> (factory, topology_class, paper_analogue)
DATASETS: Dict[str, dict] = {
    "coauthors-like": dict(
        factory=lambda: gen.rmat_graph(13, edge_factor=7, seed=1, name="coauthors-like"),
        type="rs",
        analogue="coAuthorsCiteseer (227K v, 1.6M e, scale-free)",
    ),
    "copapers-like": dict(
        factory=lambda: gen.rmat_graph(14, edge_factor=28, seed=2, name="copapers-like"),
        type="rs",
        analogue="coPapersDBLP (540K v, 30M e, scale-free, dense communities)",
    ),
    "road-like": dict(
        factory=lambda: gen.grid_graph(160, diagonals=True, spur_fraction=0.35,
                                       seed=3, name="road-like"),
        type="rm",
        analogue="road_central (14M v, 34M e, mesh-like, max degree 8)",
    ),
    "soclj-like": dict(
        factory=lambda: gen.rmat_graph(15, edge_factor=14, seed=4, name="soclj-like"),
        type="rs",
        analogue="soc-LiveJournal (4.8M v, 138M e, scale-free, max degree 20K)",
    ),
    "citpatents-like": dict(
        factory=lambda: gen.rmat_graph(14, edge_factor=4, a=0.45, b=0.22, c=0.22,
                                       seed=5, name="citpatents-like"),
        type="rs",
        analogue="cit-Patents (3.8M v, 33M e, low clustering)",
    ),
    "orkut-like": dict(
        factory=lambda: gen.rmat_graph(14, edge_factor=38, seed=6, name="orkut-like"),
        type="rs",
        analogue="com-Orkut (3.1M v, 234M e, scale-free, max degree 33K)",
    ),
    # small smoke-scale entries used by fast tests
    "tiny-rmat": dict(
        factory=lambda: gen.rmat_graph(8, edge_factor=8, seed=7, name="tiny-rmat"),
        type="rs",
        analogue="(test fixture)",
    ),
    "tiny-grid": dict(
        factory=lambda: gen.grid_graph(16, seed=8, name="tiny-grid"),
        type="rm",
        analogue="(test fixture)",
    ),
}


def available_datasets() -> list:
    """Sorted names of every registered dataset (the Table-1 analogues plus
    the tiny test fixtures)."""
    return sorted(DATASETS)


def load_dataset(name: str) -> Graph:
    """Build the registered dataset ``name``.

    Raises:
      ValueError: unknown name — the message lists every available dataset
        (a bare ``KeyError`` on a typo helped nobody).
    """
    try:
        spec = DATASETS[name]
    except KeyError:
        raise ValueError(
            f"unknown dataset {name!r}; available: "
            f"{', '.join(available_datasets())}"
        ) from None
    return spec["factory"]()
