from repro.graphs.formats import (
    Graph,
    BlockSparse,
    edges_to_csr,
    csr_to_padded_neighbors,
    degree_order_permutation,
    orient_forward,
    to_block_sparse,
    induced_subgraph,
)
from repro.graphs.device import (
    DEFAULT_SHAPE_POLICY,
    DeviceCSR,
    DeviceGraph,
    ShapePolicy,
)
from repro.graphs.generators import (
    rmat_graph,
    grid_graph,
    erdos_renyi_graph,
    watts_strogatz_graph,
    complete_graph,
    star_graph,
    path_graph,
)
from repro.graphs.datasets import DATASETS, available_datasets, load_dataset

__all__ = [
    "Graph",
    "BlockSparse",
    "DeviceCSR",
    "DeviceGraph",
    "ShapePolicy",
    "DEFAULT_SHAPE_POLICY",
    "edges_to_csr",
    "csr_to_padded_neighbors",
    "degree_order_permutation",
    "orient_forward",
    "to_block_sparse",
    "induced_subgraph",
    "rmat_graph",
    "grid_graph",
    "erdos_renyi_graph",
    "watts_strogatz_graph",
    "complete_graph",
    "star_graph",
    "path_graph",
    "DATASETS",
    "available_datasets",
    "load_dataset",
]
