"""Synthetic graph generators spanning the paper's dataset topology range.

Table 1 spans real-world scale-free (coAuthors/coPapers/soc-LJ/cit-Patents/
com-Orkut) and mesh-like (road_central) topologies. Offline we mirror both
families: RMAT (scale-free, Graph500 parameters), 2D grid + diagonals
(road-like meshes with leaf spurs), Erdős–Rényi and Watts–Strogatz controls,
plus closed-form fixtures (K_n, stars, paths) whose triangle counts are known.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.formats import Graph, edges_to_csr

__all__ = [
    "rmat_graph",
    "grid_graph",
    "erdos_renyi_graph",
    "watts_strogatz_graph",
    "complete_graph",
    "star_graph",
    "path_graph",
]


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    name: str | None = None,
) -> Graph:
    """R-MAT scale-free generator (Graph500 parameters by default)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for lvl in range(scale):
        r = rng.random(m)
        right = r >= ab  # falls into c or d quadrant -> src bit set
        lower = (r >= a) & (r < ab) | (r >= abc)  # b or d quadrant -> dst bit
        src |= right.astype(np.int64) << lvl
        dst |= lower.astype(np.int64) << lvl
    return edges_to_csr(src, dst, n=n, name=name or f"rmat{scale}")


def grid_graph(side: int, diagonals: bool = True, spur_fraction: float = 0.2,
               seed: int = 0, name: str | None = None) -> Graph:
    """Road-network-like mesh: side×side 4-connected grid, optional diagonals
    (which create triangles), plus degree-1 leaf spurs (the mesh-like property
    the paper's SM filtering exploits)."""
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).astype(np.int64)
    edges = []
    edges.append((vid[:, :-1].ravel(), vid[:, 1:].ravel()))  # right
    edges.append((vid[:-1, :].ravel(), vid[1:, :].ravel()))  # down
    if diagonals:
        edges.append((vid[:-1, :-1].ravel(), vid[1:, 1:].ravel()))  # diag
    src = np.concatenate([e[0] for e in edges])
    dst = np.concatenate([e[1] for e in edges])
    n_total = n
    if spur_fraction > 0:
        rng = np.random.default_rng(seed)
        k = int(n * spur_fraction)
        anchors = rng.integers(0, n, size=k)
        leaves = n + np.arange(k)
        src = np.concatenate([src, anchors])
        dst = np.concatenate([dst, leaves])
        n_total = n + k
    return edges_to_csr(src, dst, n=n_total, name=name or f"grid{side}")


def erdos_renyi_graph(n: int, avg_degree: float = 8.0, seed: int = 0,
                      name: str | None = None) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return edges_to_csr(src, dst, n=n, name=name or f"er{n}")


def watts_strogatz_graph(n: int, k: int = 6, p: float = 0.1, seed: int = 0,
                         name: str | None = None) -> Graph:
    """Small-world ring lattice with rewiring — high clustering coefficient,
    the regime where triangle counting is used for small-world detection."""
    rng = np.random.default_rng(seed)
    base = np.arange(n, dtype=np.int64)
    src_list, dst_list = [], []
    for off in range(1, k // 2 + 1):
        src_list.append(base)
        dst_list.append((base + off) % n)
    src = np.concatenate(src_list)
    dst = np.concatenate(dst_list)
    rewire = rng.random(src.shape[0]) < p
    dst = np.where(rewire, rng.integers(0, n, size=src.shape[0]), dst)
    return edges_to_csr(src, dst, n=n, name=name or f"ws{n}")


def complete_graph(n: int) -> Graph:
    ii, jj = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    keep = ii < jj
    return edges_to_csr(ii[keep], jj[keep], n=n, name=f"K{n}")


def star_graph(n: int) -> Graph:
    """Hub + (n-1) leaves: zero triangles, maximally skewed degrees."""
    return edges_to_csr(np.zeros(n - 1, dtype=np.int64),
                        np.arange(1, n, dtype=np.int64), n=n, name=f"star{n}")


def path_graph(n: int) -> Graph:
    return edges_to_csr(np.arange(n - 1, dtype=np.int64),
                        np.arange(1, n, dtype=np.int64), n=n, name=f"path{n}")
