"""Device-resident graph containers and jitted prep primitives.

Everything the triangle-counting *prep* stage used to do in per-graph host
numpy — CSR construction, degree-rank forward orientation, padded neighbor
gathers, degree-class bucket layout — reformulated as statically-shaped JAX
computations so batch workloads are kernel-bound, not prep-bound (the
TRUST-style decoupling of GPU-resident preprocessing from counting, and the
Wang & Owens formulation of orientation/filtering as device primitives).

Static shapes are the whole game: every jitted stage here is keyed on shapes
only, so the retrace/recompile cost is paid once per *shape class*, not once
per graph. ``ShapePolicy`` defines the shape classes — it rounds every
data-dependent extent (edge-array lengths, per-bucket edge counts) up to the
next power of two, padding with the repo-wide whole-row sentinels (``-1`` for
u rows, ``-2`` for v rows, which every intersection core treats as zero
matches). Two graphs prepped under the same policy whose rounded extents
collide share every traced prep stage AND every counting executable — which
is what lets ``GraphBatch`` (see ``repro.core.engine``) stack a whole batch
of generated graphs into one vmapped device dispatch.

Containers:

* ``DeviceCSR``   — the raw device-resident CSR arrays (``row_ptr``,
                    ``col_idx`` padded to a policy-rounded length), plus a
                    jitted sort-based builder ``from_edges``.
* ``DeviceGraph`` — a ``DeviceCSR`` + ``ShapePolicy`` with cached derived
                    structure: the forward-oriented edge set, padded
                    neighbor matrices, and the bucket sort the prep lanes
                    in ``repro.core.prep`` consume.

Sentinel conventions (repo-wide, see ``repro.kernels.intersect.ops``): in-row
padding uses ``n`` (u side) / ``n + 1`` (v side); whole padding rows use
``-1`` / ``-2``; padded ``col_idx`` slots use ``n``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64 as _enable_x64

from repro.graphs.formats import Graph

__all__ = [
    "DEFAULT_SHAPE_POLICY",
    "EDGE_KEY_SENTINEL",
    "WIDE_EDGE_KEY_SENTINEL",
    "DeviceCSR",
    "DeviceGraph",
    "GraphTooLargeError",
    "ShapePolicy",
    "ShardedBucket",
    "ShardedDeviceCSR",
    "bfs_levels",
    "deal_across_shards",
    "dynamic_update_step",
    "edge_key_context",
    "edge_key_dtype",
    "edge_key_sentinel",
    "fits_int32_pair_keys",
    "next_pow2",
    "resolve_edge_key_mode",
    "shard_valid_counts",
]

# Dead slots in a sorted packed-edge-key array (the dynamic lane's edge-set
# container) carry this value, so they sort past every real lo*(n+1)+hi key
# (real keys are < (n+1)^2 <= int32 max by fits_int32_pair_keys). The wide
# (int64) key mode uses WIDE_EDGE_KEY_SENTINEL the same way; prefer
# ``edge_key_sentinel(mode)`` over the raw constants.
EDGE_KEY_SENTINEL: int = int(np.iinfo(np.int32).max)
WIDE_EDGE_KEY_SENTINEL: int = int(np.iinfo(np.int64).max)

#: Valid values for every ``key_mode`` parameter in the repo.
EDGE_KEY_MODES: Tuple[str, ...] = ("auto", "int32", "wide")


class GraphTooLargeError(ValueError):
    """The graph exceeds a lane's packed-edge-key capacity.

    Raised from the single checkpoint :func:`resolve_edge_key_mode` when a
    graph cannot be represented in the requested key mode: either
    ``key_mode="int32"`` was forced past ``fits_int32_pair_keys`` (n ≲ 46k),
    or n is so large that even int64 keys would overflow (n ≳ 3e9). The
    message names the lanes/modes that *do* support the graph."""


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ ``x`` (and ≥ 1)."""
    x = int(x)
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def fits_int32_pair_keys(n: int) -> bool:
    """Whether ``(n + 1)²`` fits the int32 range — the bound behind the fast
    path of every packed ``a * (n + 1) + b`` vertex-pair key in the repo
    (``DeviceCSR.from_edges`` sort keys, the edge lane's undirected-edge
    keys). x64 is off by default, so int32 keys are the fast path; past
    n ≲ 46k the key layer promotes to the wide (x64 int64) mode — see
    :func:`resolve_edge_key_mode`."""
    return (n + 1) ** 2 <= np.iinfo(np.int32).max


def fits_int64_pair_keys(n: int) -> bool:
    """Whether ``(n + 1)²`` fits the int64 range (n ≲ 3e9) — the hard bound
    of the wide key mode, i.e. the only n bound the hardware imposes."""
    return (n + 1) ** 2 <= np.iinfo(np.int64).max


def resolve_edge_key_mode(n: int, key_mode: str = "auto", *,
                          lane: str = "edge") -> str:
    """THE capacity checkpoint: resolve a requested key mode for a graph.

    Every packed-pair-key construction site in the repo routes its capacity
    decision through here (grep-audited in ``tests/test_capacity.py``), so
    there is exactly one place that can raise :class:`GraphTooLargeError`
    and no site can silently overflow.

    Args:
      n: vertex count.
      key_mode: "auto" (int32 when it fits, else wide), "int32" (force the
        fast path; raises past the bound), or "wide" (force x64 int64 keys).
      lane: name used in error messages ("edge", "dynamic", ...).

    Returns:
      The resolved concrete mode: "int32" or "wide".

    Raises:
      GraphTooLargeError: ``key_mode="int32"`` past ``fits_int32_pair_keys``,
        or n past ``fits_int64_pair_keys`` in any mode.
    """
    if key_mode not in EDGE_KEY_MODES:
        raise ValueError(
            f"key_mode must be one of {EDGE_KEY_MODES}, got {key_mode!r}"
        )
    if not fits_int64_pair_keys(n):
        raise GraphTooLargeError(
            f"the {lane} lane packs vertex pairs into (n+1)-radix keys and "
            f"(n+1)^2 overflows even int64 for n={n}; no key mode supports "
            f"this graph (the matrix / hash / bfs lanes use no packed keys "
            f"and remain available)"
        )
    if fits_int32_pair_keys(n):
        return "wide" if key_mode == "wide" else "int32"
    if key_mode == "int32":
        raise GraphTooLargeError(
            f"the {lane} lane was forced to key_mode='int32' but "
            f"(n+1)^2 > int32 max for n={n} (the int32 fast path needs "
            f"n <= 46339); use key_mode='auto' or 'wide' for this graph, "
            f"or the matrix / hash / bfs lanes, which use no packed keys"
        )
    return "wide"


def edge_key_dtype(mode: str) -> np.dtype:
    """Host/device dtype of packed edge keys in a resolved key mode."""
    return np.dtype(np.int64) if mode == "wide" else np.dtype(np.int32)


def edge_key_sentinel(mode: str) -> int:
    """Dead-slot sentinel (dtype max) of a resolved key mode."""
    return WIDE_EDGE_KEY_SENTINEL if mode == "wide" else EDGE_KEY_SENTINEL


def edge_key_context(mode: str):
    """Context manager every wide-mode device computation runs under.

    jax demotes int64 results to int32 whenever an op runs outside an
    ``enable_x64`` scope — even on arrays created inside one — so BOTH the
    trace and every call of a wide-key executable must be wrapped. The
    int32 fast path gets a no-op context, keeping call sites uniform."""
    return _enable_x64() if mode == "wide" else contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class ShapePolicy:
    """How data-dependent extents are rounded into static shape classes.

    Attributes:
      edge_rounding: "pow2" (default) rounds every edge extent — uploaded
        ``col_idx`` length, per-bucket edge counts — up to the next power of
        two, so same-policy graphs of similar size land in identical shape
        classes and share traced prep stages and counting executables.
        "exact" keeps true extents (minimal padding, maximal retracing) —
        the parity-testing configuration.
      min_edges: floor on any rounded extent; keeps tiny buckets from
        fragmenting the executable cache into near-duplicate shapes.

    Frozen ⇒ hashable: a policy participates in ``CountOptions`` equality
    and therefore in the engine's executable-cache keys (``key()`` is the
    normalized tuple used there).
    """

    edge_rounding: str = "pow2"
    min_edges: int = 8

    def __post_init__(self):
        if self.edge_rounding not in ("pow2", "exact"):
            raise ValueError(
                f"edge_rounding must be 'pow2' or 'exact', "
                f"got {self.edge_rounding!r}"
            )
        if not isinstance(self.min_edges, int) or isinstance(self.min_edges, bool) \
                or self.min_edges < 1:
            raise ValueError(
                f"min_edges must be a positive int, got {self.min_edges!r}"
            )

    def round_edges(self, count: int) -> int:
        """The static extent an array of ``count`` edge rows is padded to."""
        count = int(count)
        if self.edge_rounding == "exact":
            return max(count, 1)
        return max(self.min_edges, next_pow2(count))

    def key(self) -> tuple:
        """Hashable identity used in options/cache keys."""
        return (self.edge_rounding, self.min_edges)


DEFAULT_SHAPE_POLICY = ShapePolicy()


# ---------------------------------------------------------------------------
# Jitted primitives — every static_argnames set is a shape class
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "m_pad"))
def _edge_sources(row_ptr: jnp.ndarray, *, n: int, m_pad: int) -> jnp.ndarray:
    """src[i] = CSR row owning slot i (the device analogue of np.repeat)."""
    slots = jnp.arange(m_pad, dtype=jnp.int32)
    src = jnp.searchsorted(row_ptr, slots, side="right") - 1
    return jnp.clip(src, 0, max(n - 1, 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "m_pad", "wide"))
def _csr_from_edges(src: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray,
                    *, n: int, m_pad: int, wide: bool = False):
    """Sort-based CSR build from a (possibly unsorted, masked) edge list.

    Assumes the valid (src, dst) pairs are deduplicated directed edges.
    Invalid slots sort to the end. Returns (row_ptr, col_idx, m) where
    ``col_idx`` is padded with the sentinel ``n`` and ``m`` is the valid
    edge count (a device scalar). Sort keys (and the ``row_starts`` probe
    vector) are int32 on the fast path and int64 when ``wide`` — the caller
    resolves the mode through ``resolve_edge_key_mode`` and wraps wide
    calls in ``edge_key_context``.
    """
    kdt = jnp.int64 if wide else jnp.int32
    big = jnp.asarray(np.iinfo(np.int64 if wide else np.int32).max, kdt)
    key = jnp.where(
        valid,
        src.astype(kdt) * jnp.asarray(n + 1, kdt) + dst.astype(kdt),
        big,
    )
    order = jnp.argsort(key)
    skey = key[order]
    m = valid.sum()
    col = jnp.where(jnp.arange(m_pad) < m, dst[order], n).astype(jnp.int32)
    row_starts = jnp.arange(n + 1, dtype=kdt) * jnp.asarray(n + 1, kdt)
    row_ptr = jnp.searchsorted(skey, row_starts, side="left").astype(jnp.int32)
    return row_ptr, col, m.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "m_pad", "mf_pad"))
def _orient_forward_dev(row_ptr: jnp.ndarray, col_idx: jnp.ndarray,
                        m, *, n: int, m_pad: int, mf_pad: int):
    """Degree-rank forward orientation, compacted to static shape.

    Keeps u→v iff rank(u) < rank(v) with rank = (degree, id) — the paper's
    'filter out half the edges by degree order'. The kept edges (exactly
    m // 2 of them) occupy the leading slots of the returned arrays in CSR
    order; ``kvalid`` marks them. Returns
    (fwd_src, fwd_dst, kvalid, fwd_row_ptr, fwd_deg).
    """
    src = _edge_sources(row_ptr, n=n, m_pad=m_pad)
    dst = col_idx
    valid = jnp.arange(m_pad) < m
    deg = jnp.diff(row_ptr)
    du = deg[src]
    dv = deg[jnp.clip(dst, 0, max(n - 1, 0))]
    keep = valid & ((du < dv) | ((du == dv) & (src < dst)))
    order = jnp.argsort(~keep)  # stable: kept edges first, CSR order intact
    take = order[:mf_pad]
    kvalid = keep[take]
    fsrc = jnp.where(kvalid, src[take], 0).astype(jnp.int32)
    fdst = jnp.where(kvalid, dst[take], 0).astype(jnp.int32)
    fdeg = jax.ops.segment_sum(
        kvalid.astype(jnp.int32), fsrc, num_segments=max(n, 1)
    )[:n]
    frow_ptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(fdeg).astype(jnp.int32)]
    )
    return fsrc, fdst, kvalid, frow_ptr, fdeg


@functools.partial(jax.jit, static_argnames=("n", "width"))
def _padded_neighbors_dev(src: jnp.ndarray, dst: jnp.ndarray,
                          valid: jnp.ndarray, row_ptr: jnp.ndarray,
                          *, n: int, width: int) -> jnp.ndarray:
    """(n, width) neighbor matrix padded with the in-row sentinel ``n``.

    Edge slot i lands at column ``i - row_ptr[src[i]]`` (edges are in CSR
    order, so each row's slots are contiguous); invalid slots scatter out of
    bounds and are dropped.
    """
    pos = jnp.arange(src.shape[0], dtype=jnp.int32) - row_ptr[src]
    pos = jnp.where(valid, pos, width)  # out of bounds ⇒ dropped
    out = jnp.full((n, width), n, dtype=jnp.int32)
    return out.at[src, pos].set(dst.astype(jnp.int32), mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "num_bounds"))
def _bucket_sort_dev(src: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray,
                     deg: jnp.ndarray, bounds: jnp.ndarray,
                     *, n: int, num_bounds: int):
    """Stable-sort edges into degree-class buckets.

    Bucket of an edge = first bound ≥ max(deg[src], deg[dst]) (the paper's
    TwoSmall/TwoLarge grouping, statically shaped); invalid slots sort into
    a trailing overflow class. Returns (sorted_src, sorted_dst, counts,
    starts) with counts/starts per real bucket.
    """
    lim = max(n - 1, 0)
    w = jnp.maximum(deg[jnp.clip(src, 0, lim)], deg[jnp.clip(dst, 0, lim)])
    b = jnp.searchsorted(bounds, w, side="left")
    b = jnp.where(valid, b, num_bounds).astype(jnp.int32)
    order = jnp.argsort(b)  # stable: CSR order preserved within a bucket
    counts = jnp.bincount(b, length=num_bounds + 1)[:num_bounds]
    starts = jnp.concatenate(
        [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)]
    )[:num_bounds]
    return src[order], dst[order], counts, starts


@functools.partial(jax.jit, static_argnames=("n", "e_pad", "width"))
def _gather_bucket_dev(sorted_src: jnp.ndarray, sorted_dst: jnp.ndarray,
                       start, count, nbrs: jnp.ndarray,
                       *, n: int, e_pad: int, width: int):
    """Materialize one bucket's padded (e_pad, width) neighbor-list pair.

    Rows past ``count`` are whole-row padding: u = -1, v = -2 (disjoint ⇒
    zero matches in every intersection core). Within real rows, u keeps the
    in-row sentinel ``n`` and v's is rewritten to ``n + 1``. Returns
    (u_lists, v_lists, src, dst); padded rows carry src = dst = 0, which is
    safe for the per-vertex scatters because their match counts are zero.
    """
    rows = jnp.arange(e_pad)
    bvalid = rows < count
    lim = max(sorted_src.shape[0] - 1, 0)
    idx = jnp.clip(start + rows, 0, lim)
    sb = jnp.where(bvalid, sorted_src[idx], 0).astype(jnp.int32)
    db = jnp.where(bvalid, sorted_dst[idx], 0).astype(jnp.int32)
    u = jnp.where(bvalid[:, None], nbrs[sb, :width], -1).astype(jnp.int32)
    vfull = nbrs[db, :width]
    v = jnp.where(
        bvalid[:, None], jnp.where(vfull == n, n + 1, vfull), -2
    ).astype(jnp.int32)
    return u, v, sb, db


@functools.partial(jax.jit, static_argnames=("n1", "wide"))
def _sorted_edge_keys_dev(src: jnp.ndarray, dst: jnp.ndarray,
                          valid: jnp.ndarray, *, n1: int,
                          wide: bool = False):
    """Sorted packed keys of a masked undirected edge list, plus the sort
    permutation.

    Each live slot's key is ``min(src, dst) * n1 + max(src, dst)`` (``n1`` =
    n + 1, so keys of distinct edges are distinct and ascending keys are
    ascending (lo, hi) pairs — the same order as a host
    ``edge_list_unique``). Dead slots take the key-dtype max sentinel and
    sort to the end, so the leading ``valid.sum()`` entries are the real
    edges. Returns ``(sorted_keys, perm)`` with ``sorted_keys = keys[perm]``
    — ``perm`` maps sorted-key positions back to edge slots, which is how
    the engine reorders its slot-indexed support vectors into key order.
    Keys are int32 on the fast path, int64 when ``wide`` (the caller
    resolves the mode through ``resolve_edge_key_mode`` and wraps wide
    calls in ``edge_key_context``).
    """
    kdt = jnp.int64 if wide else jnp.int32
    lo = jnp.minimum(src, dst).astype(kdt)
    hi = jnp.maximum(src, dst).astype(kdt)
    key = jnp.where(valid, lo * jnp.asarray(n1, kdt) + hi,
                    jnp.asarray(np.iinfo(np.int64 if wide else np.int32).max,
                                kdt))
    perm = jnp.argsort(key)
    return key[perm], perm.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n",))
def _two_core_peel_dev(src: jnp.ndarray, dst: jnp.ndarray,
                       valid: jnp.ndarray, init_alive: jnp.ndarray, *, n: int):
    """Fixed-point 2-core peel over a masked static edge list."""
    lim = max(n - 1, 0)
    dst_c = jnp.clip(dst, 0, lim)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        alive, _ = state
        contrib = (valid & alive[src] & alive[dst_c]).astype(jnp.int32)
        deg = jax.ops.segment_sum(contrib, src, num_segments=n)
        new_alive = alive & (deg >= 2)
        return new_alive, jnp.any(new_alive != alive)

    alive, _ = jax.lax.while_loop(cond, body, (init_alive, jnp.array(True)))
    return alive


@functools.partial(jax.jit, static_argnames=("n",))
def _bfs_levels_dev(src: jnp.ndarray, dst: jnp.ndarray,
                    valid: jnp.ndarray, *, n: int) -> jnp.ndarray:
    """Multi-source BFS levels over a masked static directed edge list.

    Sources are the id-local-minima — vertices with no smaller-id neighbor —
    so every connected component contains at least one (its minimum-id
    vertex) and isolated vertices are their own sources; every vertex
    therefore ends at a finite level. Levels relax as a frontier fixpoint:
    ``lvl[v] = min(lvl[v], 1 + min over in-edges of lvl[u])``, one
    ``scatter-min`` per round, while_loop until no level changes. No packed
    pair keys ⇒ no n ≲ 46k bound.
    """
    lim = max(n - 1, 0)
    src_c = jnp.clip(src, 0, lim)
    dst_c = jnp.clip(dst, 0, lim)
    inf = jnp.int32(n)  # BFS levels are hop counts < n

    has_smaller = jnp.zeros((n,), bool).at[dst_c].max(
        valid & (src < dst), mode="drop"
    )
    lvl0 = jnp.where(has_smaller, inf, 0).astype(jnp.int32)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        lvl, _ = state
        through = jnp.where(valid, lvl[src_c] + 1, inf)
        cand = jnp.full((n,), inf, jnp.int32).at[dst_c].min(through, mode="drop")
        new = jnp.minimum(lvl, cand)
        return new, jnp.any(new != lvl)

    lvl, _ = jax.lax.while_loop(cond, body, (lvl0, jnp.array(n > 0)))
    return lvl


def bfs_levels(dg: "DeviceGraph") -> jnp.ndarray:
    """(n,) int32 BFS levels of a ``DeviceGraph`` (see ``_bfs_levels_dev``).

    The BFS counting lane orders vertices by ``(level, id)`` — a total order,
    so orienting every edge toward its larger-rank endpoint yields a DAG in
    which each triangle has exactly one wedge vertex (its rank-minimum) and
    is closed exactly once.
    """
    return _bfs_levels_dev(dg.edge_sources(), dg.csr.col_idx,
                           dg.edge_valid(), n=dg.n)


@functools.partial(jax.jit, static_argnames=("n", "m_pad"))
def _induced_compact_dev(row_ptr: jnp.ndarray, col_idx: jnp.ndarray,
                         alive: jnp.ndarray, m, *, n: int, m_pad: int):
    """Compact the directed edges with both endpoints alive (CSR order kept).

    Vertex ids are NOT renumbered — dead vertices simply end up with empty
    rows, so downstream per-vertex scatters stay in original-id space.
    Returns (row_ptr_sub, col_sub, kept) with ``col_sub`` padded with ``n``.
    """
    src = _edge_sources(row_ptr, n=n, m_pad=m_pad)
    valid = jnp.arange(m_pad) < m
    lim = max(n - 1, 0)
    keep = valid & alive[src] & alive[jnp.clip(col_idx, 0, lim)]
    order = jnp.argsort(~keep)  # stable compaction
    ksrc = src[order]
    kval = keep[order]
    col = jnp.where(kval, col_idx[order], n).astype(jnp.int32)
    deg = jax.ops.segment_sum(
        kval.astype(jnp.int32), jnp.where(kval, ksrc, 0),
        num_segments=max(n, 1),
    )[:n]
    row_ptr_sub = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(deg).astype(jnp.int32)]
    )
    return row_ptr_sub, col, keep.sum()


def _anchor_rows(keys: jnp.ndarray, rkeys: jnp.ndarray, verts: jnp.ndarray,
                 valid: jnp.ndarray, *, n: int, width: int):
    """Gather padded adjacency rows for a batch of anchor vertices straight
    from the two sorted key orderings — no materialized (n, W) matrix.

    For vertex v, the forward run of ``keys`` (sorted ``lo*(n+1)+hi``)
    holds its neighbors > v and the run of ``rkeys`` (sorted
    ``hi*(n+1)+lo``) its neighbors < v; both runs are located with two
    searchsorted probes and are ascending, so emitting the reverse run
    first yields a globally ascending row (the probe/bitmap cores require
    sorted rows) padded with the in-row sentinel ``n``. Invalid anchors get
    all-padding rows and degree 0. Returns ``(rows (B, width), deg (B,))``.
    Key dtype (int32 fast path / int64 wide mode) follows ``keys``.
    """
    cap = int(keys.shape[0])
    kdt = keys.dtype
    n1 = jnp.asarray(n + 1, kdt)
    v = jnp.clip(verts, 0, max(n - 1, 0)).astype(kdt)
    base = v * n1
    # run boundaries: all of v's keys lie in [v*n1, v*n1 + n) and the
    # resolve_edge_key_mode checkpoint keeps v*n1 + n in the key range
    sf = jnp.searchsorted(keys, base)
    ef = jnp.searchsorted(keys, base + jnp.asarray(n, kdt))
    sr = jnp.searchsorted(rkeys, base)
    er = jnp.searchsorted(rkeys, base + jnp.asarray(n, kdt))
    df = jnp.where(valid, ef - sf, 0)
    dr = jnp.where(valid, er - sr, 0)
    lanes = jnp.arange(width, dtype=jnp.int32)[None, :]
    rev = rkeys[jnp.clip(sr[:, None] + lanes, 0, cap - 1)] % n1
    fwd = keys[jnp.clip(sf[:, None] + lanes - dr[:, None], 0, cap - 1)] % n1
    rows = jnp.where(
        lanes < dr[:, None], rev,
        jnp.where(lanes < (dr + df)[:, None], fwd, jnp.int32(n)))
    return rows.astype(jnp.int32), (df + dr).astype(jnp.int32)


def dynamic_update_step(keys: jnp.ndarray, rkeys: jnp.ndarray,
                        upd_keys: jnp.ndarray, upd_rkeys: jnp.ndarray,
                        upd_ins: jnp.ndarray, upd_valid: jnp.ndarray,
                        *, n: int, width: int):
    """One traced step of the dynamic lane: apply a batched edge update to
    the device-resident edge set in place.

    The edge set is kept in TWO sorted orderings of packed keys (int32 fast
    path / int64 wide mode, dtype follows ``keys``) — ``keys`` by
    ``lo*(n+1)+hi`` and ``rkeys`` by ``hi*(n+1)+lo`` — each with capacity
    ``keys.shape[0]`` (a ``ShapePolicy`` pow2 class) and the key-dtype max
    sentinel in dead slots. Together the two orderings ARE the
    adjacency structure: any vertex's neighbor row is two contiguous runs,
    so per-batch work stays O(batch) gathers plus two capacity-length
    sorts — no O(n·width) CSR / neighbor-matrix rebuild per step. The step:

    1. *resolve* — membership-test the batch against the current key set:
       effective deletes are requested deletes that are present, effective
       inserts are requested inserts that are absent (set semantics; the
       sorted side arrays feed the engine's delta executables).
    2. *apply* — tombstone each deleted slot to the sentinel in place in
       both orderings, then merge the insert candidates in and compact each
       with one sort (tombstones and overflow slots sort past every live
       key). The caller guarantees live-after <= capacity (it grows the key
       arrays BEFORE the step when a batch could overflow, so this compiles
       once per capacity class, not once per batch).
    3. *gather* — anchor-vertex adjacency rows for the delta pass, at the
       session's ``width`` class: rows/degrees of every update edge's
       endpoints against BOTH the pre-update state (for Δ⁻) and the
       post-update state (for Δ⁺), via :func:`_anchor_rows`.
    4. *degrees* — the full (n,) degree vector of the new state from two
       n-query searchsorted boundary scans (for the max-degree stat that
       drives the rare monotone width-class growth).

    Everything is statically shaped by ``(cap, ub, n, width)``; the engine
    caches one jitted wrapper per such class (``"dynamic_step"`` in the
    process-wide executable cache), so steady-state updates are a single
    cached device dispatch.

    Returns:
      (new_keys, new_rkeys, eff_ins, eff_del, ins_skeys, del_skeys,
      old_lo_rows, old_hi_rows, old_lo_deg, old_hi_deg,
      new_lo_rows, new_hi_rows, new_lo_deg, new_hi_deg, stats) —
      ``ins_skeys``/``del_skeys`` are the sorted effective-update forward
      key arrays (sentinel padded); the ``*_rows``/``*_deg`` blocks are the
      (ub, width)/(ub,) anchor adjacency of each update edge's lo/hi
      endpoint; ``stats`` is ``[live_edges, max_degree, num_inserted,
      num_deleted]`` int32, the step's single host-sync payload.
    """
    cap = int(keys.shape[0])
    kdt = keys.dtype
    sent = jnp.asarray(
        WIDE_EDGE_KEY_SENTINEL if kdt == jnp.int64 else EDGE_KEY_SENTINEL,
        kdt)
    n1 = jnp.asarray(n + 1, kdt)
    # -- resolve: which requests take effect against the current set
    idx = jnp.clip(jnp.searchsorted(keys, upd_keys), 0, cap - 1)
    present = (keys[idx] == upd_keys) & upd_valid
    eff_del = present & ~upd_ins
    eff_ins = upd_valid & upd_ins & ~present
    del_skeys = jnp.sort(jnp.where(eff_del, upd_keys, sent))
    ins_skeys = jnp.sort(jnp.where(eff_ins, upd_keys, sent))
    # -- apply: tombstone deletes in place, merge-sort-compact inserts
    # (both orderings; the reverse positions get their own searchsorted)
    tomb = keys.at[jnp.where(eff_del, idx, cap)].set(sent, mode="drop")
    new_keys = jnp.sort(jnp.concatenate(
        [tomb, jnp.where(eff_ins, upd_keys, sent)]))[:cap]
    ridx = jnp.clip(jnp.searchsorted(rkeys, upd_rkeys), 0, cap - 1)
    rtomb = rkeys.at[jnp.where(eff_del, ridx, cap)].set(sent, mode="drop")
    new_rkeys = jnp.sort(jnp.concatenate(
        [rtomb, jnp.where(eff_ins, upd_rkeys, sent)]))[:cap]
    # -- gather: anchor adjacency rows for the delta executables
    ub = int(upd_keys.shape[0])
    lo = jnp.where(upd_valid, upd_keys // n1, 0).astype(jnp.int32)
    hi = jnp.where(upd_valid, upd_keys % n1, 0).astype(jnp.int32)
    old_lo_rows, old_lo_deg = _anchor_rows(keys, rkeys, lo, upd_valid,
                                           n=n, width=width)
    old_hi_rows, old_hi_deg = _anchor_rows(keys, rkeys, hi, upd_valid,
                                           n=n, width=width)
    new_lo_rows, new_lo_deg = _anchor_rows(new_keys, new_rkeys, lo,
                                           upd_valid, n=n, width=width)
    new_hi_rows, new_hi_deg = _anchor_rows(new_keys, new_rkeys, hi,
                                           upd_valid, n=n, width=width)
    del ub
    # -- degrees of the new state: two n-query boundary scans
    live = (new_keys != sent).sum().astype(jnp.int32)
    bnds = jnp.arange(n, dtype=kdt) * n1
    sf = jnp.searchsorted(new_keys, bnds)
    sr = jnp.searchsorted(new_rkeys, bnds)
    deg = (jnp.diff(jnp.append(sf, live)) + jnp.diff(jnp.append(sr, live)))
    stats = jnp.stack([
        live,
        jnp.max(deg, initial=0).astype(jnp.int32),
        eff_ins.sum().astype(jnp.int32),
        eff_del.sum().astype(jnp.int32),
    ])
    return (new_keys, new_rkeys, eff_ins, eff_del, ins_skeys, del_skeys,
            old_lo_rows, old_hi_rows, old_lo_deg, old_hi_deg,
            new_lo_rows, new_hi_rows, new_lo_deg, new_hi_deg, stats)


# ---------------------------------------------------------------------------
# Containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceCSR:
    """Device-resident CSR arrays (undirected-symmetric or oriented).

    ``col_idx`` is padded to a policy-rounded static length with the
    sentinel ``n``; ``m`` is the true directed edge count.
    """

    n: int
    m: int
    row_ptr: jnp.ndarray  # (n+1,) int32
    col_idx: jnp.ndarray  # (m_pad,) int32, padded with n

    @property
    def m_pad(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def degrees(self) -> jnp.ndarray:
        return jnp.diff(self.row_ptr)

    @classmethod
    def from_graph(cls, g: Graph,
                   policy: ShapePolicy = DEFAULT_SHAPE_POLICY) -> "DeviceCSR":
        """Upload a host ``Graph``, padding ``col_idx`` to the policy extent."""
        m_pad = policy.round_edges(g.m_directed)
        col = jnp.asarray(g.col_idx, dtype=jnp.int32)
        pad = m_pad - g.m_directed
        if pad:
            col = jnp.concatenate([col, jnp.full(pad, g.n, jnp.int32)])
        return cls(n=g.n, m=g.m_directed,
                   row_ptr=jnp.asarray(g.row_ptr, dtype=jnp.int32),
                   col_idx=col)

    @classmethod
    def from_edges(cls, src, dst, n: int, *, valid=None,
                   policy: ShapePolicy = DEFAULT_SHAPE_POLICY,
                   key_mode: str = "auto") -> "DeviceCSR":
        """Jitted sort-based CSR build from deduplicated directed edges.

        Args:
          src, dst: equal-length int arrays (device or host) of directed
            edges; need not be sorted.
          n: vertex count (static).
          valid: optional bool mask of live slots (padding slots excluded).
          policy: extent-rounding policy for the uploaded arrays.
          key_mode: "auto" promotes the int32 sort keys to wide (int64)
            keys past ``fits_int32_pair_keys``; "int32"/"wide" force a mode.

        Returns:
          A ``DeviceCSR`` whose rows are sorted by destination id.

        Raises:
          GraphTooLargeError: the resolved key mode cannot represent the
            graph (see :func:`resolve_edge_key_mode`).
        """
        mode = resolve_edge_key_mode(n, key_mode, lane="csr-build")
        with edge_key_context(mode):
            src = jnp.asarray(src, dtype=jnp.int32)
            dst = jnp.asarray(dst, dtype=jnp.int32)
            if valid is None:
                valid = jnp.ones(src.shape[0], dtype=bool)
            m_pad = policy.round_edges(int(src.shape[0]))
            pad = m_pad - int(src.shape[0])
            if pad:
                src = jnp.concatenate([src, jnp.zeros(pad, jnp.int32)])
                dst = jnp.concatenate([dst, jnp.zeros(pad, jnp.int32)])
                valid = jnp.concatenate([valid, jnp.zeros(pad, dtype=bool)])
            row_ptr, col, m = _csr_from_edges(
                src, dst, valid, n=n, m_pad=m_pad, wide=(mode == "wide"))
        return cls(n=int(n), m=int(m), row_ptr=row_ptr, col_idx=col)


class _ForwardEdges:
    """The degree-rank-oriented edge set of a ``DeviceGraph`` (cached)."""

    def __init__(self, src, dst, kvalid, row_ptr, degrees, m: int):
        self.src = src          # (mf_pad,) int32, kept edges first
        self.dst = dst          # (mf_pad,) int32
        self.kvalid = kvalid    # (mf_pad,) bool
        self.row_ptr = row_ptr  # (n+1,) int32
        self.degrees = degrees  # (n,) int32 forward out-degrees
        self.m = m              # true kept edge count (= m_directed // 2)


class DeviceGraph:
    """A graph resident on device, with cached prep structure.

    Wraps a ``DeviceCSR`` and a ``ShapePolicy``; the forward orientation and
    padded neighbor matrices are computed lazily by jitted stages and cached
    on the instance, so the intersection and subgraph prep lanes (see
    ``repro.core.prep``) never rebuild them.
    """

    def __init__(self, csr: DeviceCSR, policy: ShapePolicy = DEFAULT_SHAPE_POLICY,
                 name: str = "graph"):
        self.csr = csr
        self.policy = policy
        self.name = name
        self._fwd: Optional[_ForwardEdges] = None
        self._nbrs: Dict[Tuple[int, bool], jnp.ndarray] = {}

    # -- basic accessors ---------------------------------------------------

    @property
    def n(self) -> int:
        return self.csr.n

    @property
    def m(self) -> int:
        """True directed edge count."""
        return self.csr.m

    @property
    def m_undirected(self) -> int:
        return self.csr.m // 2

    def edge_sources(self) -> jnp.ndarray:
        """(m_pad,) CSR row of every directed edge slot."""
        return _edge_sources(self.csr.row_ptr, n=self.n, m_pad=self.csr.m_pad)

    def edge_valid(self) -> jnp.ndarray:
        """(m_pad,) mask of live (non-padding) edge slots."""
        return jnp.arange(self.csr.m_pad) < self.m

    @classmethod
    def from_graph(cls, g: Graph,
                   policy: ShapePolicy = DEFAULT_SHAPE_POLICY) -> "DeviceGraph":
        return cls(DeviceCSR.from_graph(g, policy), policy=policy, name=g.name)

    # -- derived structure (jitted, cached) --------------------------------

    def forward(self) -> _ForwardEdges:
        """Degree-rank forward orientation (rank = (degree, id)), cached."""
        if self._fwd is None:
            mf_pad = max(1, self.csr.m_pad // 2)
            fsrc, fdst, kvalid, frow_ptr, fdeg = _orient_forward_dev(
                self.csr.row_ptr, self.csr.col_idx, self.m,
                n=self.n, m_pad=self.csr.m_pad, mf_pad=mf_pad,
            )
            self._fwd = _ForwardEdges(fsrc, fdst, kvalid, frow_ptr, fdeg,
                                      m=self.m // 2)
        return self._fwd

    def padded_neighbors(self, width: int, *, oriented: bool) -> jnp.ndarray:
        """(n, width) neighbor matrix (in-row sentinel ``n``), cached.

        ``oriented=True`` gathers the forward (N⁺) lists; ``False`` the full
        undirected adjacency rows.
        """
        key = (int(width), bool(oriented))
        if key not in self._nbrs:
            if oriented:
                fwd = self.forward()
                self._nbrs[key] = _padded_neighbors_dev(
                    fwd.src, fwd.dst, fwd.kvalid, fwd.row_ptr,
                    n=self.n, width=int(width),
                )
            else:
                self._nbrs[key] = _padded_neighbors_dev(
                    self.edge_sources(), self.csr.col_idx, self.edge_valid(),
                    self.csr.row_ptr, n=self.n, width=int(width),
                )
        return self._nbrs[key]

    def __repr__(self) -> str:
        return (f"DeviceGraph(name={self.name!r}, n={self.n}, "
                f"m_undirected={self.m_undirected}, policy={self.policy})")


# ---------------------------------------------------------------------------
# ShardedDeviceCSR — the 2D (degree-class × shard) edge partition
# ---------------------------------------------------------------------------

def shard_valid_counts(total: int, num_shards: int) -> np.ndarray:
    """Real-row count per shard under the round-robin deal.

    Row ``j`` lands on shard ``j % num_shards``, so shard ``s`` owns
    ``ceil((total - s) / num_shards)`` real rows — counts differ by at most
    one across shards, which is the static balance guarantee the
    distributed lanes assert on.
    """
    s = np.arange(int(num_shards), dtype=np.int64)
    return np.maximum(0, (int(total) - s + num_shards - 1) // num_shards) \
        .astype(np.int32)


def deal_across_shards(arr, num_shards: int, rows: int, *, fill):
    """Round-robin deal of axis 0 into a ``(num_shards, rows, ...)`` stack.

    Shard ``s``, position ``p`` receives input row ``p * num_shards + s``;
    out-of-range positions are filled with ``fill`` (the caller's padding
    sentinel). Because upstream schedules are heavy-first ordered (the
    matrix lane's tile schedule) or same-cost-per-row within a bucket (the
    degree-class buckets), the deal hands every shard an equal mix of heavy
    and light work — the multi-device analogue of the paper's
    TwoSmall/TwoLarge workload grouping. One vectorized device gather; no
    per-shard host loop.
    """
    arr = jnp.asarray(arr)
    idx = (jnp.arange(int(rows), dtype=jnp.int32)[None, :] * int(num_shards)
           + jnp.arange(int(num_shards), dtype=jnp.int32)[:, None])
    out = jnp.take(arr, idx.reshape(-1), axis=0, mode="fill",
                   fill_value=fill)
    return out.reshape((int(num_shards), int(rows)) + tuple(arr.shape[1:]))


def _deal_chunk(rows: int) -> int:
    """The length-gating granularity for one sharded bucket: the largest
    power of two ≤ 64 dividing ``rows`` (pow2-policy extents give 64; odd
    exact-policy extents degrade gracefully to 1). Padded rows past the
    last active chunk are never dispatched, and the tail chunk is masked,
    so padding contributes zero counted work."""
    rows = int(rows)
    if rows <= 0:
        return 1
    return math.gcd(rows, 64)


@dataclasses.dataclass
class ShardedBucket:
    """One degree-class bucket dealt round-robin across mesh shards.

    ``u_lists`` / ``v_lists`` are ``(num_shards, rows_per_shard, width)``
    int32 stacks, sharded over every mesh axis on their leading dim; shard
    ``s``'s first ``shard_rows[s]`` rows are real, the rest whole-row
    padding (u = -1 / v = -2). ``valid`` is the same per-shard real-row
    count as a sharded ``(num_shards,)`` device array — the executables
    length-gate their chunk loops on it, so padded rows cost nothing.
    """

    width: int
    edges: int            # total real rows across all shards
    rows_per_shard: int   # policy-rounded static per-shard row extent
    chunk: int            # length-gating granularity (divides rows_per_shard)
    u_lists: jnp.ndarray  # (num_shards, rows_per_shard, width)
    v_lists: jnp.ndarray
    valid: jnp.ndarray    # (num_shards,) int32, sharded like the stacks
    shard_rows: Tuple[int, ...]  # host copy of ``valid``

    @property
    def num_shards(self) -> int:
        return int(self.u_lists.shape[0])

    @property
    def shape(self) -> tuple:
        """Per-shard static work-unit shape ``(rows_per_shard, width)`` —
        the distributed executable-cache key component (the mesh itself is
        keyed separately)."""
        return (self.rows_per_shard, self.width)

    def dispatched_rows(self) -> Tuple[int, ...]:
        """Rows each shard actually dispatches: real rows rounded up to the
        chunk granularity (the length-gated loop's trip count × chunk)."""
        c = self.chunk
        return tuple(int(-(-r // c) * c) if r else 0 for r in self.shard_rows)


@dataclasses.dataclass
class ShardedDeviceCSR:
    """A graph's degree-class buckets partitioned across a device mesh.

    The 2D edge partition behind the ``*_distributed`` lanes: axis 1 is the
    paper's degree-class grouping (each bucket one static (rows, width)
    shape), axis 2 the round-robin deal across the mesh's shards
    (``deal_across_shards``), so every shard holds an equal dense/sparse
    mix and the per-shard work imbalance is at most one row per bucket.
    Built once per plan; the arrays are placed with a ``NamedSharding``
    over every mesh axis at construction, so counting is pure sharded
    replay with one scalar ``psum`` per bucket.
    """

    mesh: object             # jax.sharding.Mesh
    variant: str
    buckets: list            # List[ShardedBucket]
    policy: ShapePolicy
    n: int
    edges: int               # total real forward edges across buckets

    @property
    def num_shards(self) -> int:
        return int(np.prod(self.mesh.devices.shape))

    def shard_work(self) -> Tuple[int, ...]:
        """Total dispatched rows per shard, summed over buckets — the
        balance figure ``meta["shard_work"]`` exposes (max/min ≤ 2× is the
        documented contract when every shard has work)."""
        ndev = self.num_shards
        work = np.zeros(ndev, dtype=np.int64)
        for b in self.buckets:
            work += np.asarray(b.dispatched_rows(), dtype=np.int64)
        return tuple(int(w) for w in work)

    @classmethod
    def from_buckets(cls, buckets, mesh, *, variant: str,
                     policy: Optional[ShapePolicy] = None,
                     n: int = 0) -> "ShardedDeviceCSR":
        """Deal already-prepped ``DeviceBucket``s across ``mesh``'s shards.

        Each bucket's rows go round-robin to the mesh's flattened shard
        list; per-shard extents are policy-rounded (so steady-state repeat
        plans land in identical shape classes) and the stacks are placed
        with a ``NamedSharding`` over every mesh axis.
        """
        from jax.sharding import NamedSharding, PartitionSpec

        policy = policy if policy is not None else DEFAULT_SHAPE_POLICY
        ndev = int(np.prod(mesh.devices.shape))
        axes = tuple(mesh.axis_names)
        row_sharding = NamedSharding(mesh, PartitionSpec(axes))
        out = []
        total = 0
        for b in buckets:
            edges = int(b.edges)
            total += edges
            rows = policy.round_edges(-(-edges // ndev))
            chunk = _deal_chunk(rows)
            u = deal_across_shards(b.u_lists, ndev, rows, fill=-1)
            v = deal_across_shards(b.v_lists, ndev, rows, fill=-2)
            valid_h = shard_valid_counts(edges, ndev)
            u = jax.device_put(u, row_sharding)
            v = jax.device_put(v, row_sharding)
            valid = jax.device_put(jnp.asarray(valid_h), row_sharding)
            out.append(ShardedBucket(
                width=int(b.width), edges=edges, rows_per_shard=int(rows),
                chunk=int(chunk), u_lists=u, v_lists=v, valid=valid,
                shard_rows=tuple(int(x) for x in valid_h),
            ))
        return cls(mesh=mesh, variant=variant, buckets=out, policy=policy,
                   n=int(n), edges=total)

    @classmethod
    def from_graph(cls, g, mesh, *, variant: str = "filtered",
                   widths=(8, 32, 128, 512),
                   policy: Optional[ShapePolicy] = None,
                   prep_backend: str = "device") -> "ShardedDeviceCSR":
        """Prep ``g``'s degree-class buckets (device pipeline by default,
        numpy parity path under ``prep_backend="host"``) and deal them
        across ``mesh``'s shards."""
        from repro.core import prep  # deferred: prep imports this module

        policy = policy if policy is not None else DEFAULT_SHAPE_POLICY
        if prep_backend == "device":
            buckets = prep.prepare_intersection_buckets_device(
                g, variant=variant, widths=widths, policy=policy)
        else:
            buckets = [
                prep.DeviceBucket(
                    width=b["width"], edges=int(b["u_lists"].shape[0]),
                    u_lists=jnp.asarray(b["u_lists"]),
                    v_lists=jnp.asarray(b["v_lists"]),
                    src=jnp.asarray(b["src"]), dst=jnp.asarray(b["dst"]),
                )
                for b in prep.prepare_intersection_buckets_host(
                    g, variant=variant, widths=widths)
            ]
        return cls.from_buckets(buckets, mesh, variant=variant,
                                policy=policy, n=int(g.n))

    def __repr__(self) -> str:
        return (f"ShardedDeviceCSR(num_shards={self.num_shards}, "
                f"variant={self.variant!r}, edges={self.edges}, "
                f"buckets={[(b.shape, b.chunk) for b in self.buckets]})")
