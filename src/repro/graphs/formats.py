"""Graph containers and format conversions.

Host-side (numpy) graph preprocessing: CSR construction, degree-order
permutation, forward-algorithm DAG orientation, padded neighbor matrices,
degree-class bucketing, and 128x128 block-sparse (BSR) tiling.

All heavy counting FLOPs happen in JAX (see repro.core); this module is the
data pipeline that turns an edge list into the statically-shaped arrays those
JAX computations require. This mirrors the paper's split: Gunrock's frontier
plumbing (here: numpy preprocessing) vs. the compute kernels (here: Pallas).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "Graph",
    "BlockSparse",
    "EdgeUpdate",
    "edges_to_csr",
    "csr_to_padded_neighbors",
    "degree_order_permutation",
    "normalize_edge_updates",
    "orient_forward",
    "to_block_sparse",
    "induced_subgraph",
    "bucket_edges_by_degree",
]


class EdgeUpdate(NamedTuple):
    """One streamed edge mutation: insert (default) or delete edge (u, v).

    The dynamic lane (``repro.core.api.DynamicTriangleCounter``) consumes
    batches of these. Endpoints are undirected — ``EdgeUpdate(3, 7)`` and
    ``EdgeUpdate(7, 3)`` name the same edge. Inserting a present edge and
    deleting an absent one are both no-ops (set semantics).
    """

    u: int
    v: int
    insert: bool = True


def normalize_edge_updates(
    updates: Iterable[Union[EdgeUpdate, Tuple[int, ...]]], n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Canonicalize a batch of edge updates for the dynamic lane.

    Accepts ``EdgeUpdate``s, ``(u, v)`` pairs (meaning insert), or
    ``(u, v, insert)`` triples. Endpoints are canonicalized to ``lo < hi``,
    self loops are dropped (the repo's graphs are simple), and updates
    naming the same undirected edge are deduplicated **last-wins** — the
    net effect of applying the batch in order is presence iff the last
    update was an insert, which is exactly what the set semantics of
    one batched apply need.

    Args:
      updates: the update batch, in application order.
      n: vertex count; every endpoint must satisfy ``0 <= id < n``.

    Returns:
      (lo, hi, insert): int32 / int32 / bool numpy arrays, one row per
      surviving distinct undirected edge.

    Raises:
      ValueError: malformed update tuples or out-of-range endpoints.
    """
    us, vs, ins = [], [], []
    for upd in updates:
        t = tuple(upd)
        if len(t) == 2:
            u, v, i = t[0], t[1], True
        elif len(t) == 3:
            u, v, i = t
        else:
            raise ValueError(
                f"edge update must be (u, v) or (u, v, insert), got {upd!r}"
            )
        us.append(u)
        vs.append(v)
        ins.append(bool(i))
    u = np.asarray(us, dtype=np.int64)
    v = np.asarray(vs, dtype=np.int64)
    flag = np.asarray(ins, dtype=bool)
    if u.size:
        bad = (u < 0) | (u >= n) | (v < 0) | (v >= n)
        if bad.any():
            j = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"edge update ({int(u[j])}, {int(v[j])}) out of range for "
                f"n={n}; endpoints must satisfy 0 <= id < n"
            )
    lo = np.minimum(u, v)
    hi = np.maximum(u, v)
    keep = lo != hi  # drop self loops
    lo, hi, flag = lo[keep], hi[keep], flag[keep]
    if lo.size:
        # last-wins dedup: reverse, keep first occurrence per key, restore
        # order; int64 host arithmetic — overflow-free for every n whose ids
        # fit int32, no capacity checkpoint needed
        key = lo.astype(np.int64) * (n + 1) + hi
        _, first_rev = np.unique(key[::-1], return_index=True)
        idx = np.sort(key.shape[0] - 1 - first_rev)
        lo, hi, flag = lo[idx], hi[idx], flag[idx]
    return lo.astype(np.int32), hi.astype(np.int32), flag


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected simple graph in CSR form.

    ``col_idx`` stores both directions of every undirected edge (the Table-1
    convention in the paper), deduplicated, self-loop free, sorted per row.
    """

    n: int
    row_ptr: np.ndarray  # (n+1,) int32
    col_idx: np.ndarray  # (m,) int32, m = #directed edges
    name: str = "graph"

    @property
    def m_directed(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def m_undirected(self) -> int:
        return int(self.col_idx.shape[0]) // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max(initial=0))

    @property
    def sum_square_degrees(self) -> int:
        """Schank & Wagner's SSD = sum_v d(v)^2 — the Fig. 6 x-axis."""
        d = self.degrees.astype(np.int64)
        return int((d * d).sum())

    def neighbors(self, v: int) -> np.ndarray:
        return self.col_idx[self.row_ptr[v] : self.row_ptr[v + 1]]

    def edge_endpoints(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) of every directed CSR slot — the COO view of the
        graph, src[i] repeating each row id by its degree."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return src, self.col_idx

    def edge_list_unique(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) with src < dst — one row per undirected edge."""
        src, dst = self.edge_endpoints()
        keep = src < dst
        return src[keep], dst[keep]

    def to_scipy(self):
        import scipy.sparse as sp

        data = np.ones_like(self.col_idx, dtype=np.int64)
        return sp.csr_matrix(
            (data, self.col_idx, self.row_ptr), shape=(self.n, self.n)
        )


@dataclasses.dataclass(frozen=True)
class BlockSparse:
    """Block-sparse matrix with dense B×B tiles (BSR-like, tile list form).

    ``blocks[t]`` is the dense content of tile t, located at block coordinates
    ``(block_row[t], block_col[t])``. Tiles are sorted by (row, col).
    """

    n: int  # logical matrix dim (padded to multiple of block)
    block: int  # tile edge length (128 = MXU native)
    block_row: np.ndarray  # (T,) int32
    block_col: np.ndarray  # (T,) int32
    blocks: np.ndarray  # (T, block, block) float32/bool

    @property
    def num_blocks(self) -> int:
        return int(self.block_row.shape[0])

    @property
    def grid(self) -> int:
        return self.n // self.block

    def block_index_map(self) -> dict:
        """dict[(br, bc)] -> tile id."""
        return {
            (int(r), int(c)): i
            for i, (r, c) in enumerate(zip(self.block_row, self.block_col))
        }

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n), dtype=self.blocks.dtype)
        b = self.block
        for i in range(self.num_blocks):
            r, c = int(self.block_row[i]) * b, int(self.block_col[i]) * b
            out[r : r + b, c : c + b] = self.blocks[i]
        return out


def edges_to_csr(
    src: np.ndarray,
    dst: np.ndarray,
    n: Optional[int] = None,
    name: str = "graph",
) -> Graph:
    """Build a simple undirected CSR graph from a (possibly dirty) edge list.

    Symmetrizes, removes self loops, deduplicates parallel edges, and sorts
    each adjacency list by neighbor id (required by every intersection
    routine downstream).
    """
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    # symmetrize
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    # dedup via linear key
    key = u * n + v
    key = np.unique(key)
    u = (key // n).astype(np.int32)
    v = (key % n).astype(np.int32)
    # already sorted by (u, v) because unique sorts keys
    counts = np.bincount(u, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return Graph(n=int(n), row_ptr=row_ptr, col_idx=v, name=name)


def degree_order_permutation(g: Graph) -> np.ndarray:
    """perm[new_id] = old_id sorted by (degree, old_id) increasing.

    The paper's tc-matrix step 1 ('permute rows so that it is ordered by an
    increasing number of nonzeros'): shoves the heavy rows to the bottom-right
    so L·U wedge counts stay cheap.
    """
    d = g.degrees
    return np.lexsort((np.arange(g.n), d)).astype(np.int32)


def apply_permutation(g: Graph, perm: np.ndarray) -> Graph:
    """Relabel graph so that new vertex i corresponds to old vertex perm[i]."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.n, dtype=np.int32)
    src, dst = g.edge_endpoints()
    return edges_to_csr(inv[src], inv[dst], n=g.n, name=g.name)


def orient_forward(g: Graph) -> Graph:
    """Forward-algorithm DAG orientation: keep u→v iff rank(u) < rank(v),
    rank = (degree, id). Result is a directed CSR whose rows are the N⁺ lists
    (sorted by vertex id) — this is the paper's 'filter out half the edges by
    degree order' step, and guarantees Σ d⁺(v)² = O(m^1.5) work.
    """
    d = g.degrees
    src, dst = g.edge_endpoints()
    du, dv = d[src], d[dst]
    keep = (du < dv) | ((du == dv) & (src < dst))
    src, dst = src[keep], dst[keep]
    counts = np.bincount(src, minlength=g.n)
    row_ptr = np.zeros(g.n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    # rows remain sorted by dst because the original rows were sorted
    return Graph(n=g.n, row_ptr=row_ptr, col_idx=dst.astype(np.int32), name=g.name + "+fwd")


def csr_to_padded_neighbors(
    g: Graph, pad_to: Optional[int] = None, fill: Optional[int] = None
) -> np.ndarray:
    """(n, pad_to) neighbor matrix padded with ``fill`` (default n — a vertex
    id that never matches a real neighbor, so intersections ignore padding)."""
    width = int(pad_to if pad_to is not None else max(1, g.max_degree))
    fill_v = g.n if fill is None else fill
    out = np.full((g.n, width), fill_v, dtype=np.int32)
    d = g.degrees
    if g.m_directed:
        cols = np.arange(g.m_directed) - np.repeat(g.row_ptr[:-1], d)
        rows = np.repeat(np.arange(g.n), d)
        # rows wider than `width` are silently truncated: bucketed callers
        # guarantee the rows they index fit, and truncated rows are unused
        keep = cols < width
        out[rows[keep], cols[keep]] = g.col_idx[keep]
    return out


def induced_subgraph(g: Graph, vertex_mask: np.ndarray) -> Tuple[Graph, np.ndarray]:
    """Induced subgraph on ``vertex_mask`` (bool, len n). Returns (graph,
    old_ids) where old_ids[new] = old. Mirrors the paper's 'reform the induced
    subgraph with only the edges not filtered'."""
    old_ids = np.nonzero(vertex_mask)[0].astype(np.int32)
    remap = np.full(g.n, -1, dtype=np.int64)
    remap[old_ids] = np.arange(old_ids.shape[0])
    src, dst = g.edge_endpoints()
    keep = vertex_mask[src] & vertex_mask[dst]
    sub = edges_to_csr(
        remap[src[keep]], remap[dst[keep]], n=int(old_ids.shape[0]), name=g.name + "+sub"
    )
    return sub, old_ids


def bucket_edges_by_degree(
    src: np.ndarray,
    dst: np.ndarray,
    out_degree: np.ndarray,
    widths: Sequence[int] = (8, 32, 128, 512),
) -> list:
    """The TPU analogue of the paper's TwoSmall/TwoLarge dynamic grouping.

    Edges are grouped by the max out-degree of their endpoints into buckets of
    static width; each bucket is later processed by one statically-shaped
    intersection kernel launch. Returns a list of dicts
    {width, src, dst} (numpy); edges wider than widths[-1] land in a final
    bucket of width = next pow2 ≥ true max.
    """
    wu = out_degree[src]
    wv = out_degree[dst]
    w = np.maximum(wu, wv)
    buckets = []
    prev = 0
    bounds = list(widths)
    maxw = int(w.max(initial=0))
    if maxw > bounds[-1]:
        top = 1 << int(np.ceil(np.log2(max(maxw, 1))))
        bounds.append(top)
    for width in bounds:
        sel = (w > prev) & (w <= width)
        if sel.any():
            buckets.append(
                dict(width=int(width), src=src[sel].copy(), dst=dst[sel].copy())
            )
        prev = width
    return buckets


def to_block_sparse(
    g: Graph,
    block: int = 128,
    part: str = "full",
    dtype=np.float32,
) -> BlockSparse:
    """Tile the adjacency matrix into dense B×B blocks, keeping only nonzero
    tiles. ``part`` ∈ {full, lower, upper} selects A, strict-L, or strict-U.

    This is the HBM layout for the masked-SpGEMM TC kernel: scale-free graphs
    permuted by increasing degree concentrate nonzeros into few tiles, so the
    MXU runs dense 128³ products over a sparse tile schedule.
    """
    assert part in ("full", "lower", "upper")
    src = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees)
    dst = g.col_idx.astype(np.int64)
    if part == "lower":
        keep = dst < src
        src, dst = src[keep], dst[keep]
    elif part == "upper":
        keep = dst > src
        src, dst = src[keep], dst[keep]
    n_pad = ((g.n + block - 1) // block) * block
    br, bc = src // block, dst // block
    key = br * (n_pad // block) + bc
    order = np.argsort(key, kind="stable")
    src, dst, key = src[order], dst[order], key[order]
    uniq, start = np.unique(key, return_index=True)
    grid = n_pad // block
    block_row = (uniq // grid).astype(np.int32)
    block_col = (uniq % grid).astype(np.int32)
    T = uniq.shape[0]
    blocks = np.zeros((max(T, 1), block, block), dtype=dtype)
    tile_of_edge = np.searchsorted(uniq, key)
    blocks[tile_of_edge, src % block, dst % block] = 1
    if T == 0:
        blocks = np.zeros((0, block, block), dtype=dtype)
        block_row = np.zeros((0,), dtype=np.int32)
        block_col = np.zeros((0,), dtype=np.int32)
    return BlockSparse(
        n=int(n_pad), block=block, block_row=block_row, block_col=block_col, blocks=blocks
    )
