"""Parameter / activation / optimizer sharding rules.

Rules are path-pattern → PartitionSpec, applied to the param pytree. The
scheme is Megatron-style TP on "model" with optional FSDP on "data":

  embed (V, D)                → (model, None)   vocab-parallel embedding
  attn wq/wk/wv (D, H·hd)     → (fsdp?, model)  column-parallel
  attn wo (H·hd, D)           → (model, fsdp?)  row-parallel
  mlp wi/wg (D, F)            → (fsdp?, model)
  mlp wo (F, D)               → (model, fsdp?)
  moe wi/wg (E, D, F)         → (model, fsdp?, None)  expert-parallel
  moe wo (E, F, D)            → (model, None, fsdp?)
  ssm in/out projections      → column/row parallel like attention
  scalars/norms/biases        → replicated

Layer-stacked params carry a leading L (or group G) dim → specs get None
prepended automatically. Optimizer moments inherit the param spec (they are
elementwise) — with fsdp=True that is ZeRO-3; without it, moments still shard
over "model" (ZeRO wrt TP).

"pod" is deliberately never used for params: parameters are replicated across
pods and gradients reduce hierarchically (GSPMD emits intra-pod
reduce-scatter + inter-pod all-reduce from the batch sharding alone).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "param_shardings", "batch_specs", "cache_specs"]

# (regex over '/'-joined path, spec WITHOUT the stacked-layer leading axis)
_RULES = [
    (r"embed$", ("model", None)),
    (r"dec_pos$", (None, None)),
    (r"vision_proj/w$", (None, "model")),
    # attention
    (r"(attn|xattn)/w[qkv]/w$", ("_fsdp", "model")),
    (r"(attn|xattn)/w[qkv]/b$", ("model",)),
    (r"(attn|xattn)/wo/w$", ("model", "_fsdp")),
    # dense mlp
    (r"(mlp|dense)/w[ig]/w$", ("_fsdp", "model")),
    (r"(mlp|dense)/wo/w$", ("model", "_fsdp")),
    # moe experts: expert dim over model (EP), feature dims over fsdp
    (r"moe/router$", (None, None)),
    (r"moe/w[ig]$", ("model", "_fsdp", None)),
    (r"moe/wo$", ("model", None, "_fsdp")),
    # mamba2
    (r"in_proj/w$", ("_fsdp", "model")),
    (r"out_proj/w$", ("model", "_fsdp")),
    (r"conv_w$", (None, "model")),
    # griffin recurrent branch
    (r"(in_x|in_gate)/w$", ("_fsdp", "model")),
    (r"out/w$", ("model", "_fsdp")),
    (r"(gate_[ri]_[wb]|lam)$", ("model",)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path_s: str, ndim: int, fsdp: bool) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path_s):
            axes = tuple(("data" if fsdp else None) if a == "_fsdp" else a
                         for a in spec)
            # stacked-layer leading dims: pad with None on the left
            pad = ndim - len(axes)
            if pad < 0:  # rule is wider than the actual array (e.g. no bias)
                axes = axes[-ndim:] if ndim else ()
            return P(*((None,) * max(pad, 0) + axes))
    return P()  # replicate (norms, scalars, small tables)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a] if a in mesh.shape else 1
    return n


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (jit rejects
    non-divisible *argument* shardings; replication is always legal).
    E.g. mamba2's vocab 50280 and minicpm's 122753 aren't 16-divisible."""
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape) or entry is None:
            out.append(entry)
            continue
        out.append(entry if shape[i] % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def param_specs(params, *, fsdp: bool = False):
    """Pytree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _spec_for(_path_str(path), x.ndim, fsdp), params)


def param_shardings(params, mesh: Mesh, *, fsdp: bool = False):
    specs = param_specs(params, fsdp=fsdp)
    return jax.tree.map(
        lambda s, x: NamedSharding(mesh, sanitize_spec(s, x.shape, mesh)),
        specs, params)


def batch_specs(batch_axes=("data",), with_pod: bool = True):
    """Spec for a training batch dict: batch dim over (pod, data)."""

    def spec(x=None):
        return P(batch_axes)

    return spec


def data_axis(mesh: Mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def batch_sharding(mesh: Mesh, ndim_or_aval):
    """Batch-leading sharding for an input array. Accepts an abstract value
    (preferred — enables the divisibility check) or a plain rank."""
    ax = data_axis(mesh)
    if hasattr(ndim_or_aval, "shape"):
        shape = ndim_or_aval.shape
        spec = sanitize_spec(P(ax, *([None] * (len(shape) - 1))), shape, mesh)
        return NamedSharding(mesh, spec)
    return NamedSharding(mesh, P(ax, *([None] * (ndim_or_aval - 1))))


def cache_specs(cache, mesh: Mesh, batch_size: int):
    """KV/state caches: shard the batch dim (identified by size — caches are
    (L, B, ...) for scan-stacked models but (B, ...) for hybrid ring-buffer
    blocks) over data; for layer-stacked 5D KV caches (L, B, T, H, hd) also
    shard heads over model when divisible. batch=1 (long_500k) replicates."""
    ax = data_axis(mesh)

    def spec(x):
        entries = [None] * x.ndim
        for i, d in enumerate(x.shape[:2]):  # batch dim is dim 0 or 1
            if d == batch_size:
                entries[i] = ax
                break
        if x.ndim >= 5:  # (L, B, T, H, hd): heads over model, else seq
            if x.shape[3] % _axis_size(mesh, "model") == 0:
                entries[3] = "model"
            else:  # MHA archs (qwen 40H, minicpm 36H): flash-decode style
                entries[2] = "model"
        s = sanitize_spec(P(*entries), x.shape, mesh)
        return NamedSharding(mesh, s)

    return jax.tree.map(spec, cache)
