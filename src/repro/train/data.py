"""Synthetic data pipeline: deterministic, seekable, shardable.

A real deployment would stream tokenized shards; the contract that matters
for the framework is reproduced exactly:

  * determinism — batch(step) is a pure function of (seed, step), so restarts
    resume bit-identically without data-state checkpoints beyond the step,
  * seekability — elastic restarts at a different data-parallel size re-slice
    the same global batch,
  * modality stubs — encdec gets frame embeddings, vlm gets patch embeddings
    (the assignment's stub contract for [audio]/[vlm] frontends).

Structure: token sequences are Zipf-ish draws (vocab-heavy head) so xent
curves move during the example training runs instead of staying at log V.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["SyntheticDataConfig", "SyntheticDataset", "make_batch"]


@dataclasses.dataclass(frozen=True)
class SyntheticDataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2


def _tokens(rng: np.random.Generator, cfg: SyntheticDataConfig, vocab: int):
    # zipf draws clipped into vocab; add positional autocorrelation so the
    # model has something learnable (next token correlates with current)
    base = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq_len)) % vocab
    drift = np.cumsum(rng.integers(0, 3, size=(cfg.batch, cfg.seq_len)), axis=1)
    return ((base + drift) % vocab).astype(np.int32)


def make_batch(model_cfg: ModelConfig, data_cfg: SyntheticDataConfig,
               step: int) -> Dict[str, np.ndarray]:
    """Pure function of (seed, step) → batch dict (numpy, host)."""
    rng = np.random.default_rng((data_cfg.seed, step))
    toks = _tokens(rng, data_cfg, model_cfg.vocab)
    batch = {
        "tokens": toks[:, :-1].copy(),
        "labels": toks[:, 1:].copy(),
    }
    if model_cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (data_cfg.batch, model_cfg.encoder_seq, model_cfg.d_model),
            dtype=np.float32)
    if model_cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (data_cfg.batch, model_cfg.vision_tokens, model_cfg.vision_dim),
            dtype=np.float32)
    return batch


class SyntheticDataset:
    """Step-indexed iterator with explicit ``state`` (the step counter) so
    checkpoint/restore and elastic resharding are trivial."""

    def __init__(self, model_cfg: ModelConfig, data_cfg: SyntheticDataConfig,
                 start_step: int = 0):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = make_batch(self.model_cfg, self.data_cfg, self.step)
        self.step += 1
        return b

    @property
    def state(self) -> int:
        return self.step

    def seek(self, step: int) -> None:
        self.step = step
