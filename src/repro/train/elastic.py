"""Fault tolerance and elasticity: the runtime contract.

At 1000+ nodes the failure model is: some host dies every few hours; the
scheduler respawns the job, possibly at a different size. The framework's
answer has three layers, all implemented here or in checkpoint.py:

1. CHECKPOINT/RESTART — atomic checkpoints every N steps (checkpoint.py);
   the driver auto-resumes from ``latest_step`` on boot. Data pipeline state
   is one integer (data.py is step-indexed), so resume is bit-exact.

2. ELASTIC RESCALE — checkpoints are mesh-independent; ``ElasticTrainer``
   re-derives shardings from the *live* mesh on restore, so a 512-chip run
   restarts on 256 chips (half data-parallelism, same model parallelism)
   without conversion. Global batch is preserved by scaling microbatch
   count: new_micro = old_micro · old_dp / new_dp.

3. STRAGGLER MITIGATION — within a step, TPU SPMD is bulk-synchronous, so
   stragglers are handled ahead of the step: (a) static workload balancing
   (identical per-device shapes — guaranteed by the batch/TP sharding and,
   on the TC side, by the snake-dealt tile schedule in core/distributed.py);
   (b) heartbeat detection (``Heartbeat``) so the watchdog replaces a slow
   host at the next checkpoint boundary rather than letting it drag the
   collective — the standard preemption-over-waiting policy.

The in-process pieces (heartbeat file, resume logic, rescale math) run and
are tested; host replacement itself belongs to the cluster scheduler.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax

from repro.train import checkpoint as ckpt

__all__ = ["Heartbeat", "ElasticTrainer", "rescale_microbatches"]


class Heartbeat:
    """Liveness file a watchdog polls; stale mtime ⇒ replace the host."""

    def __init__(self, path: str, interval_s: float = 30.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval_s:
            with open(self.path, "w") as f:
                json.dump({"step": step, "time": now,
                           "process": jax.process_index()}, f)
            self._last = now


def rescale_microbatches(old_micro: int, old_dp: int, new_dp: int) -> int:
    """Preserve global batch across a data-parallel rescale."""
    total = old_micro * old_dp
    assert total % new_dp == 0, (total, new_dp)
    return total // new_dp


@dataclasses.dataclass
class ElasticTrainer:
    """Auto-resuming training-loop shell: owns checkpoint cadence, heartbeat,
    and restore-under-current-mesh."""

    ckpt_dir: str
    save_every: int = 100
    keep: int = 3
    heartbeat: Optional[Heartbeat] = None

    def resume_or_init(self, init_fn: Callable, like=None, shardings=None):
        """Returns (state, start_step). ``init_fn()`` builds fresh state."""
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return init_fn(), 0
        like = like if like is not None else init_fn()
        state, extra = ckpt.restore_checkpoint(
            self.ckpt_dir, step, like, shardings)
        return state, int(extra.get("next_step", step))

    def maybe_save(self, step: int, state, *, force: bool = False) -> None:
        if self.heartbeat is not None:
            self.heartbeat.beat(step)
        if force or (step > 0 and step % self.save_every == 0):
            ckpt.save_checkpoint(self.ckpt_dir, step, state,
                                 extra={"next_step": step + 1}, keep=self.keep)
