"""Serving steps: batched prefill, single-token decode, and a fori-loop
generate driver. These are the functions the decode_* / long_* dry-run cells
lower (one new token against a seq_len KV cache / recurrent state)."""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["make_serve_fns", "greedy_generate"]


def make_serve_fns(model, cfg: ModelConfig):
    def prefill(params, batch, max_len: int):
        return model.prefill(params, batch, max_len)

    def decode_step(params, cache, tokens):
        """tokens (B, 1) — returns (logits (B,1,V), new cache)."""
        return model.decode_step(params, cache, tokens)

    return prefill, decode_step


def greedy_generate(model, cfg: ModelConfig, params, prompt_batch,
                    *, steps: int, max_len: int):
    """Prefill the prompt then greedy-decode ``steps`` tokens (scan-driven)."""
    logits, cache = model.prefill(params, prompt_batch, max_len)
    first = jnp.argmax(logits[:, -1:], axis=-1)

    def body(carry, _):
        cache, tok = carry
        lg, cache = model.decode_step(params, cache, tok)
        nxt = jnp.argmax(lg[:, -1:], axis=-1)
        return (cache, nxt), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (cache, first), None, length=steps)
    return toks.swapaxes(0, 1)  # (B, steps)
