"""Fault-tolerant checkpointing: atomic, mesh-elastic, GC'd.

Design for 1000+ nodes (documented contract; single-process implementation):

  * ATOMICITY — write to ``<dir>/tmp.<step>`` then ``os.rename`` to
    ``step_<n>``; a crash mid-write never corrupts the latest checkpoint.
  * MESH ELASTICITY — arrays are stored as full (unsharded) host numpy with
    their tree paths; ``restore`` device_puts against whatever sharding the
    *current* mesh prescribes, so a 512-chip checkpoint restores onto 256
    chips (elastic downscale) or a different TP split unchanged. On a real
    multi-host fleet the same layout is written per-shard via ocdbt; the
    manifest/commit protocol here is the same.
  * GC — ``keep`` most recent checkpoints are retained.
  * AUTO-RESUME — ``latest_step`` scans the directory; the train driver calls
    it on startup, making SIGKILL-and-respawn the recovery story (see
    train/elastic.py for the watchdog contract).
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(k.key) if isinstance(k, jax.tree_util.DictKey)
            else str(getattr(k, "name", getattr(k, "idx", k)))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "keys": sorted(flat.keys()),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit point
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1)) for m in
        (_STEP_RE.match(d) for d in os.listdir(directory)) if m)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for m in
             (_STEP_RE.match(d) for d in os.listdir(directory)) if m]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``; device_put with ``shardings``
    (a matching pytree or None) — this is where elastic remeshing happens."""
    path = os.path.join(directory, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(flat_like[0]))
    for (pathk, leaf), shard in zip(flat_like[0], shard_leaves):
        key = "/".join(
            str(k.key) if isinstance(k, jax.tree_util.DictKey)
            else str(getattr(k, "name", getattr(k, "idx", k)))
            for k in pathk)
        arr = data[key]
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    return tree, manifest["extra"]
