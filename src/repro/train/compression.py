"""Error-feedback int8 gradient compression for the DP all-reduce.

At 1000+ nodes the inter-pod (DCI) all-reduce of bf16 gradients is the
bandwidth tail; 1-byte quantization with error feedback (residual carried to
the next step) cuts cross-pod bytes 2× vs bf16 / 4× vs fp32 with no
convergence loss at these scales (standard EF-SGD result).

Mechanics: per-leaf symmetric int8 quantization (scale = max|g+e|/127),
psum in int32 (overflow-safe to 2^23 summands), dequantize by the global
scale max. The residual e ← (g+e) − Q⁻¹(Q(g+e)) is optimizer state.

Used by the `compressed` flag of launch/train.py and exercised in
tests/test_compression.py; plugged between grad accumulation and
adamw_update.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "compress_decompress", "ef_psum"]


def ef_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, ef_state):
    """Single-process path: quantize+dequantize each leaf, update residuals.
    Models exactly what the wire sees; the psum itself is exact in int32."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])


def ef_psum(grads, ef_state, axis_name):
    """shard_map-context compressed all-reduce. Devices first agree on a
    SHARED scale (pmax of local maxima — one scalar collective), then
    int8-quantize, psum in int32, and dequantize by the shared scale; mixing
    per-device scales inside an integer reduction would be unrecoverable."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        local_max = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12)
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq_local = q.astype(jnp.float32) * scale
        return (total.astype(jnp.float32) * scale).astype(g.dtype), gf - deq_local

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])
