"""AdamW with warmup-stable-decay (WSD) schedule — no external deps.

WSD (the MiniCPM schedule, [arXiv:2404.06395]): linear warmup → constant
plateau → short exponential-to-zero decay tail. Falls back to cosine via
``schedule="cosine"``.

Moment dtype follows ``ModelConfig.adam_dtype``: bf16 moments halve optimizer
HBM (the difference between arctic-480b fitting a 256-chip pod or not — see
EXPERIMENTS.md §Dry-run); small-model runs use fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "wsd_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    stable_steps: int = 1000
    decay_steps: int = 100
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "wsd"  # wsd | cosine | constant
    moment_dtype: Any = jnp.bfloat16


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def wsd_schedule(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    if cfg.schedule == "constant":
        return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr)
    if cfg.schedule == "cosine":
        total = cfg.stable_steps + cfg.decay_steps
        frac = jnp.clip((step - cfg.warmup_steps) / max(total, 1), 0.0, 1.0)
        return jnp.where(
            step < cfg.warmup_steps, warm,
            0.5 * cfg.peak_lr * (1 + jnp.cos(jnp.pi * frac)))
    # wsd: plateau then exponential tail to ~1% of peak
    decay_start = cfg.warmup_steps + cfg.stable_steps
    tail = jnp.clip((step - decay_start) / max(cfg.decay_steps, 1), 0.0, 1.0)
    return jnp.where(
        step < cfg.warmup_steps, warm,
        jnp.where(step < decay_start, cfg.peak_lr,
                  cfg.peak_lr * jnp.power(0.01, tail)))


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = wsd_schedule(step, cfg)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_m, nu=new_v), metrics
