"""Training step: loss, gradient accumulation (microbatching), optimizer.

Gradient accumulation is a `lax.scan` over microbatches with fp32 grad
accumulators, so peak activation memory is one microbatch regardless of the
global batch — together with per-layer remat this is what bounds arctic-480b
train_4k activations per chip (see EXPERIMENTS.md §Dry-run).

Everything is mesh-free; distribution enters only through the shardings the
launcher attaches via jax.jit in/out_shardings.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update

__all__ = ["make_loss_fn", "make_train_step", "init_train_state"]

_MOE_AUX_WEIGHT = 0.01


def make_loss_fn(model, cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.apply_train(params, batch)
        labels = batch["labels"]
        valid = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        # xent = logsumexp − label logit: avoids materializing log_softmax
        # over the full (tokens, vocab) plane (a §Perf memory-term win)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0] - lse
        ntok = jnp.maximum(valid.sum(), 1.0)
        xent = -(ll * valid).sum() / ntok
        loss = xent + _MOE_AUX_WEIGHT * aux
        return loss, {"xent": xent, "aux": aux, "ntok": ntok}

    return loss_fn


def init_train_state(model, cfg: ModelConfig, opt_cfg: AdamWConfig, key,
                     dtype=jnp.bfloat16):
    params = model.init(key, dtype=dtype)
    opt_state = adamw_init(params, opt_cfg)
    return params, opt_state


def make_train_step(model, cfg: ModelConfig, opt_cfg: AdamWConfig,
                    *, microbatches: Optional[int] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` arrays have the GLOBAL batch leading dim; with microbatching it
    is split as (n_micro, B/n_micro, ...) inside the step (a reshape, so the
    batch sharding on dim 0 survives on dim 1).
    """
    n_micro = microbatches if microbatches is not None else cfg.microbatches
    acc_dtype = (jnp.bfloat16 if cfg.grad_accum_dtype == "bfloat16"
                 else jnp.float32)
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        if n_micro <= 1:
            grads, metrics = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                # STRIDED split (b-major), not contiguous: microbatch m takes
                # rows {k·n_micro + m}. A contiguous split would place each
                # microbatch on a 1/n_micro slice of the data-parallel axis
                # and GSPMD would replicate compute ~n_micro× (observed 8×
                # flops inflation in the dry-run before this fix — see
                # EXPERIMENTS.md §Perf iteration 0).
                return x.reshape(b // n_micro, n_micro,
                                 *x.shape[1:]).swapaxes(0, 1)

            micro = jax.tree.map(split, batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)

            def body(acc, mb):
                g, m = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(acc_dtype) / n_micro, acc, g)
                return acc, m

            grads, ms = jax.lax.scan(body, acc0, micro)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics = {**metrics, **opt_metrics,
                   "loss": metrics["xent"] + _MOE_AUX_WEIGHT * metrics["aux"]}
        return new_params, new_opt, metrics

    return train_step
