"""Reference implementation for the hash-probe cores.

``hash_probe_counts_ref`` is THE semantic oracle (what ``backend="ref"``
dispatches to): it ignores the bucket structure entirely and compares every
probe against every table slot, so a bucketing or ranking bug in the build
path cannot hide in the reference. O(E·W·B·D) — tests and tiny buckets only.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["hash_probe_counts_ref"]


def hash_probe_counts_ref(
    w_lists: jnp.ndarray, src: jnp.ndarray, table: jnp.ndarray
) -> jnp.ndarray:
    """Bucket-structure-independent membership oracle.

    Args:
      w_lists: (E, W) int32 candidate rows (in-row sentinel n + 1, whole
        padding rows -2).
      src: (E,) int32 anchor vertex per row.
      table: (n, B, D) int32 hash table; empty slots -1. Slot *positions*
        are irrelevant here — only the multiset of stored ids matters, which
        is exactly what makes this a cross-check of the build path.

    Returns:
      (E,) int32 — per-edge count of candidates stored anywhere in
      ``table[src]``. Matches the bucketed cores because stored ids are
      unique per row and no sentinel (-2, -1, n, n + 1) collides with a
      stored id.
    """
    flat = table[src].reshape(src.shape[0], -1)  # (E, B·D)
    eq = flat[:, :, None] == w_lists[:, None, :]
    return eq.sum(axis=(1, 2)).astype(jnp.int32)
