"""Per-vertex hash-table construction for the TRUST-style hashing lane.

TRUST (arXiv:2103.08053) makes each warp intersect a candidate list against a
*hash table* of the anchor vertex's oriented neighbor list instead of a sorted
array — O(1) expected probes per candidate regardless of list width. The TPU
analogue built here is a dense, statically shaped table:

    table[v, b, d]  —  (n, B, D) int32

where ``B`` (``num_buckets``, a power of two) buckets neighbor ``w`` of ``v``
at ``b = w & (B - 1)`` and ``D`` (``depth``) is the maximum bucket occupancy
over the whole graph, so every (vertex, bucket) chain fits without probing
chains of dynamic length. Empty slots hold ``-1`` — a value that is never a
probe (probes are real ids ≥ 0 or the positive sentinels n/n+1), so padding
can never match. Both ``B`` and ``D`` are rounded to powers of two by the
planner so same-shape graphs share compiled executables.

Build cost is one O(n·W·log W) jitted pass (an argsort by bucket id per row
plus a segmented-rank scan); it runs once per plan, like the other prep
stages.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["build_hash_table", "hash_table_depth"]


def _bucket_ranks(b: jnp.ndarray) -> jnp.ndarray:
    """Per-row rank of each entry within its bucket chain.

    Args:
      b: (n, W) int32 bucket ids (invalid entries mapped to a bucket id that
        sorts after all real ones, e.g. ``num_buckets``).

    Returns:
      (n, W) int32 — ``rank[v, j]`` = number of row-``v`` entries with the
      same bucket id that sort before entry ``j``. Computed by a stable
      argsort by bucket id followed by a running-maximum segment scan, so it
      is O(W log W) per row instead of the O(W²) pairwise compare.
    """
    n, w = b.shape
    idx = jnp.arange(w, dtype=jnp.int32)
    order = jnp.argsort(b, axis=1)  # stable: ties keep original order
    sb = jnp.take_along_axis(b, order, axis=1)
    is_start = jnp.concatenate(
        [jnp.ones((n, 1), bool), sb[:, 1:] != sb[:, :-1]], axis=1
    )
    start = jax.lax.cummax(jnp.where(is_start, idx[None, :], 0), axis=1)
    rank_sorted = idx[None, :] - start
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, w))
    return jnp.zeros_like(b).at[rows, order].set(rank_sorted)


@jax.jit
def hash_table_depth(nbrs: jnp.ndarray, num_buckets: jnp.ndarray) -> jnp.ndarray:
    """Maximum bucket occupancy over all (vertex, bucket) chains.

    Args:
      nbrs: (n, W) int32 padded oriented neighbor rows, in-row padding = n.
      num_buckets: scalar int32 power-of-two bucket count.

    Returns:
      int32 scalar — the smallest table depth D that loses no entries. The
      planner syncs this once and rounds it to a power of two.
    """
    n = nbrs.shape[0]
    valid = nbrs < n
    b = jnp.where(valid, nbrs & (num_buckets - 1), num_buckets)
    rank = _bucket_ranks(b)
    return jnp.max(jnp.where(valid, rank + 1, 0), initial=0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_buckets", "depth"))
def build_hash_table(
    nbrs: jnp.ndarray, *, num_buckets: int, depth: int
) -> jnp.ndarray:
    """Scatter oriented neighbor rows into the (n, B, D) hash table.

    Args:
      nbrs: (n, W) int32 padded oriented neighbor rows (N⁺ lists, in-row
        padding sentinel = n, rows sorted ascending).
      num_buckets: B, a power of two; bucket(w) = ``w & (B - 1)``.
      depth: D ≥ ``hash_table_depth(nbrs, B)``; shallower chains drop
        entries silently (``mode="drop"``), so callers must size D first.

    Returns:
      (n, B, D) int32 table, empty slots = -1.
    """
    n, w = nbrs.shape
    valid = nbrs < n
    b = jnp.where(valid, nbrs & (num_buckets - 1), num_buckets)  # invalid → OOB
    rank = _bucket_ranks(b)
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, w))
    table = jnp.full((n, num_buckets, depth), -1, jnp.int32)
    return table.at[rows, b, rank].set(nbrs.astype(jnp.int32), mode="drop")
