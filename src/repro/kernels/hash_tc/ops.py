"""Dispatch layer for the TRUST-style per-vertex hash-table counting core.

The hashing lane's count stage is a membership problem: for forward edge
(u, v), how many of v's oriented neighbors appear in u's oriented neighbor
list? The intersect package answers it by merging two *sorted arrays*; this
package answers it TRUST-style (arXiv:2103.08053) by probing a *per-vertex
hash table* — O(D) slot compares per probe instead of O(W) or O(log W):

    backend      core                                  notes
    --------     ----------------------------------   -------------------------
    "jnp"        ``hash_probe_counts_jnp``             chunked gather, CPU path
    "pallas"     ``hash_probe_counts_pallas``          table-in-VMEM TPU kernel
    "ref"        ``hash_probe_counts_ref``             structure-blind oracle

Sentinel rules (shared with the rest of the repo): candidate rows are the
bucket machinery's ``v_lists`` — in-row padding n + 1, whole padding rows -2,
with ``src`` carrying 0 on padding rows; table padding is -1. Only values in
[0, n) probe, so no sentinel combination can ever match.

Table sizing: ``hash_num_buckets`` picks B = next-pow2(width) (≥ 8), i.e. a
load factor ≤ 1 for a full row; the planner measures the real maximum chain
length with ``hash_table_depth`` and rounds it to a pow2 D, so the table
shape (n, B, D) is a deterministic function of the graph's shape class and
plans with equal classes share compiled executables.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.hash_tc.build import build_hash_table, hash_table_depth
from repro.kernels.hash_tc.probe import (
    hash_probe_counts_jnp,
    hash_probe_counts_pallas,
)
from repro.kernels.hash_tc.ref import hash_probe_counts_ref

__all__ = [
    "build_hash_table",
    "hash_num_buckets",
    "hash_probe_counts",
    "hash_table_depth",
]


def hash_num_buckets(width: int) -> int:
    """Bucket count for a table serving rows of ``width``: next pow2, ≥ 8."""
    return max(8, 1 << max(0, int(width) - 1).bit_length())


def hash_probe_counts(
    w_lists: jnp.ndarray,
    src: jnp.ndarray,
    table: jnp.ndarray,
    *,
    backend: str = "jnp",
    interpret: bool = True,
    tile_edges: int = 128,
) -> jnp.ndarray:
    """Dispatch per-edge hash-probe counts. (E, W) probes × (n, B, D) → (E,).

    Args:
      w_lists: (E, W) int32 candidate rows (sorted N⁺(dst) lists; in-row
        sentinel n + 1, whole padding rows -2).
      src: (E,) int32 anchor vertex per row (padding rows carry 0).
      table: (n, B, D) int32 per-vertex hash table from
        ``build_hash_table``; B must be a power of two.
      backend: "pallas" (table-in-VMEM TPU kernel), "jnp" (chunked-gather
        production path), or "ref" (the structure-blind oracle).
      tile_edges: pallas grid tile height; E is sentinel-row-padded to a
        multiple of it and the padding stripped from the result.
      interpret: pallas interpret mode (True = run kernel bodies on CPU).

    Returns:
      (E,) int32 — per-edge count of candidates present in ``table[src]``
      (= |N⁺(dst) ∩ N⁺(src)| when fed the planner's oriented rows).
    """
    if backend not in ("pallas", "jnp", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "ref":
        return hash_probe_counts_ref(w_lists, src, table)
    if backend == "jnp":
        return hash_probe_counts_jnp(w_lists, src, table)

    # backend == "pallas": tile the edge axis, strip padding on the way out
    e = int(w_lists.shape[0])
    if e == 0:
        return jnp.zeros((0,), jnp.int32)
    pad = (-e) % tile_edges
    if pad:
        w_lists = jnp.pad(w_lists, ((0, pad), (0, 0)), constant_values=-2)
        src = jnp.pad(src, ((0, pad),), constant_values=0)
    out = hash_probe_counts_pallas(
        w_lists, src, table, tile_edges=tile_edges, interpret=interpret
    )
    return out[:e] if pad else out
