"""Hash-probe cores for the TRUST-style hashing lane.

Per forward edge (u, v) the lane counts ``|N⁺(v) ∩ N⁺(u)|`` by probing each
element of the candidate row (``N⁺(v)``, the bucket machinery's ``v_lists``)
against the anchor's hash table row ``table[u]`` — TRUST's warp-level
hash-intersection, vectorized: a probe ``w`` reads bucket ``w & (B - 1)`` and
compares against its D chain slots, so per-edge work is O(W·D) instead of the
broadcast core's O(W²).

Two implementations of the same semantics:

* ``hash_probe_counts_jnp``    — gathers each edge's table row and resolves
                                 all probes with one ``take_along_axis``;
                                 ``lax.map``-chunked so the (C, B, D) gather
                                 stays inside a fixed element budget.
* ``hash_probe_counts_pallas`` — a Pallas kernel: the whole flattened table
                                 sits in VMEM, each grid step loads a
                                 (TE, W) probe tile and walks its rows with
                                 ``pl.ds`` dynamic slices + an in-register
                                 bucket gather.

Probe-validity rule: only values in [0, n) probe; the candidate rows' in-row
sentinel (n + 1) and whole-row padding (-2) are masked out, and empty table
slots hold -1 which no valid probe can equal.

VMEM budget (pallas): the table is not tiled — n·B·D·4B must fit beside the
(TE, W) probe tile; with n=8192, B=64, D=4 that is ~8 MB. Wider tables want
the jnp path (documented in docs/ARCHITECTURE.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hash_probe_counts_jnp", "hash_probe_counts_pallas"]

# element budget for one chunk's (C, B, D) table gather + (C, W, D) candidate
# compare — mirrors the broadcast core's chunking constant
_PROBE_CHUNK_ELEMS = 1 << 22


def _probe_block(w_lists: jnp.ndarray, src: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    n, num_buckets, _ = table.shape
    tbl = table[src]  # (C, B, D)
    valid = (w_lists >= 0) & (w_lists < n)
    bkt = jnp.where(valid, w_lists & (num_buckets - 1), 0)
    cand = jnp.take_along_axis(tbl, bkt[:, :, None], axis=1)  # (C, W, D)
    hit = jnp.any(cand == w_lists[:, :, None], axis=-1) & valid
    return hit.sum(axis=1).astype(jnp.int32)


@jax.jit
def hash_probe_counts_jnp(
    w_lists: jnp.ndarray, src: jnp.ndarray, table: jnp.ndarray
) -> jnp.ndarray:
    """Chunked jnp hash probe (the production CPU path).

    Args:
      w_lists: (E, W) int32 candidate rows (sorted N⁺(dst) lists, in-row
        sentinel n + 1, whole padding rows -2).
      src: (E,) int32 anchor vertex per row (padding rows carry 0 — harmless,
        their probes are all invalid).
      table: (n, B, D) int32 hash table from ``build_hash_table``; B must be
        a power of two.

    Returns:
      (E,) int32 — per-edge count of candidates present in ``table[src]``.
    """
    e, w = w_lists.shape
    if e == 0:
        return jnp.zeros((0,), jnp.int32)
    _, num_buckets, depth = table.shape
    per_row = (num_buckets + w) * max(1, depth)
    chunk = int(min(e, max(1, _PROBE_CHUNK_ELEMS // max(1, per_row))))
    if chunk >= e:
        return _probe_block(w_lists, src, table)
    pad = (-e) % chunk
    wp = jnp.pad(w_lists, ((0, pad), (0, 0)), constant_values=-2)
    sp = jnp.pad(src, ((0, pad),), constant_values=0)
    out = jax.lax.map(
        lambda t: _probe_block(t[0], t[1], table),
        (wp.reshape(-1, chunk, w), sp.reshape(-1, chunk)),
    )
    return out.reshape(-1)[:e]


def _hash_probe_kernel(w_ref, src_ref, tbl_ref, out_ref, *, num_buckets: int, n: int):
    w = w_ref[...]  # (TE, W) int32 candidate rows

    def body(i, carry):
        u = src_ref[i, 0]
        tbl = tbl_ref[pl.ds(u * num_buckets, num_buckets), :]  # (B, D)
        row = w[i, :]
        valid = (row >= 0) & (row < n)
        bkt = jnp.where(valid, row & (num_buckets - 1), 0)
        cand = jnp.take(tbl, bkt, axis=0)  # (W, D) in-register gather
        hit = jnp.any(cand == row[:, None], axis=-1) & valid
        pl.store(out_ref, (pl.ds(i, 1),), hit.sum(dtype=jnp.int32)[None])
        return carry

    jax.lax.fori_loop(0, w.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("tile_edges", "interpret"))
def hash_probe_counts_pallas(
    w_lists: jnp.ndarray,
    src: jnp.ndarray,
    table: jnp.ndarray,
    *,
    tile_edges: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas hash-probe kernel; semantics of ``hash_probe_counts_jnp``.

    Args:
      w_lists: (E, W) int32 candidate rows; E must be a multiple of
        ``tile_edges`` (ops.py pads with sentinel rows).
      src: (E,) int32 anchor vertices (padding rows carry 0).
      table: (n, B, D) int32 hash table, B a power of two; resident in VMEM
        un-tiled (see module docstring for the budget).
      tile_edges: probe rows per grid step.
      interpret: run the kernel body on CPU for validation; pass False on a
        real TPU.

    Returns:
      (E,) int32 per-edge hit counts.
    """
    e, w = w_lists.shape
    n, num_buckets, depth = table.shape
    assert e % tile_edges == 0, (e, tile_edges)
    flat = table.reshape(n * num_buckets, depth)
    grid = (e // tile_edges,)
    return pl.pallas_call(
        functools.partial(_hash_probe_kernel, num_buckets=num_buckets, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
            pl.BlockSpec((tile_edges, 1), lambda i: (i, 0)),
            pl.BlockSpec((n * num_buckets, depth), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_edges,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(w_lists, src.reshape(e, 1), flat)
