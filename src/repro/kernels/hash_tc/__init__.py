from repro.kernels.hash_tc.ops import (
    build_hash_table,
    hash_num_buckets,
    hash_probe_counts,
    hash_table_depth,
)
from repro.kernels.hash_tc.probe import (
    hash_probe_counts_jnp,
    hash_probe_counts_pallas,
)
from repro.kernels.hash_tc.ref import hash_probe_counts_ref

__all__ = [
    "build_hash_table",
    "hash_num_buckets",
    "hash_probe_counts",
    "hash_probe_counts_jnp",
    "hash_probe_counts_pallas",
    "hash_probe_counts_ref",
    "hash_table_depth",
]
