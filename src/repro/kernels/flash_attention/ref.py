"""Pure-jnp oracle for the flash-attention TPU kernel.

Plain materialized attention (the O(S·T) logit plane) — numerically the
ground truth the tiled kernel must match. Supports causal masking, local
windows, and GQA via q-head grouping, mirroring repro.models.layers.attention
semantics (which is itself a chunked-streaming evaluation of this oracle).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def flash_attention_ref(
    q: jnp.ndarray,  # (B, S, Hq, hd)
    k: jnp.ndarray,  # (B, T, Hkv, hd)
    v: jnp.ndarray,  # (B, T, Hkv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jnp.ndarray:
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bshgt", qg, k.astype(jnp.float32))
    logits = logits / jnp.sqrt(hd)
    if cap is not None:
        logits = jnp.tanh(logits / cap) * cap
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    valid = jnp.ones((s, t), bool)
    if causal:
        valid &= qp >= kp
    if window is not None:
        valid &= (qp - kp) < window
    logits = jnp.where(valid[None, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bshgt,bthd->bshgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)
