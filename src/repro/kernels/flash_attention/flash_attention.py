"""Pallas TPU flash attention (forward) — the MXU realization of the chunked
online-softmax schedule in repro.models.layers.attention.

Tiling: grid = (batch·kv_heads, S/BLOCK_Q). Each program owns a BLOCK_Q tile
of queries (all G grouped q-heads at once) and streams the full K/V sequence
through VMEM in BLOCK_K slabs via `jax.lax.fori_loop`, maintaining running
(max, sumexp, out) — O(BLOCK_Q·BLOCK_K) live memory, never S×T.

The q tile arrives as (BLOCK_Q, G·hd) so the q@kᵀ and p@v products are plain
2-D MXU matmuls (G folds into the N dimension). Causal/window masking is
positional arithmetic on the fly; softcap (gemma2) is a tanh on the tile.

VMEM at BLOCK_Q=256, BLOCK_K=512, hd=256, G=8: q 1 MB + k/v 0.5 MB each +
out 2 MB (f32) — ~4.5 MB, double-buffer safe.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, *, block_k: int, seq_k: int,
                  causal: bool, window: Optional[int], cap: Optional[float],
                  g: int, hd: int, block_q: int):
    qi = pl.program_id(1)
    q = q_ref[...]  # (BQ, G*hd)
    scale = 1.0 / (hd ** 0.5)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    nblocks = seq_k // block_k

    def body(kb, carry):
        m_run, l_run, acc = carry
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k),
                                 slice(None)))  # (BK, hd)
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k),
                                 slice(None)))
        # logits: (BQ*G, BK) via 2-D matmul on the MXU
        qf = q.astype(jnp.float32).reshape(block_q * g, hd)
        logits = jax.lax.dot_general(
            qf, k_tile.astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if cap is not None:
            logits = jnp.tanh(logits / cap) * cap
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        qk = jnp.repeat(q_pos, g, axis=0) - k_pos  # (BQ*G, BK)
        valid = jnp.ones_like(qk, dtype=jnp.bool_)
        if causal:
            valid &= qk >= 0
        if window is not None:
            valid &= qk < window
        logits = jnp.where(valid, logits, -1e30)
        m_new = jnp.maximum(m_run, logits.max(axis=1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_run * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v_tile.astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (BQ*G, hd)
        acc = acc * alpha[:, None] + pv
        return m_new, l_new, acc

    m0 = jnp.full((block_q * g,), -1e30, jnp.float32)
    l0 = jnp.zeros((block_q * g,), jnp.float32)
    a0 = jnp.zeros((block_q * g, hd), jnp.float32)
    m_f, l_f, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, a0))
    out = acc / jnp.maximum(l_f, 1e-30)[:, None]
    out_ref[...] = out.reshape(block_q, g * hd).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "cap", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, S, Hq, hd)
    k: jnp.ndarray,  # (B, T, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    # fold GQA: (B·Hkv, S, G·hd) queries against (B·Hkv, T, hd) keys
    qr = q.reshape(b, s, hkv, g * hd).transpose(0, 2, 1, 3).reshape(
        b * hkv, s, g * hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    grid = (b * hkv, s // block_q)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_k=block_k, seq_k=t, causal=causal,
            window=window, cap=cap, g=g, hd=hd, block_q=block_q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, g * hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, t, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, t, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, g * hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, s, g * hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hkv, s, g, hd).transpose(0, 2, 1, 3, 4).reshape(
        b, s, hq, hd)
