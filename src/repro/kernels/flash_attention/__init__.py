from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas

__all__ = ["flash_attention", "flash_attention_ref", "flash_attention_pallas"]
