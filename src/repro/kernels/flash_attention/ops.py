"""Jitted dispatch for attention: pallas flash kernel / chunked-jnp / oracle."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref

__all__ = ["flash_attention"]


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, cap: Optional[float] = None,
                    backend: str = "jnp", interpret: bool = True):
    if backend == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      cap=cap, interpret=interpret)
    if backend == "jnp":
        from repro.models.layers import attention, NO_WINDOW

        s, t = q.shape[1], k.shape[1]
        return attention(q, k, v, q_pos=jnp.arange(s), k_pos=jnp.arange(t),
                         causal=causal,
                         window=NO_WINDOW if window is None else window,
                         cap=cap)
    if backend == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    raise ValueError(backend)
