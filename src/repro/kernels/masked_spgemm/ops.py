"""Jitted wrapper for the masked block-SpGEMM triangle kernel.

``backend`` ∈ {"pallas", "jnp", "ref"}:
  pallas — the MXU tile kernel (interpret=True on CPU),
  jnp    — chunked einsum path (memory-bounded via lax.map), production CPU
           path and the path GSPMD shards in distributed TC,
  ref    — the one-shot einsum oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.masked_spgemm.masked_spgemm import masked_spgemm_pallas
from repro.kernels.masked_spgemm.ref import masked_spgemm_ref

__all__ = ["masked_spgemm_counts"]


@functools.partial(jax.jit, static_argnames=("chunk",))
def _masked_spgemm_chunked(l_tiles, u_tiles, a_tiles, *, chunk: int = 64):
    t = l_tiles.shape[0]
    pad = (-t) % chunk
    if pad:
        z = jnp.zeros((pad,) + l_tiles.shape[1:], l_tiles.dtype)
        l_tiles = jnp.concatenate([l_tiles, z])
        u_tiles = jnp.concatenate([u_tiles, z])
        a_tiles = jnp.concatenate([a_tiles, z])
    lt = l_tiles.reshape(-1, chunk, *l_tiles.shape[1:])
    ut = u_tiles.reshape(-1, chunk, *u_tiles.shape[1:])
    at = a_tiles.reshape(-1, chunk, *a_tiles.shape[1:])

    def body(args):
        l, u, a = args
        prod = jnp.einsum("tik,tkj->tij", l, u, preferred_element_type=jnp.float32)
        return (prod * a).sum(axis=(1, 2))

    out = jax.lax.map(body, (lt, ut, at)).reshape(-1)
    return out[:t] if pad else out


def masked_spgemm_counts(
    l_tiles: jnp.ndarray,
    u_tiles: jnp.ndarray,
    a_tiles: jnp.ndarray,
    *,
    backend: str = "jnp",
    tile_triples: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Dispatch per-triple masked wedge counts ``sum(A ∘ (L @ U))``.

    Args:
      l_tiles: (T, B, B) float32 (or bf16) dense L tiles from the host
        schedule; zero tiles are valid padding and contribute exactly 0.
      u_tiles: (T, B, B) U tiles, same dtype/layout.
      a_tiles: (T, B, B) strict-upper mask tiles.
      backend: "pallas" | "jnp" | "ref" (see module docstring).
      tile_triples: pallas grid tile depth; T is zero-padded to a multiple of
        it and the padding stripped from the result.
      interpret: pallas interpret mode (True = run kernel bodies on CPU).

    Returns:
      (T,) float32 per-triple partial counts; their sum is the triangle
      count when A covers the strict upper triangle.
    """
    if backend == "pallas":
        t = l_tiles.shape[0]
        pad = (-t) % tile_triples
        if pad:
            z = jnp.zeros((pad,) + l_tiles.shape[1:], l_tiles.dtype)
            l_tiles = jnp.concatenate([l_tiles, z])
            u_tiles = jnp.concatenate([u_tiles, z])
            a_tiles = jnp.concatenate([a_tiles, z])
        out = masked_spgemm_pallas(
            l_tiles, u_tiles, a_tiles, tile_triples=tile_triples, interpret=interpret
        )
        return out[:t] if pad else out
    if backend == "jnp":
        return _masked_spgemm_chunked(l_tiles, u_tiles, a_tiles)
    if backend == "ref":
        return masked_spgemm_ref(l_tiles, u_tiles, a_tiles)
    raise ValueError(f"unknown backend {backend!r}")
