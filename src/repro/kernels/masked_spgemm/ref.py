"""Pure-jnp oracle for the fused masked block-SpGEMM triangle kernel.

Inputs are stacked 128×128 (or any B×B) dense tiles gathered by the host
scheduler (core/tc_matrix.py):

  l_tiles (T, B, B)  — L tile at (I, K) for triple t
  u_tiles (T, B, B)  — U tile at (K, J) for triple t
  a_tiles (T, B, B)  — mask tile A at (I, J) for triple t

Output: per-triple masked partial wedge counts  sum(A_IJ ∘ (L_IK @ U_KJ)),
shape (T,) float32. Total triangles = sum(out) when A covers the strict upper
triangle (each triangle counted exactly once at its min-vertex wedge).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["masked_spgemm_ref"]


def masked_spgemm_ref(
    l_tiles: jnp.ndarray, u_tiles: jnp.ndarray, a_tiles: jnp.ndarray
) -> jnp.ndarray:
    """One-shot einsum oracle for the fused masked block-SpGEMM kernel.

    Args:
      l_tiles / u_tiles / a_tiles: (T, B, B) stacked dense tiles (see module
        docstring for the triple-schedule layout).

    Returns:
      (T,) float32 — per-triple ``sum(A_IJ ∘ (L_IK @ U_KJ))``.
    """
    prod = jnp.einsum(
        "tik,tkj->tij", l_tiles, u_tiles, preferred_element_type=jnp.float32
    )
    return (prod * a_tiles).sum(axis=(1, 2)).astype(jnp.float32)
