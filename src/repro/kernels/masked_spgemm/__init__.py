from repro.kernels.masked_spgemm.ops import masked_spgemm_counts
from repro.kernels.masked_spgemm.ref import masked_spgemm_ref
from repro.kernels.masked_spgemm.masked_spgemm import masked_spgemm_pallas

__all__ = ["masked_spgemm_counts", "masked_spgemm_ref", "masked_spgemm_pallas"]
