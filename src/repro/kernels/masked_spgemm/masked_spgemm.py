"""Pallas TPU kernel: fused masked block-SpGEMM for triangle counting.

This kernel implements, in one fused pass, all three TC-specific SpGEMM
optimizations the paper identifies but leaves as future work (§5):

  (1) compute only the upper-triangular part  — the host scheduler emits
      triples only for A's strict-upper tiles;
  (2) avoid multiplications where A is known zero — only nonzero (A, L, U)
      tile triples are scheduled at all (block-level masking), and the
      elementwise mask inside the tile kills the rest;
  (3) never write B = L·U to global memory    — the tile product lives only
      in VMEM/registers; the kernel emits one f32 partial count per triple.

TPU mapping: each grid step processes TT triples. The B×B×B tile product runs
on the MXU (B = 128 → one native systolic pass); mask + reduce run on the VPU.
Arithmetic intensity per triple: 2·B³ FLOPs over 3·B²·4 bytes ≈ 21 FLOP/byte
(B=128), comfortably compute-bound against TPU v5e's ~240 FLOP/byte ridge only
at low B — which is exactly why the tile schedule (not this kernel) is where
hillclimbing happens; see EXPERIMENTS.md §Perf.

VMEM: 3 · TT·B²·4B + TT·4B. TT=8, B=128 → ~1.6 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_spgemm_pallas"]


def _masked_spgemm_kernel(l_ref, u_ref, a_ref, out_ref):
    l = l_ref[...]  # (TT, B, B)
    u = u_ref[...]
    a = a_ref[...]
    prod = jax.lax.dot_general(
        l,
        u,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),  # batched (B,B)@(B,B)
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = (prod * a).sum(axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("tile_triples", "interpret"))
def masked_spgemm_pallas(
    l_tiles: jnp.ndarray,
    u_tiles: jnp.ndarray,
    a_tiles: jnp.ndarray,
    *,
    tile_triples: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas fused masked block-SpGEMM: per-triple ``sum(A ∘ (L @ U))``.

    Args:
      l_tiles: (T, B, B) float32/bf16 dense L tiles; T must be a multiple of
        ``tile_triples`` (callers pad with zero tiles, which contribute
        exactly 0 to the count).
      u_tiles: (T, B, B) U tiles, same dtype.
      a_tiles: (T, B, B) mask tiles (strict upper triangle of A).
      tile_triples: triples per grid step (VMEM tile depth).
      interpret: run the kernel body on CPU for validation; pass False on a
        real TPU.

    Returns:
      (T,) float32 per-triple masked partial wedge counts.
    """
    t, b, b2 = l_tiles.shape
    assert b == b2 and t % tile_triples == 0, (t, b, b2, tile_triples)
    grid = (t // tile_triples,)
    return pl.pallas_call(
        _masked_spgemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_triples, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_triples, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_triples, b, b), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_triples,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=interpret,
    )(l_tiles, u_tiles, a_tiles)
