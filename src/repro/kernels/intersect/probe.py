"""Binary-probe set-intersection core (the ``probe`` strategy).

Scan the u-list, binary-search each element in the sorted v-list — the TPU
analogue of the paper's proposed third GPU kernel ("scan the smaller list,
search the larger") and of Wang & Owens' BFS-based follow-up (arXiv:1909.02127)
where binary probing wins on wide, skewed neighborhoods. O(W·log W) work per
edge vs the broadcast core's O(W²).

Two implementations of the same semantics:

* ``intersect_counts_probe``        — vmapped ``jnp.searchsorted`` (the
                                      production CPU path; GSPMD-shardable).
* ``intersect_counts_probe_pallas`` — a Pallas kernel running a branchless
                                      fixed-iteration lower-bound search per
                                      lane: every u element in a (TE, W) tile
                                      searches its v row in ``bit_length(W)``
                                      compare/select rounds, each a gather +
                                      VPU select at full vector width.

Both require rows sorted ascending. Padding follows the repo-wide sentinel
rule: u rows pad with one value, v rows with a *different* value, so padding
never probes successfully.

VMEM budget (pallas): 2 · TE·W·4B inputs + 4 · TE·W·4B search state; with
TE=256, W=512 that is ~3.1 MB — under the ~16 MB/core budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["intersect_counts_probe", "intersect_counts_probe_pallas"]


@jax.jit
def intersect_counts_probe(u_lists: jnp.ndarray, v_lists: jnp.ndarray) -> jnp.ndarray:
    """Binary-search each element of u in the sorted v list.

    Args:
      u_lists: (E, W) int32, each row sorted ascending (neighbor list +
        trailing sentinel padding).
      v_lists: (E, W) int32, same layout, padded with a sentinel disjoint
        from u's so padding never matches.

    Returns:
      (E,) int32 — per-edge |N(u) ∩ N(v)|. O(W log W) per row.
    """

    def one(u, v):
        pos = jnp.searchsorted(v, u)
        pos = jnp.clip(pos, 0, v.shape[0] - 1)
        return (v[pos] == u).sum(dtype=jnp.int32)

    return jax.vmap(one)(u_lists, v_lists)


def _probe_kernel(u_ref, v_ref, out_ref, *, width: int):
    u = u_ref[...]  # (TE, W) int32, rows sorted
    v = v_ref[...]  # (TE, W) int32, rows sorted
    # Branchless lower-bound binary search, all TE·W lanes in lockstep.
    # Fixed iteration count bit_length(W) ≥ ceil(log2(W+1)) covers the
    # [0, W] search range; converged lanes are frozen by the `active` mask.
    lo = jnp.zeros(u.shape, jnp.int32)
    hi = jnp.full(u.shape, width, jnp.int32)
    for _ in range(max(1, int(width).bit_length())):
        active = lo < hi
        mid = (lo + hi) // 2  # active lanes have mid ∈ [lo, hi) ⊂ [0, W)
        v_mid = jnp.take_along_axis(v, jnp.clip(mid, 0, width - 1), axis=1)
        go_right = active & (v_mid < u)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    pos = jnp.clip(lo, 0, width - 1)
    found = (jnp.take_along_axis(v, pos, axis=1) == u) & (lo < width)
    out_ref[...] = found.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_edges", "interpret"))
def intersect_counts_probe_pallas(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    tile_edges: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas binary-probe kernel: per-edge |N(u) ∩ N(v)| for (E, W) lists.

    Args:
      u_lists: (E, W) int32 sorted rows; E must be a multiple of
        ``tile_edges`` (callers pad with sentinel rows — see ops.py).
      v_lists: (E, W) int32 sorted rows, disjoint padding sentinel.
      tile_edges: rows per grid step (VMEM tile height).
      interpret: run the kernel body on CPU for validation; pass False on a
        real TPU.

    Returns:
      (E,) int32 per-edge intersection sizes.
    """
    e, w = u_lists.shape
    assert e % tile_edges == 0, (e, tile_edges)
    grid = (e // tile_edges,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, width=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_edges,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(u_lists, v_lists)
