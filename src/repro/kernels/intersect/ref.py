"""Pure-jnp oracle for batched sorted-neighbor-list intersection.

Given two padded neighbor-list batches ``u_lists`` and ``v_lists`` of shape
(E, W) — row e holding the sorted out-neighbor list of edge e's endpoints,
padded with a sentinel that appears in neither list — returns the per-edge
intersection sizes (E,) int32.

This is the semantic the paper's TwoSmall/TwoLarge GPU kernels compute; the
oracle is O(E·W²) broadcast-compare, trivially correct.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["intersect_counts_ref"]


def intersect_counts_ref(u_lists: jnp.ndarray, v_lists: jnp.ndarray) -> jnp.ndarray:
    """O(W^2) membership test. Padding must use sentinels that never collide
    (callers use n for u-padding and n+1 for v-padding)."""
    eq = u_lists[:, :, None] == v_lists[:, None, :]
    return eq.sum(axis=(1, 2)).astype(jnp.int32)
