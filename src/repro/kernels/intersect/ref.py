"""Reference implementations for batched sorted-neighbor-list intersection.

``intersect_counts_ref`` is THE semantic oracle (what ``backend="ref"``
dispatches to): O(E·W²) broadcast-compare, trivially correct, strategy-
independent. Every strategy core (broadcast / probe / bitmap) must agree with
it exactly on in-range ids — the tier-1 strategy sweep and the benchmark
``strat`` figure both assert against it.

``intersect_counts_probe_ref`` is an additional numpy cross-check for the
probe cores (per-row ``np.searchsorted``), sharing no code with the jnp or
Pallas implementations. The matching bitmap reference lives in bitmap.py
because it must also model the bitmap masking contract.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["intersect_counts_ref", "intersect_counts_probe_ref"]


def intersect_counts_ref(u_lists: jnp.ndarray, v_lists: jnp.ndarray) -> jnp.ndarray:
    """O(W²) broadcast-compare membership oracle.

    Args:
      u_lists: (E, W) int32; row e holds a sorted neighbor list padded with a
        sentinel that appears in neither list (the engine uses ``n``).
      v_lists: (E, W) int32, same layout, padded with a *different* sentinel
        (the engine uses ``n + 1``) so padding contributes zero matches.

    Returns:
      (E,) int32 — per-edge |N(u) ∩ N(v)| (pairwise-equality count; equal to
      the set-intersection size whenever rows are strictly increasing apart
      from the trailing padding run).
    """
    eq = u_lists[:, :, None] == v_lists[:, None, :]
    return eq.sum(axis=(1, 2)).astype(jnp.int32)


def intersect_counts_probe_ref(u_lists, v_lists) -> np.ndarray:
    """Numpy per-row binary-search reference for the probe cores (tests only).

    Args:
      u_lists / v_lists: (E, W) integer arrays, rows sorted ascending with
        disjoint padding sentinels.

    Returns:
      (E,) int32 numpy array — count of u elements found in the v row.
    """
    u = np.asarray(u_lists)
    v = np.asarray(v_lists)
    out = np.zeros(u.shape[0], dtype=np.int32)
    for e in range(u.shape[0]):
        pos = np.clip(np.searchsorted(v[e], u[e]), 0, v.shape[1] - 1)
        out[e] = int((v[e][pos] == u[e]).sum())
    return out
