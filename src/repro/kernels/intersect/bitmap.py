"""Bitmap set-intersection core (the ``bitmap`` strategy).

TRUST-style dense core (arXiv:2103.08053): pack each v-list into
``num_bits/32`` uint32 words — bit ``i`` set iff vertex ``i`` is a neighbor —
then test each u element by one word gather plus shift/AND. Packing is
O(W·num_bits/32) adds per row, membership testing O(W); when the id range
fits in ``num_bits ≈ W`` bits this is a ~32× reduction over the broadcast
core's O(W²) compares, which is why the engine's ``strategy="auto"`` cost
model picks it exactly when a bucket's id range fits the packed width.

Contract (shared by the jnp, Pallas, and numpy-ref implementations):

* rows sorted ascending; real values strictly increasing, then a run of one
  repeated padding sentinel (the layout ``csr_to_padded_neighbors`` emits).
  Strictness is what lets the packer turn bit-wise OR into a masked SUM
  (each kept value owns a distinct bit).
* values outside ``[0, num_bits)`` never match: negative row-padding
  sentinels and any overflow ids are masked out on both sides. Callers that
  need exact agreement with the broadcast/probe cores must therefore choose
  ``num_bits`` ≥ id range (the engine uses ``n + 2`` to cover both in-row
  sentinels ``n`` and ``n + 1``).

VMEM budget (pallas): 2 · TE·W·4B inputs + TE·(num_bits/8)B packed words;
TE=256, W=512, num_bits=512 adds only 16 KB of words.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "intersect_counts_bitmap",
    "intersect_counts_bitmap_pallas",
    "intersect_counts_bitmap_ref",
    "intersect_matches_bitmap",
]


def _pack_rows(v: jnp.ndarray, num_bits: int) -> jnp.ndarray:
    """Pack each sorted row of v into ``num_bits/32`` uint32 bitmap words."""
    assert num_bits % 32 == 0 and num_bits > 0, num_bits
    nwords = num_bits // 32
    # keep only the first occurrence of each value so the per-word SUM below
    # equals the bit-wise OR (rows are sorted ⇒ duplicates are adjacent, and
    # only the trailing padding run repeats)
    first = jnp.concatenate(
        [jnp.ones_like(v[:, :1], dtype=bool), v[:, 1:] != v[:, :-1]], axis=1
    )
    v_valid = first & (v >= 0) & (v < num_bits)
    v_word = jnp.where(v_valid, v // 32, 0)
    v_bit = jnp.where(v_valid, v % 32, 0).astype(jnp.uint32)
    contrib = jnp.where(
        v_valid, jnp.left_shift(jnp.uint32(1), v_bit), jnp.uint32(0)
    )
    words = []
    for k in range(nwords):  # static unroll; bounds memory at (E, W) per word
        sel = jnp.where(v_word == k, contrib, jnp.uint32(0))
        words.append(sel.sum(axis=1, dtype=jnp.uint32))
    return jnp.stack(words, axis=1)  # (E, nwords) uint32


def _probe_bits(packed: jnp.ndarray, u: jnp.ndarray,
                num_bits: int) -> jnp.ndarray:
    """(E, W) bool: gather each u element's word and test its bit."""
    u_valid = (u >= 0) & (u < num_bits)
    u_word = jnp.where(u_valid, u // 32, 0)
    u_bit = jnp.where(u_valid, u % 32, 0).astype(jnp.uint32)
    hit_words = jnp.take_along_axis(packed, u_word, axis=1)  # (E, W)
    hits = jnp.right_shift(hit_words, u_bit) & jnp.uint32(1)
    return (hits != 0) & u_valid


def _pack_and_probe(u: jnp.ndarray, v: jnp.ndarray, num_bits: int) -> jnp.ndarray:
    """Shared jnp body: pack v rows into uint32 words, probe u. (E,) int32."""
    return _probe_bits(_pack_rows(v, num_bits), u, num_bits) \
        .sum(axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_bits",))
def intersect_matches_bitmap(
    u_lists: jnp.ndarray, v_lists: jnp.ndarray, *, num_bits: int
) -> jnp.ndarray:
    """Bitmap membership MASK (jnp path): which u positions occur in v.

    The mask form of ``intersect_counts_bitmap`` — same packing, but the
    per-position hits are returned instead of row-summed, for the engine's
    vertex/edge analysis executables (which scatter each match to its
    triangle's vertices/edges).

    Args:
      u_lists: (E, W) int32 sorted rows (see module contract).
      v_lists: (E, W) int32 sorted rows, disjoint padding sentinel.
      num_bits: static packed-bitmap capacity, a positive multiple of 32.
        Values outside [0, num_bits) on either side never match.

    Returns:
      (E, W) bool — ``out[e, j]`` iff ``u_lists[e, j]`` is in
      ``v_lists[e]`` and within [0, num_bits).
    """
    return _probe_bits(_pack_rows(v_lists, num_bits), u_lists, num_bits)


@functools.partial(jax.jit, static_argnames=("num_bits",))
def intersect_counts_bitmap(
    u_lists: jnp.ndarray, v_lists: jnp.ndarray, *, num_bits: int
) -> jnp.ndarray:
    """Bitmap membership counts (jnp path).

    Args:
      u_lists: (E, W) int32 sorted rows (see module contract).
      v_lists: (E, W) int32 sorted rows, disjoint padding sentinel.
      num_bits: static packed-bitmap capacity, a positive multiple of 32.
        Values outside [0, num_bits) on either side are masked out.

    Returns:
      (E,) int32 — per-edge |N(u) ∩ N(v)| restricted to ids < num_bits.
    """
    return _pack_and_probe(u_lists, v_lists, num_bits)


def _bitmap_kernel(u_ref, v_ref, out_ref, *, num_bits: int):
    out_ref[...] = _pack_and_probe(u_ref[...], v_ref[...], num_bits)


@functools.partial(
    jax.jit, static_argnames=("num_bits", "tile_edges", "interpret")
)
def intersect_counts_bitmap_pallas(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    num_bits: int,
    tile_edges: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas bitmap kernel: per-edge |N(u) ∩ N(v)| for (E, W) lists.

    Args:
      u_lists: (E, W) int32 sorted rows; E must be a multiple of
        ``tile_edges`` (callers pad with sentinel rows — see ops.py).
      v_lists: (E, W) int32 sorted rows, disjoint padding sentinel.
      num_bits: static packed-bitmap capacity, a positive multiple of 32.
      tile_edges: rows per grid step (VMEM tile height).
      interpret: run the kernel body on CPU for validation; pass False on a
        real TPU.

    Returns:
      (E,) int32 per-edge intersection sizes restricted to ids < num_bits.
    """
    e, w = u_lists.shape
    assert e % tile_edges == 0, (e, tile_edges)
    grid = (e // tile_edges,)
    return pl.pallas_call(
        functools.partial(_bitmap_kernel, num_bits=num_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_edges,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(u_lists, v_lists)


def intersect_counts_bitmap_ref(
    u_lists, v_lists, *, num_bits: int
) -> np.ndarray:
    """Numpy reference for the bitmap masking contract (tests only).

    Implements the same semantics as the jnp/Pallas bitmap cores — ids
    outside [0, num_bits) are ignored, v treated as a set — via Python sets,
    sharing no code with them.

    Args:
      u_lists / v_lists: (E, W) integer arrays, any layout.
      num_bits: bitmap capacity; out-of-range ids never match.

    Returns:
      (E,) int32 numpy array of per-edge counts.
    """
    u = np.asarray(u_lists)
    v = np.asarray(v_lists)
    out = np.zeros(u.shape[0], dtype=np.int32)
    for e in range(u.shape[0]):
        members = {x for x in v[e].tolist() if 0 <= x < num_bits}
        out[e] = sum(1 for x in u[e].tolist() if 0 <= x < num_bits and x in members)
    return out
