"""Jitted wrappers around the batched intersection kernel.

Three execution paths, selected by ``backend``:

* ``"pallas"``   — the TPU kernel (interpret=True on CPU) in intersect.py.
* ``"jnp"``      — O(E·W·log W) vmapped binary probe (searchsorted); the
                   production CPU path and the GSPMD-shardable path.
* ``"ref"``      — O(E·W²) broadcast-compare oracle (ref.py).

The binary-probe path is also the TPU analogue of the paper's proposed third
kernel (scan the smaller list, search the larger): callers order (u, v) so the
probed list is the larger one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.intersect.intersect import intersect_counts_pallas
from repro.kernels.intersect.ref import intersect_counts_ref

__all__ = ["intersect_counts", "intersect_counts_probe"]


@jax.jit
def intersect_counts_probe(u_lists: jnp.ndarray, v_lists: jnp.ndarray) -> jnp.ndarray:
    """Binary-search each element of u in the sorted v list. O(W log W)."""

    def one(u, v):
        pos = jnp.searchsorted(v, u)
        pos = jnp.clip(pos, 0, v.shape[0] - 1)
        return (v[pos] == u).sum(dtype=jnp.int32)

    return jax.vmap(one)(u_lists, v_lists)


def intersect_counts(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    backend: str = "jnp",
    tile_edges: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Dispatch per-edge intersection counts. Shapes (E, W) -> (E,) int32."""
    if backend == "pallas":
        e = u_lists.shape[0]
        pad = (-e) % tile_edges
        if pad:
            # sentinel-pad rows: u rows all-(-1), v rows all-(-2) never match
            u_lists = jnp.concatenate(
                [u_lists, jnp.full((pad, u_lists.shape[1]), -1, u_lists.dtype)]
            )
            v_lists = jnp.concatenate(
                [v_lists, jnp.full((pad, v_lists.shape[1]), -2, v_lists.dtype)]
            )
        out = intersect_counts_pallas(
            u_lists, v_lists, tile_edges=tile_edges, interpret=interpret
        )
        return out[:e] if pad else out
    if backend == "jnp":
        return intersect_counts_probe(u_lists, v_lists)
    if backend == "ref":
        return intersect_counts_ref(u_lists, v_lists)
    raise ValueError(f"unknown backend {backend!r}")
