"""Strategy × backend dispatch for the batched set-intersection core.

The TC hot loop is one function — per-edge |N(u) ∩ N(v)| over padded (E, W)
sorted neighbor lists — with three interchangeable *strategies* (how the
intersection is computed) times three *backends* (where it runs):

  strategy    work/row      wins when
  ---------   -----------   ------------------------------------------------
  broadcast   O(W²)         narrow buckets: pure VPU compare, no gathers
  probe       O(W·log W)    wide skewed buckets: log W gather/select rounds
  bitmap      O(W·B/32)     the bucket's id range fits B ≈ W packed bits
                            (TRUST-style dense neighborhoods)

  backend
  -------
  pallas      the TPU kernels (interpret=True runs them on CPU)
  jnp         pure-jnp paths — the production CPU paths, GSPMD-shardable
  ref         the O(E·W²) broadcast-compare oracle (strategy-independent
              semantics; every strategy must agree with it on in-range ids)

``choose_strategy`` is the documented cost model behind ``strategy="auto"``:
bitmap when the id range fits the packed width (a ~32× compare reduction),
probe for wide buckets (W ≥ 64, past the measured O(W²)/O(W log W)
crossover), broadcast for narrow ones. ``resolve_strategy`` additionally
picks the bitmap capacity; the engine applies it per degree bucket and bakes
the result into the executable-cache key.

Sentinel-padding rules (repo-wide): within a row, u pads with one value and
v with a different one (the engine uses ``n`` and ``n + 1``); whole padding
*rows* added to reach a tile multiple use ``-1`` (u) and ``-2`` (v). Disjoint
sentinels mean padding contributes zero matches without masks — except in the
bitmap core, which masks ids outside [0, num_bits) explicitly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.intersect.bitmap import (
    intersect_counts_bitmap,
    intersect_counts_bitmap_pallas,
    intersect_matches_bitmap,
)
from repro.kernels.intersect.intersect import intersect_counts_pallas
from repro.kernels.intersect.probe import (
    intersect_counts_probe,
    intersect_counts_probe_pallas,
)
from repro.kernels.intersect.ref import intersect_counts_ref

__all__ = [
    "BITMAP_MAX_BITS",
    "STRATEGIES",
    "available_strategies",
    "intersect_counts",
    "intersect_counts_probe",
    "intersect_matches",
    "intersect_matches_both",
    "choose_strategy",
    "choose_mask_strategy",
    "resolve_mask_strategy",
    "resolve_strategy",
    "packed_bits",
]

STRATEGIES = ("broadcast", "probe", "bitmap")


def available_strategies() -> tuple:
    """The valid set-intersection strategy names, sorted (the discovery
    helper mirroring ``repro.graphs.available_datasets`` /
    ``repro.core.available_algorithms``). Every ``strategy=`` kwarg accepts
    these plus ``"auto"``, which resolves per bucket via the
    ``choose_strategy`` / ``choose_mask_strategy`` cost models."""
    return tuple(sorted(STRATEGIES))

# O(W²) broadcast vs O(W log W) probe crossover: below this width the
# gather-free broadcast compare wins on the VPU
_PROBE_MIN_WIDTH = 64

# hard cap on any bitmap's capacity: the packer statically unrolls
# num_bits/32 iterations (each touching an (E, W) temporary), so an
# unbounded forced bitmap on a large id range would blow up trace time
# long before producing a result — refuse instead
BITMAP_MAX_BITS = 1 << 16


def _ceil32(x: int) -> int:
    return max(32, ((int(x) + 31) // 32) * 32)


def packed_bits(width: int) -> int:
    """Bitmap capacity paired with a width-W bucket: W bits (min one word).

    The bitmap core packs v-lists into ``packed_bits(W)/32`` uint32 words, so
    a bucket qualifies for the auto cost model only when every vertex id the
    bucket can contain fits below this many bits.
    """
    return _ceil32(width)


def choose_strategy(width: int, id_range=None) -> str:
    """The ``strategy="auto"`` cost model. Pure function, documented contract.

    Args:
      width: the bucket's padded list width W (static).
      id_range: number of distinct ids the lists may contain (the engine
        passes ``n + 2`` to cover the in-row sentinels ``n`` and ``n + 1``);
        None when unknown (e.g. under tracing), which disqualifies bitmap.

    Returns:
      "bitmap" when ``id_range`` fits the packed width (membership tests
      collapse to shift/AND over W/32 words; the packed width must also stay
      under ``BITMAP_MAX_BITS``), else "probe" for wide buckets (W ≥ 64),
      else "broadcast" for narrow ones.
    """
    pw = packed_bits(width)
    if id_range is not None and int(id_range) <= pw and pw <= BITMAP_MAX_BITS:
        return "bitmap"
    if width >= _PROBE_MIN_WIDTH:
        return "probe"
    return "broadcast"


def resolve_strategy(width: int, id_range=None, strategy: str = "auto"):
    """Resolve ("auto" or explicit) strategy to (strategy, bitmap_bits).

    ``bitmap_bits`` is None except for the bitmap strategy, where it is
    ``packed_bits(width)`` when the id range fits (so same-shaped buckets from
    different graphs share one executable-cache entry) and the id range
    rounded up to a word multiple when bitmap is forced beyond it.

    Raises:
      ValueError: strategy="bitmap" forced with no ``id_range`` to size the
        bitmap, forced over an id range needing more than ``BITMAP_MAX_BITS``
        packed bits, or an unknown strategy name.
    """
    if strategy == "auto":
        strategy = choose_strategy(width, id_range)
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected 'auto' or one of {STRATEGIES}"
        )
    bits = None
    if strategy == "bitmap":
        if id_range is None:
            raise ValueError("strategy='bitmap' needs id_range to size the bitmap")
        pw = packed_bits(width)
        bits = pw if int(id_range) <= pw else _ceil32(id_range)
        if bits > BITMAP_MAX_BITS:
            raise ValueError(
                f"strategy='bitmap' would need a {bits}-bit bitmap for id "
                f"range {int(id_range)} (cap: BITMAP_MAX_BITS={BITMAP_MAX_BITS}); "
                f"use strategy='probe' (or 'auto') for this bucket"
            )
    return strategy, bits


def _probe_mask(u_lists, v_lists):
    """Probe-core membership mask: binary-search each u element in v."""

    def one(u, v):
        pos = jnp.clip(jnp.searchsorted(v, u), 0, v.shape[0] - 1)
        return v[pos] == u

    return jax.vmap(one)(u_lists, v_lists)


def _resolve_mask_args(u_lists, v_lists, strategy, bitmap_bits):
    """Shared strategy resolution for the mask entry points: "auto" uses
    the concrete id range when available (``choose_mask_strategy``), the
    width-only rule under tracing; forced bitmap sizes its capacity."""
    if strategy == "auto":
        strategy, bits = resolve_mask_strategy(
            u_lists.shape[1], _auto_id_range(u_lists, v_lists)
        )
        if strategy == "bitmap":
            bitmap_bits = bits
    elif strategy == "bitmap" and bitmap_bits is None:
        _, bitmap_bits = resolve_mask_strategy(
            u_lists.shape[1], _auto_id_range(u_lists, v_lists),
            strategy="bitmap",
        )
    elif strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected 'auto' or one of {STRATEGIES}"
        )
    return strategy, bitmap_bits


def intersect_matches(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    strategy: str = "auto",
    bitmap_bits=None,
) -> jnp.ndarray:
    """Per-position membership mask: which u-list entries appear in v.

    The mask form of ``intersect_counts`` — summing the result along the
    last axis gives exactly the per-edge intersection sizes — consumed by
    the engine's "vertex" and "edge" analysis executables, which need to
    know WHICH common neighbor matched so they can scatter the triangle to
    its three vertices / three edges. All three strategies apply: broadcast
    (eq-any over the (E, W, W) compare tensor), probe (searchsorted), and
    bitmap (pack v, gather-test each u element — the TRUST-style core,
    picked by "auto" exactly when the id range fits the packed width).

    Args:
      u_lists: (E, W) int32, each row a sorted neighbor list padded with a
        sentinel disjoint from v's.
      v_lists: (E, W) int32, same layout, disjoint padding sentinel.
      strategy: "auto" | "broadcast" | "probe" | "bitmap" — the same cost
        model as ``intersect_counts`` (``choose_strategy``).
      bitmap_bits: static bitmap capacity for strategy="bitmap"; must cover
        the id range for exact agreement with the other strategies.

    Returns:
      (E, W) bool — ``out[e, j]`` iff ``u_lists[e, j]`` occurs in
      ``v_lists[e]``. Padding positions are never True (disjoint sentinels).
    """
    strategy, bitmap_bits = _resolve_mask_args(u_lists, v_lists,
                                               strategy, bitmap_bits)
    if strategy == "broadcast":
        return (u_lists[:, :, None] == v_lists[:, None, :]).any(axis=2)
    if strategy == "bitmap":
        return intersect_matches_bitmap(u_lists, v_lists,
                                        num_bits=int(bitmap_bits))
    return _probe_mask(u_lists, v_lists)


def intersect_matches_both(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    strategy: str = "auto",
    bitmap_bits=None,
) -> tuple:
    """Both directions of ``intersect_matches`` in one call.

    Returns ``(matched_u, matched_v)`` — (E, W) bool masks of which u-list
    positions occur in v and which v-list positions occur in u. For every
    common element there is exactly one True in each mask (rows are
    deduplicated neighbor lists), so both masks row-sum to the same
    per-edge intersection sizes. The broadcast core shares one (E, W, W)
    eq tensor between the two reductions; probe and bitmap each run two
    passes with the roles swapped. The engine's "edge" executables consume
    both masks to group triangle contributions by u-row and v-row
    respectively.
    """
    strategy, bitmap_bits = _resolve_mask_args(u_lists, v_lists,
                                               strategy, bitmap_bits)
    if strategy == "broadcast":
        eq = u_lists[:, :, None] == v_lists[:, None, :]
        return eq.any(axis=2), eq.any(axis=1)
    if strategy == "bitmap":
        bits = int(bitmap_bits)
        return (intersect_matches_bitmap(u_lists, v_lists, num_bits=bits),
                intersect_matches_bitmap(v_lists, u_lists, num_bits=bits))
    return _probe_mask(u_lists, v_lists), _probe_mask(v_lists, u_lists)


def choose_mask_strategy(width: int, id_range=None) -> str:
    """The ``strategy="auto"`` cost model for MASK consumers
    (``intersect_matches`` / ``intersect_matches_both``).

    The mask entry points pay differently than the counting ones: probe
    masks run TWO vmapped searchsorted passes (one per direction) while the
    bitmap mask packs each side once and then does O(W) word gathers — so
    bitmap stays the winner well past the counting lane's
    ``id_range ≤ packed_bits(width)`` rule. Measured on the CPU jnp paths
    the crossover sits near B ≈ 4·W packed bits, which is the bound used
    here (capped by ``BITMAP_MAX_BITS`` as everywhere).

    Args:
      width: the bucket's padded list width W (static).
      id_range: number of distinct ids the lists may contain (the engine
        passes ``n + 2``); None (e.g. under tracing) disqualifies bitmap.

    Returns:
      "bitmap" | "probe" | "broadcast".
    """
    if id_range is not None:
        bits = _ceil32(id_range)
        if bits <= BITMAP_MAX_BITS and bits <= 4 * packed_bits(width):
            return "bitmap"
    if width >= _PROBE_MIN_WIDTH:
        return "probe"
    return "broadcast"


def resolve_mask_strategy(width: int, id_range=None, strategy: str = "auto"):
    """Resolve an ("auto" or explicit) MASK strategy to (strategy, bitmap_bits).

    The mask analogue of ``resolve_strategy``: "auto" applies
    ``choose_mask_strategy``; an explicit "bitmap" sizes its capacity from
    the id range (word-rounded), with the same ``BITMAP_MAX_BITS`` refusal.

    Raises:
      ValueError: bitmap forced with no ``id_range``, an id range past the
        packed-bits cap, or an unknown strategy name.
    """
    if strategy == "auto":
        strategy = choose_mask_strategy(width, id_range)
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected 'auto' or one of {STRATEGIES}"
        )
    bits = None
    if strategy == "bitmap":
        if id_range is None:
            raise ValueError("strategy='bitmap' needs id_range to size the bitmap")
        bits = _ceil32(id_range)
        if bits > BITMAP_MAX_BITS:
            raise ValueError(
                f"strategy='bitmap' would need a {bits}-bit bitmap for id "
                f"range {int(id_range)} (cap: BITMAP_MAX_BITS={BITMAP_MAX_BITS}); "
                f"use strategy='probe' (or 'auto') for this bucket"
            )
    return strategy, bits


def _pad_rows(u_lists, v_lists, tile_edges: int):
    """Sentinel-pad (E, W) pairs to an E that is a multiple of tile_edges.

    Padding rows use u=-1, v=-2: disjoint (and negative, so also masked by
    the bitmap core) ⇒ they contribute zero matches.
    """
    e = u_lists.shape[0]
    pad = (-e) % tile_edges
    if pad:
        u_lists = jnp.concatenate(
            [u_lists, jnp.full((pad, u_lists.shape[1]), -1, u_lists.dtype)]
        )
        v_lists = jnp.concatenate(
            [v_lists, jnp.full((pad, v_lists.shape[1]), -2, v_lists.dtype)]
        )
    return u_lists, v_lists, e, pad


# compare-matrix elements materialized per lax.map step of the jnp broadcast
# path — bounds memory at ~16M bools however large the bucket is
_BROADCAST_CHUNK_ELEMS = 1 << 24


@jax.jit
def _broadcast_jnp(u_lists, v_lists):
    """jnp broadcast-compare, chunked over rows to bound the (E, W, W)
    compare tensor (same algorithm as the pallas broadcast kernel)."""
    e, w = u_lists.shape
    chunk = int(max(1, min(max(e, 1), _BROADCAST_CHUNK_ELEMS // max(w * w, 1))))
    u_lists, v_lists, e, pad = _pad_rows(u_lists, v_lists, chunk)
    uc = u_lists.reshape(-1, chunk, w)
    vc = v_lists.reshape(-1, chunk, w)
    out = jax.lax.map(
        lambda ab: intersect_counts_ref(ab[0], ab[1]), (uc, vc)
    ).reshape(-1)
    return out[:e] if pad else out


def _auto_id_range(u_lists, v_lists):
    """Best-effort id range from concrete inputs; None under tracing.

    Rows are sorted ascending (the repo-wide contract every core relies on),
    so each row's max is its last column — an O(E) reduction, not O(E·W).
    """
    if isinstance(u_lists, jax.core.Tracer) or isinstance(v_lists, jax.core.Tracer):
        return None
    if u_lists.shape[0] == 0 or u_lists.shape[1] == 0:
        return 0
    hi = max(int(jnp.max(u_lists[:, -1])), int(jnp.max(v_lists[:, -1])), -1)
    return hi + 1


def intersect_counts(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    strategy: str = "auto",
    backend: str = "jnp",
    tile_edges: int = 256,
    interpret: bool = True,
    bitmap_bits=None,
) -> jnp.ndarray:
    """Dispatch per-edge intersection counts. Shapes (E, W) ×2 → (E,) int32.

    Args:
      u_lists: (E, W) int32; each row a sorted neighbor list, padded with a
        sentinel value disjoint from v's.
      v_lists: (E, W) int32, same layout, disjoint padding sentinel.
      strategy: "broadcast" | "probe" | "bitmap" | "auto". "auto" applies
        ``choose_strategy`` using the concrete id range when available
        (falling back to the width-only probe/broadcast rule under tracing).
      backend: "pallas" (TPU kernels), "jnp" (pure-jnp production path), or
        "ref" (the broadcast-compare oracle, strategy-independent).
      tile_edges: pallas grid tile height; E is sentinel-row-padded to a
        multiple of it and the padding stripped from the result.
      interpret: pallas interpret mode (True = run kernel bodies on CPU).
      bitmap_bits: static bitmap capacity for strategy="bitmap" only
        (multiple of 32); never consulted by the "auto" selector. Defaults
        to the concrete id range rounded up; required when tracing. Ids ≥
        bitmap_bits never match — callers wanting exact agreement with the
        other strategies must cover the full id range.

    Returns:
      (E,) int32 per-edge |N(u) ∩ N(v)|.
    """
    if backend not in ("pallas", "jnp", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "ref":
        return intersect_counts_ref(u_lists, v_lists)

    if strategy == "auto":
        # derive the id range from the data (the engine pre-resolves with the
        # graph's true id range instead); under tracing this is None and the
        # width-only probe/broadcast rule applies, so auto never selects a
        # bitmap whose capacity the data wasn't checked against
        strategy, bits = resolve_strategy(
            u_lists.shape[1], _auto_id_range(u_lists, v_lists)
        )
        if strategy == "bitmap":
            bitmap_bits = bits
    elif strategy == "bitmap" and bitmap_bits is None:
        _, bitmap_bits = resolve_strategy(
            u_lists.shape[1], _auto_id_range(u_lists, v_lists),
            strategy="bitmap",
        )
    elif strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected 'auto' or one of {STRATEGIES}"
        )

    if backend == "jnp":
        if strategy == "broadcast":
            return _broadcast_jnp(u_lists, v_lists)
        if strategy == "probe":
            return intersect_counts_probe(u_lists, v_lists)
        return intersect_counts_bitmap(u_lists, v_lists, num_bits=int(bitmap_bits))

    # backend == "pallas": tile the edge axis, strip padding on the way out
    u_lists, v_lists, e, pad = _pad_rows(u_lists, v_lists, tile_edges)
    if strategy == "broadcast":
        out = intersect_counts_pallas(
            u_lists, v_lists, tile_edges=tile_edges, interpret=interpret
        )
    elif strategy == "probe":
        out = intersect_counts_probe_pallas(
            u_lists, v_lists, tile_edges=tile_edges, interpret=interpret
        )
    else:
        out = intersect_counts_bitmap_pallas(
            u_lists, v_lists, num_bits=int(bitmap_bits),
            tile_edges=tile_edges, interpret=interpret,
        )
    return out[:e] if pad else out
