"""Broadcast-compare set-intersection core (the ``broadcast`` strategy).

Pallas TPU kernel for batched sorted-list intersection — the TC hot loop, and
the strategy the ``auto`` cost model keeps for narrow degree buckets where
the O(W²) compare is pure gather-free VPU work (see ops.py for the dispatch
and probe.py / bitmap.py for the other cores).

TPU adaptation of the paper's 2-kernel (TwoSmall/TwoLarge) strategy:

* Load balancing is static: callers bucket edges by max endpoint degree
  (``graphs.formats.bucket_edges_by_degree``), so every row in one launch has
  the same padded width W and every grid step does identical work — the MXU/VPU
  equivalent of the paper's "process intersections with same level of workload
  together".
* Each grid step loads a (TE, W) tile of u-lists and v-lists into VMEM and
  intersects by chunked broadcast-compare over the v-axis in VREG-friendly
  slabs of 128 lanes: for each 128-wide chunk of v, compare (TE, W, 1) ==
  (TE, 1, 128) and accumulate matches. Membership tests run at full VPU width
  with zero divergence — the role merge-path played on the GPU.
* Padding uses disjoint sentinels so no equality fires on padding; the kernel
  needs no masks.

VMEM budget: 2 · TE·W·4B (inputs) + TE·4B (out). With TE=256, W=512 that is
~1.1 MB — far under the ~16 MB/core budget, leaving headroom for double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["intersect_counts_pallas"]

_LANE = 128


def _intersect_kernel(u_ref, v_ref, out_ref, *, width: int):
    u = u_ref[...]  # (TE, W) int32
    v = v_ref[...]  # (TE, W) int32
    te = u.shape[0]
    acc = jnp.zeros((te,), dtype=jnp.int32)
    # chunk the v axis in 128-lane slabs; W is always a multiple of 8 and the
    # bucket widths are powers of two, so the last slab may be narrower.
    for start in range(0, width, _LANE):
        stop = min(start + _LANE, width)
        v_chunk = v[:, start:stop]  # (TE, C)
        eq = u[:, :, None] == v_chunk[:, None, :]  # (TE, W, C) bool
        acc = acc + eq.sum(axis=(1, 2)).astype(jnp.int32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_edges", "interpret"))
def intersect_counts_pallas(
    u_lists: jnp.ndarray,
    v_lists: jnp.ndarray,
    *,
    tile_edges: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas broadcast-compare kernel: per-edge |N(u) ∩ N(v)|.

    Args:
      u_lists: (E, W) int32; sorted rows padded with a sentinel disjoint from
        v's; E must be a multiple of ``tile_edges`` (callers pad with
        sentinel rows — see ops.py).
      v_lists: (E, W) int32, same layout, disjoint padding sentinel.
      tile_edges: rows per grid step (VMEM tile height).
      interpret: run the kernel body on CPU for validation; pass False on a
        real TPU.

    Returns:
      (E,) int32 per-edge intersection sizes.
    """
    e, w = u_lists.shape
    assert e % tile_edges == 0, (e, tile_edges)
    grid = (e // tile_edges,)
    return pl.pallas_call(
        functools.partial(_intersect_kernel, width=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
            pl.BlockSpec((tile_edges, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_edges,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(u_lists, v_lists)
