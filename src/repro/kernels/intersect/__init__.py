from repro.kernels.intersect.ops import (
    BITMAP_MAX_BITS,
    STRATEGIES,
    available_strategies,
    choose_strategy,
    intersect_counts,
    intersect_counts_probe,
    intersect_matches,
    intersect_matches_both,
    packed_bits,
    resolve_strategy,
)
from repro.kernels.intersect.ref import (
    intersect_counts_probe_ref,
    intersect_counts_ref,
)
from repro.kernels.intersect.intersect import intersect_counts_pallas
from repro.kernels.intersect.probe import intersect_counts_probe_pallas
from repro.kernels.intersect.bitmap import (
    intersect_counts_bitmap,
    intersect_counts_bitmap_pallas,
    intersect_counts_bitmap_ref,
    intersect_matches_bitmap,
)

__all__ = [
    "BITMAP_MAX_BITS",
    "STRATEGIES",
    "available_strategies",
    "choose_strategy",
    "resolve_strategy",
    "packed_bits",
    "intersect_counts",
    "intersect_counts_probe",
    "intersect_matches",
    "intersect_matches_both",
    "intersect_matches_bitmap",
    "intersect_counts_probe_pallas",
    "intersect_counts_probe_ref",
    "intersect_counts_bitmap",
    "intersect_counts_bitmap_pallas",
    "intersect_counts_bitmap_ref",
    "intersect_counts_ref",
    "intersect_counts_pallas",
]
