from repro.kernels.intersect.ops import intersect_counts, intersect_counts_probe
from repro.kernels.intersect.ref import intersect_counts_ref
from repro.kernels.intersect.intersect import intersect_counts_pallas

__all__ = [
    "intersect_counts",
    "intersect_counts_probe",
    "intersect_counts_ref",
    "intersect_counts_pallas",
]
