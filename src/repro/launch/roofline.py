"""Roofline-term extraction from compiled (AOT) artifacts.

Per (arch, shape, mesh) the dry-run produces a lowered+compiled executable;
this module derives the three roofline terms against TPU v5e constants:

  compute    = HLO_FLOPs_per_chip    / PEAK_FLOPS        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip    / HBM_BW            (819 GB/s)
  collective = collective_bytes_per_chip / ICI_BW        (50 GB/s/link)

``compiled.cost_analysis()`` reports the per-device (post-SPMD-partitioning)
module, so its flops/bytes are already per-chip. Collective bytes are NOT in
cost_analysis: we parse the optimized HLO and sum, per collective op, the
bytes that cross the wire per chip with ring-algorithm factors:

  all-reduce        2·(N−1)/N · size   (reduce-scatter + all-gather phases)
  all-gather        (N−1)/N · output
  reduce-scatter    (N−1)/N · input
  all-to-all        (N−1)/N · size
  collective-permute  1 · size

N (participants) is parsed from replica_groups when present; N→large makes
the factor ≈1, so unparsed groups default to factor 1 (2 for all-reduce).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineResult"]

# TPU v5e (per chip)
HW = dict(
    peak_flops=197e12,  # bf16
    hbm_bw=819e9,  # bytes/s
    ici_bw=50e9,  # bytes/s/link
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _participants(line: str) -> Optional[int]:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return None


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, parsed from optimized HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        n = _participants(line)
        frac = (n - 1) / n if n and n > 1 else 1.0
        if n is not None and n <= 1:
            continue  # degenerate single-participant op moves nothing
        factor = {"all-reduce": 2.0 * frac,
                  "all-gather": frac,
                  "reduce-scatter": frac,
                  "all-to-all": frac,
                  "collective-permute": 1.0}[kind]
        out[kind] = out.get(kind, 0.0) + size * factor
    return out


@dataclasses.dataclass
class RooflineResult:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    useful_ratio: float

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(cost: dict, hlo_text: str, *, model_flops_per_chip: float
                   ) -> RooflineResult:
    """Loop-aware terms via launch.hlo_cost (xla cost_analysis counts while
    bodies once — unusable for scan-stacked models; we keep its numbers only
    as a cross-check in the record)."""
    from repro.launch.hlo_cost import analyze_hlo

    hc = analyze_hlo(hlo_text)
    flops = hc.flops or float(cost.get("flops", 0.0))
    hbm = hc.bytes or float(cost.get("bytes accessed", 0.0))
    coll = {k: float(v) for k, v in hc.coll_by_kind.items()}
    coll_total = sum(coll.values())
    t_c = flops / HW["peak_flops"]
    t_m = hbm / HW["hbm_bw"]
    t_n = coll_total / HW["ici_bw"]
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                   key=lambda kv: kv[1])[0]
    return RooflineResult(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_total, coll_by_kind=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_n, dominant=dominant,
        model_flops=model_flops_per_chip,
        useful_ratio=(model_flops_per_chip / flops) if flops else 0.0,
    )
