"""Production training driver: mesh-aware, sharded, auto-resuming.

On real hardware this is the per-host entrypoint (jax.distributed handles
multi-host init); on CPU it runs the same code path on whatever devices
exist. The dry-run (dryrun.py) proves the 256/512-chip lowering of exactly
the step built here.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 50 \
      --reduced --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.meshctx import activation_mesh
from repro.models.registry import get_config, get_model, get_reduced_config
from repro.train.checkpoint import latest_step
from repro.train.data import SyntheticDataConfig, SyntheticDataset
from repro.train.elastic import ElasticTrainer, Heartbeat
from repro.train.optimizer import AdamWConfig, OptState, adamw_init
from repro.train.sharding import batch_sharding, param_shardings
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (requires 256 devices)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--save-every", type=int, default=25)
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = get_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(args.model_parallel))
    opt_cfg = AdamWConfig(
        peak_lr=3e-4, warmup_steps=max(args.steps // 10, 1),
        stable_steps=args.steps, decay_steps=max(args.steps // 10, 1),
        moment_dtype=jnp.bfloat16 if cfg.adam_dtype == "bfloat16"
        else jnp.float32)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    trainer = ElasticTrainer(
        ckpt_dir=f"{args.ckpt_dir}_{cfg.name}", save_every=args.save_every,
        heartbeat=Heartbeat(f"{args.ckpt_dir}_{cfg.name}.hb"))

    def fresh():
        params = model.init(jax.random.key(0), dtype=jnp.float32)
        return {"params": params, "opt": adamw_init(params, opt_cfg)}

    with activation_mesh(mesh):
        state, start = trainer.resume_or_init(fresh)
        p_shard = param_shardings(state["params"], mesh, fsdp=cfg.fsdp)
        state["params"] = jax.device_put(state["params"], p_shard)
        step_fn = jax.jit(
            make_train_step(model, cfg, opt_cfg,
                            microbatches=min(cfg.microbatches, args.batch)),
            in_shardings=(p_shard, None, None),
            donate_argnums=(0, 1))
        ds = SyntheticDataset(cfg, SyntheticDataConfig(args.batch,
                                                       args.seq + 1), start)
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {k: jax.device_put(v, batch_sharding(mesh, v.ndim))
                     for k, v in next(ds).items()}
            p, o, m = step_fn(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o}
            trainer.maybe_save(step, state)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                      f"gnorm {float(m['grad_norm']):.3f}  "
                      f"{time.time()-t0:6.1f}s", flush=True)
        trainer.maybe_save(args.steps - 1, state, force=True)


if __name__ == "__main__":
    main()
