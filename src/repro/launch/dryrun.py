"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers, compiles,
shards coherently, and fits memory — without hardware.

MUST set the placeholder-device flag before any other import (jax locks the
device count on first init). Only this entrypoint sees 512 devices; tests and
benches see 1.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --tc        # paper-core cell
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    SHAPES, abstract_params, cell_spec, input_specs, skip_reason,
)
from repro.launch.roofline import roofline_terms
from repro.models.meshctx import activation_mesh
from repro.models.registry import ARCHS, get_config, get_model
from repro.train.optimizer import AdamWConfig, OptState, adamw_init
from repro.train.sharding import (
    batch_sharding, cache_specs, data_axis, param_shardings,
)
from repro.train.train_step import make_train_step


def _cost_dict(compiled):
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    return dict(c) if c else {}


def _memory_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    return {k: int(getattr(m, k)) for k in keys if hasattr(m, k)}


def _model_flops_per_chip(cfg, cell, chips: int) -> float:
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens / chips
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch / chips


def lower_cell(arch: str, shape: str, mesh) -> dict:
    """Lower + compile one cell; returns the §Dry-run/§Roofline record."""
    cfg = get_config(arch)
    cell = cell_spec(arch, shape)
    chips = mesh.devices.size
    rec = dict(arch=arch, shape=shape,
               mesh="x".join(map(str, mesh.devices.shape)),
               kind=cell.kind, chips=chips)
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec

    model = get_model(cfg)
    with activation_mesh(mesh):
        return _lower_cell_inner(arch, shape, mesh, cfg, cell, chips, rec,
                                 model)


def _lower_cell_inner(arch, shape, mesh, cfg, cell, chips, rec, model):
    params_abs = abstract_params(arch)
    p_shard = param_shardings(params_abs, mesh, fsdp=cfg.fsdp)
    dax = data_axis(mesh)
    t0 = time.time()

    if cell.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype=jnp.bfloat16 if cfg.adam_dtype == "bfloat16"
            else jnp.float32)
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, cfg=opt_cfg), params_abs)
        opt_shard = OptState(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: s, p_shard),
            nu=jax.tree.map(lambda s: s, p_shard),
        )
        batch_abs = input_specs(arch, shape)
        b_shard = {k: batch_sharding(mesh, v) for k, v in batch_abs.items()}
        step = make_train_step(model, cfg, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif cell.kind == "prefill":
        batch_abs = input_specs(arch, shape)
        b_shard = {k: batch_sharding(mesh, v) for k, v in batch_abs.items()}
        # VLM caches cover vision prefix + text
        max_len = cell.seq_len + (cfg.vision_tokens if cfg.family == "vlm"
                                  else 0)
        fn = lambda params, batch: model.prefill(params, batch, max_len)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        specs = input_specs(arch, shape)
        cache_abs, tok_abs = specs["cache"], specs["tokens"]
        c_shard = cache_specs(cache_abs, mesh, cell.global_batch)
        t_shard = batch_sharding(mesh, tok_abs)
        jitted = jax.jit(model.decode_step,
                         in_shardings=(p_shard, c_shard, t_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_abs, cache_abs, tok_abs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = _cost_dict(compiled)
    mem = _memory_dict(compiled)
    hlo = compiled.as_text()
    rl = roofline_terms(
        cost, hlo, model_flops_per_chip=_model_flops_per_chip(cfg, cell, chips))
    rec.update(
        status="ok", lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem, roofline=rl.as_dict(),
        params_b=cfg.param_count(), active_params_b=cfg.active_param_count(),
    )
    return rec


def lower_tc(mesh, *, tiles: int = 8192, block: int = 128) -> dict:
    """Dry-run the paper core: the planned ``"matrix_distributed"`` lane on
    the production mesh — the SAME cached per-shard executable
    ``plan_triangle_count(g, "matrix_distributed", mesh=mesh)`` binds, here
    lowered against ShapeDtypeStructs (a synthetic dealt tile schedule, no
    graph), so the structural check covers exactly what production runs:
    the length-gated tile loop and the single scalar psum."""
    from repro.core import engine

    chips = mesh.devices.size
    axes = tuple(mesh.axis_names)
    t_per = -(-tiles // chips)
    sh = NamedSharding(mesh, P(axes))
    abs_tiles = jax.ShapeDtypeStruct((chips, t_per, block, block),
                                     jnp.float32, sharding=sh)
    abs_valid = jax.ShapeDtypeStruct((chips,), jnp.int32, sharding=sh)

    fn = engine.get_executable("matrix_distributed", "jnp", False,
                               (t_per, block, block), mesh=mesh)
    t0 = time.time()
    lowered = fn.lower(abs_tiles, abs_tiles, abs_tiles, abs_valid)
    compiled = lowered.compile()
    dt = time.time() - t0
    cost = _cost_dict(compiled)
    rl = roofline_terms(cost, compiled.as_text(),
                        model_flops_per_chip=2 * t_per * block**3)
    return dict(arch="tc-masked-spgemm", shape=f"tiles{tiles}",
                mesh="x".join(map(str, mesh.devices.shape)), chips=chips,
                status="ok", compile_s=round(dt, 2),
                memory=_memory_dict(compiled), roofline=rl.as_dict())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tc", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    cells = []
    if args.tc:
        cells = [("tc", None)]
    elif args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch, "--arch, --all, or --tc required"
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(args.arch, s) for s in shapes]

    failures = 0
    for arch, shape in cells:
        for mesh in meshes:
            try:
                if arch == "tc":
                    rec = lower_tc(mesh)
                else:
                    rec = lower_cell(arch, shape, mesh)
            except Exception as e:  # a dry-run failure is a bug: report it
                failures += 1
                rec = dict(arch=arch, shape=shape,
                           mesh="x".join(map(str, mesh.devices.shape)),
                           status="error", error=repr(e),
                           trace=traceback.format_exc()[-2000:])
            line = json.dumps(rec)
            print(line, flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(line + "\n")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
