"""Device meshes: production topologies and local test meshes.

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module never touches jax device state — dryrun.py must set XLA_FLAGS
before first jax init, and tests must keep seeing 1 device.

Production target: TPU v5e pods, 256 chips/pod.
  single-pod:  (16, 16)    axes ("data", "model")
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model")

"pod" composes with "data" for hierarchical gradient reduction
(reduce-scatter intra-pod over ICI, all-reduce across pods over DCI); "model"
carries TP/EP collectives and is kept inside a pod where ICI is fastest.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import Mesh

__all__ = ["make_mesh", "make_production_mesh", "make_local_mesh", "mesh_axes"]


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (forward-compatible);
    older jax has no AxisType and defaults every axis to Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(
        tuple(shape),
        tuple(axes),
        axis_types=(axis_type.Auto,) * len(axes),
    )


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Whatever devices exist, split (data, model). Used by tests/examples."""
    ndev = jax.device_count()
    assert ndev % model_parallel == 0, (ndev, model_parallel)
    return make_mesh((ndev // model_parallel, model_parallel), ("data", "model"))


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension: ("pod","data") when pod exists."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
