"""ShapeDtypeStruct stand-ins for every (architecture × input shape) cell.

``input_specs(arch, shape)`` returns the abstract inputs the dry-run lowers
against — weak-type-correct, shardable, zero allocation. The assigned shape
set (LM transformers):

  train_4k     seq 4096,   global_batch 256   → train_step
  prefill_32k  seq 32768,  global_batch 32    → prefill
  decode_32k   cache 32768, global_batch 128  → decode_step (1 new token)
  long_500k    cache 524288, global_batch 1   → decode_step, sub-quadratic
                archs only (ssm / hybrid); others report a documented skip.

Modality stubs per the brief: whisper gets precomputed frame embeddings,
paligemma gets precomputed patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import get_config, get_model

__all__ = ["SHAPES", "CellSpec", "cell_spec", "input_specs", "skip_reason"]

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch: str
    shape: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def cell_spec(arch: str, shape: str) -> CellSpec:
    return CellSpec(arch=arch, shape=shape, **SHAPES[shape])


def skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    if shape == "long_500k" and not cfg.supports_long_context:
        return ("quadratic global attention at 524288 ctx — skipped per "
                "brief (run for SSM/hybrid only)")
    return None


def input_specs(arch: str, shape: str) -> Dict[str, S]:
    """Abstract batch for the step function the cell lowers."""
    cfg = get_config(arch)
    cell = cell_spec(arch, shape)
    b, sl = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        batch = {
            "tokens": S((b, sl), jnp.int32),
            "labels": S((b, sl), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = S((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = S((b, cfg.vision_tokens, cfg.vision_dim),
                                 jnp.float32)
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": S((b, sl), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = S((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = S((b, cfg.vision_tokens, cfg.vision_dim),
                                 jnp.float32)
        return batch
    # decode: one new token + abstract cache
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, sl, jnp.bfloat16))
    return {"tokens": S((b, 1), jnp.int32), "cache": cache}


def abstract_params(arch: str, dtype=jnp.bfloat16):
    cfg = get_config(arch)
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init(jax.random.key(0), dtype=dtype))
