"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from dryrun.jsonl.

    PYTHONPATH=src python -m repro.launch.report dryrun.jsonl
"""

from __future__ import annotations

import json
import sys
from collections import OrderedDict


def _fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/1e9:.2f}"


def load(path: str):
    recs = OrderedDict()
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r.get("shape"), r["mesh"])] = r  # last write wins
    return list(recs.values())


def roofline_table(recs, mesh="16x16"):
    rows = []
    header = ("| arch | shape | status | t_compute (s) | t_memory (s) | "
              "t_collective (s) | dominant | MODEL/HLO flops | temp GB/chip |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skip | — | — | — | — "
                        f"| — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rl['t_compute']:.3f} | {rl['t_memory']:.3f} "
            f"| {rl['t_collective']:.3f} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.2f} "
            f"| {_fmt_bytes(mem.get('temp_size_in_bytes'))} |")
    return "\n".join(rows)


def summary(recs):
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skipped" for r in recs)
    err = sum(r["status"] not in ("ok", "skipped") for r in recs)
    return f"{ok} ok / {skip} documented skips / {err} errors"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun.jsonl"
    recs = load(path)
    print("## Dry-run summary:", summary(recs))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh}\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
