"""Loop-aware HLO cost analysis (flops / HBM-traffic / collective bytes).

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
but scan-stacked layers, microbatch accumulation, and chunked attention all
live inside while loops — a 26-layer model would be undercounted ~26×. XLA
records ``backend_config={"known_trip_count":{"n":...}}`` on while ops, so we
walk the HLO text and multiply.

Model per op (per-device, post-SPMD shapes):
  dot            flops += 2 · |out| · |contracting|;  bytes += in + out
  fusion         bytes += operands + output (internal traffic elided — the
                 fusion boundary IS the HBM boundary); flops += dots inside
  while          (body + cond) × known_trip_count
  call/cond      cost of callee (branches: max)
  collectives    wire bytes with ring factors (see below) — also trip-scaled
  other real ops bytes += operands + output, flops += |out|
  parameter/constant/tuple/get-tuple-element/bitcast  free

Ring factors per chip: all-reduce 2(N−1)/N, all-gather & reduce-scatter &
all-to-all (N−1)/N, collective-permute 1. N parsed from replica_groups.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "rng-bit-generator",
}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "  %name = TYPE op(...)" or "  ROOT %name = TYPE op(...)"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d.strip()]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _elems_of(type_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Op:
    name: str
    out_type: str
    opcode: str
    rest: str  # operand list + attrs (rest of line)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * scale


def _parse_computations(text: str) -> Tuple[Dict[str, List[_Op]], Optional[str]]:
    comps: Dict[str, List[_Op]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3),
                                  m.group(4)))
    return comps, entry


def _participants(rest: str) -> Optional[int]:
    m = _GROUPS_V2_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return None


def _split_operands(rest: str) -> str:
    """The operand segment = up to the matching close paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


def _fusion_effective_bytes(callee: str, comps: Dict[str, List[_Op]],
                            operand_names: List[str], symtab: Dict[str, str]):
    """Slice-aware fusion traffic.

    Input side: a fusion parameter consumed ONLY by dynamic-slice/gather ops
    reads just the slices (the layer-weight gather from a scan-stacked array
    would otherwise count the whole stack every iteration). Output side: a
    ROOT dynamic-update-slice writes only the update region (a decode step
    would otherwise count the whole KV cache as written per token).
    Returns (in_bytes|None, out_bytes|None) — None = no adjustment.
    """
    ops = comps.get(callee)
    if not ops:
        return None, None
    csym = {op.name: op.out_type for op in ops}
    # map parameter number -> op name
    param_of = {}
    for op in ops:
        if op.opcode == "parameter":
            mnum = re.match(r"\s*(\d+)", op.rest)
            if mnum:
                param_of[int(mnum.group(1))] = op.name
    in_bytes = 0.0
    for i, oname in enumerate(operand_names):
        full = _bytes_of(symtab.get(oname, ""))
        pname = param_of.get(i)
        if pname is None:
            in_bytes += full
            continue
        consumers = [op for op in ops
                     if pname in _OPERAND_RE.findall(_split_operands(op.rest))]
        if consumers and all(c.opcode in ("dynamic-slice", "gather")
                             for c in consumers):
            in_bytes += sum(_bytes_of(c.out_type) for c in consumers)
        else:
            in_bytes += full
    out_bytes = None
    root = ops[-1]
    if root.opcode == "dynamic-update-slice":
        onames = _OPERAND_RE.findall(_split_operands(root.rest))
        if len(onames) > 1:
            out_bytes = 2.0 * _bytes_of(csym.get(onames[1], ""))
    return in_bytes, out_bytes


def _comp_cost(name: str, comps: Dict[str, List[_Op]],
               memo: Dict[str, HloCost]) -> HloCost:
    if name in memo:
        return memo[name]
    memo[name] = HloCost()  # break cycles defensively
    total = HloCost()
    symtab = {op.name: op.out_type for op in comps.get(name, [])}
    for op in comps.get(name, []):
        oc = op.opcode
        operand_str = _split_operands(op.rest)
        operand_names = _OPERAND_RE.findall(operand_str)
        operand_bytes = sum(_bytes_of(symtab.get(o, "")) for o in operand_names)
        out_bytes = _bytes_of(op.out_type)

        if oc == "while":
            body = _BODY_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            trip = _TRIP_RE.search(op.rest)
            n = int(trip.group(1)) if trip else 1
            sub = HloCost()
            if body:
                sub.add(_comp_cost(body.group(1), comps, memo))
            if cond:
                sub.add(_comp_cost(cond.group(1), comps, memo))
            total.add(sub, scale=n)
            continue
        if oc == "conditional":
            m = _BRANCH_RE.search(op.rest)
            if m:
                branches = [_comp_cost(b.strip().lstrip("%"), comps, memo)
                            for b in m.group(1).split(",") if b.strip()]
                if branches:
                    best = max(branches, key=lambda c: c.flops + c.bytes)
                    total.add(best)
            total.bytes += operand_bytes + out_bytes
            continue
        if oc in ("call", "fusion", "async-start"):
            m = _CALLS_RE.search(op.rest)
            eff_in, eff_out = operand_bytes, out_bytes
            if m:
                callee = m.group(1)
                sub = _comp_cost(callee, comps, memo)
                total.flops += sub.flops  # dots inside fusions still count
                total.coll_bytes += sub.coll_bytes
                for k, v in sub.coll_by_kind.items():
                    total.coll_by_kind[k] = total.coll_by_kind.get(k, 0) + v
                ein, eout = _fusion_effective_bytes(
                    callee, comps, operand_names, symtab)
                if ein is not None:
                    eff_in = ein
                if eout is not None:
                    eff_out = eout
            total.bytes += eff_in + eff_out
            continue
        if oc in ("dynamic-slice", "gather", "slice"):
            total.bytes += 2 * out_bytes  # reads |slice|, writes |slice|
            continue
        if oc in ("dynamic-update-slice", "scatter"):
            upd = (_bytes_of(symtab.get(operand_names[1], ""))
                   if len(operand_names) > 1 else out_bytes)
            total.bytes += 2 * upd  # in-place: touches only the update region
            continue
        if oc in _FREE_OPS:
            continue
        if oc == "dot":
            cd = _LHS_CDIMS_RE.search(op.rest)
            k_elems = 1
            if cd and operand_names:
                lhs_type = symtab.get(operand_names[0], "")
                dims_list = _shape_dims(lhs_type)
                if dims_list:
                    lhs_dims = dims_list[0][1]
                    for idx in cd.group(1).split(","):
                        if idx.strip():
                            i = int(idx)
                            if i < len(lhs_dims):
                                k_elems *= lhs_dims[i]
            total.flops += 2.0 * _elems_of(op.out_type) * k_elems
            total.bytes += operand_bytes + out_bytes
            continue
        if oc == "convolution":
            # rough: 2 * out_elems * (in_channels * window) — parse window
            total.flops += 2.0 * _elems_of(op.out_type)
            total.bytes += operand_bytes + out_bytes
            continue
        base = oc.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if oc.endswith("-done"):
                continue  # counted at -start
            n = _participants(op.rest)
            frac = (n - 1) / n if n and n > 1 else 1.0
            if n is not None and n <= 1:
                continue
            size = max(operand_bytes, out_bytes)
            factor = {"all-reduce": 2.0 * frac, "all-gather": frac,
                      "reduce-scatter": frac, "all-to-all": frac,
                      "collective-permute": 1.0}[base]
            wire = size * factor
            total.coll_bytes += wire
            total.coll_by_kind[base] = total.coll_by_kind.get(base, 0) + wire
            total.bytes += operand_bytes + out_bytes
            continue
        # generic real op: elementwise-ish
        total.flops += _elems_of(op.out_type)
        total.bytes += operand_bytes + out_bytes
    memo[name] = total
    return total


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloCost()
    # fusions/bodies are reachable from entry; memoized walk handles sharing
    return _comp_cost(entry, comps, {})
