"""``TriangleService`` — the concurrent front end over the counting engine.

One dispatcher thread drains a bounded admission queue (``queueing.py``);
coalescible count requests — same resolved ``CountOptions.key()``, which
folds in the ``ShapePolicy`` layout class — are grouped within a batching
window and counted by single vmapped dispatches (``coalescer.py``); every
other kind (per-vertex analysis, edge support, k-truss, dynamic-session
updates) executes singly through a bounded session cache keyed by
``CounterSession.session_key()``. Every request resolves exactly one way:
a ``ServeResult`` on its future, the request's own exception, or a typed
``RequestShed`` (queue full / deadline expired / shutdown) — the service
never queues unboundedly and never hangs a caller.

    from repro.serve import ServeConfig, TriangleService

    with TriangleService(algorithm="intersection") as svc:
        svc.warmup([g1, g2])                    # optional: fix the layout
        futs = [svc.submit("count", g, tenant="a") for g in graphs]
        results = [f.result() for f in futs]    # ServeResult each
        svc.snapshot()                          # metrics + cache counters

All compilation state is process-wide (the engine's bounded LRU), so a
service restart — or a second service — inherits every warm executable.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import registry
from repro.core.api import (
    DynamicTriangleCounter,
    TriangleCounter,
    graph_fingerprint,
)
from repro.core.engine import _BoundedLRU
from repro.core.options import CountOptions
from repro.serve.coalescer import Coalescer, _pow2_chunks
from repro.serve.metrics import MetricsRegistry
from repro.serve.queueing import (
    SHED_DEADLINE,
    SHED_SHUTDOWN,
    AdmissionQueue,
    QueuedRequest,
    RequestShed,
)

__all__ = ["KINDS", "ServeConfig", "ServeResult", "TriangleService"]

KINDS = ("count", "vertex", "edge_support", "k_truss", "update")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The service's admission / batching / cache knobs.

    Attributes:
      max_queue_depth: admission bound — request ``max_queue_depth + 1``
        is shed with ``"queue-full"`` instead of buffered.
      batch_window_ms: how long the dispatcher holds a coalescible head
        request open for compatible arrivals (0 disables waiting; already
        queued compatible requests still coalesce).
      max_batch: the largest group one window may collect (chunks dispatch
        as powers of two, so 8 means batch executables for 2/4/8).
      default_deadline_ms: deadline applied to requests that do not carry
        their own (None = no deadline). Expired requests are shed with
        ``"deadline"`` at admission or at dispatch, never executed late.
      plan_cache_size: bound of the coalescer's prepped-plan LRU
        (fingerprint + prep options -> device buckets).
      session_cache_size: bound of the single-execution session LRU
        (``session_key()`` -> ``TriangleCounter``); 0 disables session
        reuse (a fresh session per request).
    """

    max_queue_depth: int = 64
    batch_window_ms: float = 2.0
    max_batch: int = 8
    default_deadline_ms: Optional[float] = None
    plan_cache_size: int = 128
    session_cache_size: int = 32

    def __post_init__(self):
        if self.max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, "
                             f"got {self.max_queue_depth}")
        if self.batch_window_ms < 0:
            raise ValueError(f"batch_window_ms must be >= 0, "
                             f"got {self.batch_window_ms}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.default_deadline_ms is not None \
                and self.default_deadline_ms <= 0:
            raise ValueError(f"default_deadline_ms must be positive or None, "
                             f"got {self.default_deadline_ms}")
        if self.plan_cache_size < 1:
            raise ValueError(f"plan_cache_size must be >= 1, "
                             f"got {self.plan_cache_size}")
        if self.session_cache_size < 0:
            raise ValueError(f"session_cache_size must be >= 0, "
                             f"got {self.session_cache_size}")


@dataclasses.dataclass
class ServeResult:
    """What a served request resolves to.

    ``count`` is the exact triangle count for "count" and "update" kinds
    (None otherwise); ``value`` carries the analysis payload (per-vertex
    array, (src, dst, support) triple, or the k-truss ``Graph``).
    ``batch_size`` is the size of the device dispatch that served this
    request (1 = single pass-through), ``batch_id`` groups requests that
    shared a window. ``exec_s`` is the whole dispatch's execution time —
    shared, not per-request, for coalesced members.
    """

    request_id: int
    kind: str
    tenant: str
    count: Optional[int]
    value: Any
    algorithm: str
    batch_id: int
    batch_size: int
    queue_wait_s: float
    exec_s: float
    total_s: float

    def __int__(self) -> int:
        if self.count is None:
            raise TypeError(f"{self.kind!r} results carry no count")
        return self.count


class TriangleService:
    """The concurrent, coalescing, load-shedding triangle-counting front
    end. See the module docstring for the lifecycle; constructor options
    mirror ``CounterSession`` (an optional ``CountOptions`` plus field
    overrides) with a ``config=ServeConfig(...)`` for the serving knobs."""

    def __init__(self, options: Optional[CountOptions] = None, *,
                 config: Optional[ServeConfig] = None, **overrides):
        if options is None:
            options = CountOptions(**overrides)
        elif overrides:
            options = options.replace(**overrides)
        if not isinstance(options, CountOptions):
            raise TypeError(f"options must be a CountOptions, "
                            f"got {type(options).__name__}")
        self.options = options
        self.config = config or ServeConfig()
        self.metrics = MetricsRegistry()
        self._queue = AdmissionQueue(self.config.max_queue_depth)
        self._coalescer = Coalescer(self.config.plan_cache_size)
        self._sessions: Optional[_BoundedLRU] = (
            _BoundedLRU(self.config.session_cache_size)
            if self.config.session_cache_size else None
        )
        self._dyn: Dict[str, DynamicTriangleCounter] = {}
        self._dyn_lock = threading.Lock()
        self._req_seq = itertools.count()
        self._batch_seq = itertools.count()
        self._dyn_seq = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._stopping = threading.Event()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "TriangleService":
        """Spawn the dispatcher thread (idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            self._stopping.clear()
            self._thread = threading.Thread(
                target=self._dispatch_loop, name="tc-serve-dispatcher",
                daemon=True,
            )
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True,
             timeout: Optional[float] = 30.0) -> None:
        """Stop admitting and shut the dispatcher down.

        ``drain=True`` (default) serves everything already queued first;
        ``drain=False`` sheds the backlog with reason ``"shutdown"``.
        """
        self._queue.close()
        if not drain:
            for req in self._queue.drain():
                self._shed(req, SHED_SHUTDOWN, "service stopping")
        self._stopping.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        for req in self._queue.drain():  # anything the join left behind
            self._shed(req, SHED_SHUTDOWN, "service stopped")

    def __enter__(self) -> "TriangleService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ---------------------------------------------------------

    def submit(self, kind: str, graph=None, *, tenant: str = "default",
               options: Optional[CountOptions] = None,
               deadline_ms: Optional[float] = None,
               **payload) -> Future:
        """Enqueue one request; returns its future immediately.

        The future resolves to a ``ServeResult``, raises the request's own
        error, or raises ``RequestShed`` when admission control rejects it
        (queue full / deadline / shutdown) — it never blocks forever while
        the service runs. ``kind`` is one of ``KINDS``; "k_truss" takes
        ``k=...``, "update" takes ``handle=...`` and ``updates=[...]`` (and
        no graph — updates target the handle's dynamic session and always
        bypass coalescing).
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
        if kind == "update":
            if graph is not None:
                raise ValueError("update requests target a dynamic-session "
                                 "handle, not a graph")
            handle = payload.get("handle")
            with self._dyn_lock:
                if handle not in self._dyn:
                    raise KeyError(f"unknown dynamic session {handle!r}")
            if "updates" not in payload:
                raise ValueError("update requests need updates=[...]")
        else:
            if graph is None:
                raise ValueError(f"{kind!r} requests need a graph")
            if kind == "k_truss" and "k" not in payload:
                raise ValueError("k_truss requests need k=...")
        opts = options if options is not None else self.options
        if not isinstance(opts, CountOptions):
            raise TypeError(f"options must be a CountOptions, "
                            f"got {type(opts).__name__}")

        ddl_ms = deadline_ms if deadline_ms is not None \
            else self.config.default_deadline_ms
        deadline = (time.perf_counter() + ddl_ms / 1e3
                    if ddl_ms is not None else None)

        fingerprint = graph_fingerprint(graph) if graph is not None else None
        compat_key = None
        if kind == "count":
            lane = self._resolve_lane(graph, opts)
            if self._batchable(lane, opts):
                compat_key = ("count", lane, opts.key())

        req = QueuedRequest(
            request_id=next(self._req_seq), kind=kind, tenant=tenant,
            graph=graph, options=opts, compat_key=compat_key,
            fingerprint=fingerprint, payload=dict(payload),
            deadline=deadline,
        )
        self.metrics.inc("offered")
        reason = self._queue.offer(req)
        if reason is not None:
            self._shed(req, reason,
                       f"depth={self._queue.depth}/{self._queue.max_depth}")
        else:
            self.metrics.inc("accepted")
        return req.future

    def count(self, graph, **kwargs) -> ServeResult:
        """Blocking convenience: ``submit("count", ...).result()``."""
        return self.submit("count", graph, **kwargs).result()

    # -- dynamic sessions ---------------------------------------------------

    def open_dynamic_session(self, graph, *, tenant: str = "default",
                             options: Optional[CountOptions] = None) -> str:
        """Create a per-tenant ``DynamicTriangleCounter`` and return its
        handle; stream batches through ``submit("update", handle=...,
        updates=[...])`` (FIFO per handle — the dispatcher is the only
        executor, so update order is submission order)."""
        opts = options if options is not None else self.options
        if opts.algorithm not in ("auto", "dynamic"):
            opts = opts.replace(algorithm="dynamic")
        handle = f"dyn-{tenant}-{next(self._dyn_seq)}"
        session = DynamicTriangleCounter(graph, opts)
        with self._dyn_lock:
            self._dyn[handle] = session
        return handle

    def close_dynamic_session(self, handle: str) -> None:
        with self._dyn_lock:
            self._dyn.pop(handle)

    # -- warmup / introspection ---------------------------------------------

    def warmup(self, graphs: Iterable, *,
               options: Optional[CountOptions] = None) -> dict:
        """Deterministically prime every cache a request pool will touch.

        Batchable graphs are prepped into the plan cache (fixing the
        coalescer's monotone layout) and one synthetic dispatch runs per
        pow-2 chunk size up to ``max_batch`` plus the single pass-through;
        non-batchable graphs get a counted session in the session cache.
        After a warmup over the pool, steady-state serving compiles
        nothing — ``snapshot()["engine_cache"]["misses"]`` stays flat.
        """
        opts = options if options is not None else self.options
        t0 = time.perf_counter()
        by_key: Dict[tuple, List[tuple]] = {}
        singles = 0
        for g in graphs:
            lane = self._resolve_lane(g, opts)
            fp = graph_fingerprint(g)
            if self._batchable(lane, opts):
                key = ("count", lane, opts.key())
                by_key.setdefault(key, []).append((g, fp))
            else:
                singles += 1
                req = QueuedRequest(
                    request_id=-1, kind="count", tenant="warmup", graph=g,
                    options=opts, compat_key=None, fingerprint=fp,
                    payload={},
                )
                self._session(req).count()
        for key, members in by_key.items():
            self._coalescer.warmup(key, members, opts,
                                   self.config.max_batch)
        return dict(
            seconds=time.perf_counter() - t0,
            batchable=sum(len(m) for m in by_key.values()),
            singles=singles,
            layouts=len(by_key),
        )

    def snapshot(self) -> dict:
        """The full metrics snapshot: request counters, latency stats,
        coalesce factor, engine-cache counters, plus the serve-local plan
        and session cache counters and the live queue depth."""
        snap = self.metrics.snapshot()
        snap["plan_cache"] = self._coalescer.cache_info()
        snap["session_cache"] = (
            self._sessions.info() if self._sessions is not None
            else dict(size=0, maxsize=0, hits=0, misses=0, evictions=0)
        )
        snap["queue_depth"] = self._queue.depth
        return snap

    # -- internals ----------------------------------------------------------

    @staticmethod
    def _batchable(lane: str, opts: CountOptions) -> bool:
        # mirrors TriangleCounter._batchable: the vmapped stacking regime
        return (lane == "intersection" and opts.backend == "jnp"
                and opts.prep_backend == "device")

    @staticmethod
    def _resolve_lane(graph, opts: CountOptions) -> str:
        if opts.algorithm != "auto":
            return opts.algorithm
        if opts.chooser == "measured":
            from repro.core.calibrate import choose_measured
            return choose_measured(graph)
        return registry.choose_algorithm(graph)

    def _shed(self, req: QueuedRequest, reason: str,
              detail: str = "") -> None:
        self.metrics.inc("shed")
        self.metrics.inc(f"shed_{reason}")
        if not req.future.done():
            req.future.set_exception(RequestShed(reason, detail))

    def _session(self, req: QueuedRequest) -> TriangleCounter:
        """The request's ``TriangleCounter``, through the bounded session
        cache (``session_key()``-equal requests share prep + plan)."""
        if self._sessions is None:
            return TriangleCounter(req.graph, req.options)
        key = (req.fingerprint, req.options.key())
        return self._sessions.get_or_build(
            key, lambda: TriangleCounter(req.graph, req.options)
        )

    def _dispatch_loop(self) -> None:
        while True:
            req = self._queue.pop(timeout=0.05)
            if req is None:
                if self._stopping.is_set() and self._queue.depth == 0:
                    return
                continue
            try:
                if req.compat_key is not None:
                    self._dispatch_group(self._collect_group(req))
                else:
                    self._execute_single(req)
            except BaseException as e:  # the loop must outlive any request
                if not req.future.done():
                    self.metrics.inc("errors")
                    req.future.set_exception(e)

    def _collect_group(self, head: QueuedRequest) -> List[QueuedRequest]:
        """Fill the batching window: everything compatible already queued,
        then wait (up to ``batch_window_ms``) for stragglers, flushing
        early once ``max_batch`` is reached or the service is stopping."""
        group = [head]
        limit = self.config.max_batch
        group += self._queue.take_compatible(head.compat_key,
                                             limit - len(group))
        window_end = time.perf_counter() + self.config.batch_window_ms / 1e3
        while len(group) < limit and not self._stopping.is_set():
            remaining = window_end - time.perf_counter()
            if remaining <= 0:
                break
            self._queue.wait_for_arrival(min(remaining, 0.01))
            group += self._queue.take_compatible(head.compat_key,
                                                 limit - len(group))
        return group

    def _dispatch_group(self, group: List[QueuedRequest]) -> None:
        now = time.perf_counter()
        live = []
        for r in group:
            if r.expired(now):
                self._shed(r, SHED_DEADLINE, "deadline expired in queue")
            else:
                live.append(r)
        if not live:
            return
        exec_start = time.perf_counter()
        try:
            prepped = [
                self._coalescer.prep(r.graph, r.fingerprint, r.options)
                for r in live
            ]
            counts, chunk_sizes = self._coalescer.count_group(
                live[0].compat_key, prepped, live[0].options
            )
        except BaseException as e:
            for r in live:
                if not r.future.done():
                    self.metrics.inc("errors")
                    r.future.set_exception(e)
            return
        exec_s = time.perf_counter() - exec_start
        batch_id = next(self._batch_seq)
        chunks = _pow2_chunks(len(live))
        self.metrics.inc("dispatches", len(chunks))
        self.metrics.inc("dispatched_requests", len(live))
        self.metrics.inc("coalesced_requests",
                         sum(c for c in chunks if c >= 2))
        for r, c, bs in zip(live, counts, chunk_sizes):
            self._complete(r, count=int(c), value=None,
                           algorithm="intersection", batch_id=batch_id,
                           batch_size=bs, exec_start=exec_start,
                           exec_s=exec_s)

    def _execute_single(self, req: QueuedRequest) -> None:
        if req.expired():
            self._shed(req, SHED_DEADLINE, "deadline expired in queue")
            return
        exec_start = time.perf_counter()
        try:
            if req.kind == "update":
                with self._dyn_lock:
                    dyn = self._dyn[req.payload["handle"]]
                res = dyn.apply_updates(req.payload["updates"])
                count, value, algorithm = int(res), None, "dynamic"
            else:
                session = self._session(req)
                algorithm = session.algorithm
                count, value = None, None
                if req.kind == "count":
                    r = session.count()
                    count = r.count
                elif req.kind == "vertex":
                    value = session.triangles_per_vertex()
                elif req.kind == "edge_support":
                    value = session.edge_support()
                else:  # k_truss
                    value = session.k_truss(req.payload["k"])
        except BaseException as e:
            self.metrics.inc("errors")
            if not req.future.done():
                req.future.set_exception(e)
            return
        exec_s = time.perf_counter() - exec_start
        self.metrics.inc("dispatches")
        self.metrics.inc("dispatched_requests")
        self._complete(req, count=count, value=value, algorithm=algorithm,
                       batch_id=next(self._batch_seq), batch_size=1,
                       exec_start=exec_start, exec_s=exec_s)

    def _complete(self, req: QueuedRequest, *, count, value, algorithm,
                  batch_id: int, batch_size: int, exec_start: float,
                  exec_s: float) -> None:
        done = time.perf_counter()
        queue_wait = exec_start - req.submitted
        total = done - req.submitted
        self.metrics.observe("queue_wait", queue_wait)
        self.metrics.observe("exec", exec_s)
        self.metrics.observe("total", total)
        self.metrics.inc("completed")
        result = ServeResult(
            request_id=req.request_id, kind=req.kind, tenant=req.tenant,
            count=count, value=value, algorithm=algorithm,
            batch_id=batch_id, batch_size=batch_size,
            queue_wait_s=queue_wait, exec_s=exec_s, total_s=total,
        )
        if not req.future.done():
            req.future.set_result(result)
