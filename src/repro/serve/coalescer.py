"""Compatible-request coalescing: shared prep, stable layouts, one dispatch.

The coalescer is where the service converts a burst of same-options count
requests into the paper's actual throughput story: instead of one device
round-trip per request, every group of compatible requests — same resolved
``CountOptions.key()`` (which folds in the ``ShapePolicy`` layout class) —
is stacked and counted by a single vmapped batch executable, exactly the
``GraphBatch`` fast path, but fed from caches so steady state touches no
host prep and compiles nothing:

* **Prepped-plan cache** — a bounded LRU mapping ``(graph_fingerprint,
  prep-relevant options)`` to the graph's device-resident
  ``DeviceBucket`` list. Repeat requests for a graph the service has seen
  skip ``DeviceGraph`` construction entirely; this is most of the win over
  a per-request facade loop, which re-preps every time.
* **Monotone layouts** — per compatibility key the coalescer remembers the
  union of bucket widths, the max policy-rounded ``e_pad`` per width, and
  the max vertex count seen. The stacked layout only ever *grows* (and
  only when a new graph exceeds its shape class), so once the request pool
  has been seen — or ``warmup()`` has swept it — every group of a given
  size stacks into the same specs and hits the same cached batch
  executable.
* **Pow-2 group decomposition** — a group of k requests dispatches as
  pow-2 chunks (7 → 4 + 2 + 1), bounding the set of batch executables to
  log2(max_batch) per layout instead of one per observed group size. A
  chunk of one skips stacking and replays the graph's own buckets through
  the ordinary single-graph executables (single-request pass-through).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

# The engine's bounded-LRU + bucket helpers are deliberately shared: the
# coalescer must resolve strategies and pad rows byte-identically to
# GraphBatch.from_graphs, or coalesced counts would drift from the facade.
from repro.core.engine import (
    _BoundedLRU,
    _pad_bucket_rows,
    _resolve_bucket_strategy,
    get_batch_executable,
    get_executable,
)
from repro.core import prep

__all__ = ["Coalescer", "PreppedGraph", "prep_cache_key"]


@dataclass
class PreppedGraph:
    """One graph's device-resident prep, reusable across requests."""

    buckets: List[Any]  # List[DeviceBucket]
    n: int
    name: str
    divisor: int  # 6 for the full variant, else 1


def prep_cache_key(fingerprint: str, options) -> tuple:
    """The prepped-plan cache key: graph content + every option the bucket
    layout depends on (variant, widths, shape policy). Strategy and
    bitmap knobs resolve at dispatch, so they deliberately do NOT key the
    prep — forcing ``strategy="probe"`` reuses the same buckets."""
    return (fingerprint, options.variant, options.widths,
            options.resolved_shape_policy.key())


@dataclass
class _Layout:
    """The monotone stacked layout of one compatibility key."""

    e_pads: Dict[int, int] = field(default_factory=dict)  # width -> e_pad
    max_n: int = 0

    def absorb(self, pg: PreppedGraph) -> None:
        self.max_n = max(self.max_n, pg.n)
        for b in pg.buckets:
            self.e_pads[b.width] = max(self.e_pads.get(b.width, 0), b.e_pad)


def _pow2_chunks(k: int) -> List[int]:
    """k as descending powers of two (7 -> [4, 2, 1])."""
    out, p = [], 1
    while p * 2 <= k:
        p *= 2
    while k:
        if p <= k:
            out.append(p)
            k -= p
        p //= 2
    return out


class Coalescer:
    """Grouped counting over the bounded prepped-plan cache (thread-safe;
    the service calls it from the dispatcher thread, tests from anywhere)."""

    def __init__(self, plan_cache_size: int = 128):
        self._plans = _BoundedLRU(plan_cache_size)
        self._layouts: Dict[tuple, _Layout] = {}
        self._lock = threading.Lock()

    # -- prep ---------------------------------------------------------------

    def prep(self, g, fingerprint: str, options) -> PreppedGraph:
        """The graph's ``DeviceBucket`` list, through the bounded cache."""
        key = prep_cache_key(fingerprint, options)

        def build() -> PreppedGraph:
            buckets = prep.prepare_intersection_buckets_device(
                g, variant=options.variant, widths=options.widths,
                policy=options.resolved_shape_policy,
            )
            return PreppedGraph(
                buckets=buckets, n=int(g.n), name=g.name,
                divisor=6 if options.variant == "full" else 1,
            )

        return self._plans.get_or_build(key, build)

    def cache_info(self) -> dict:
        """The prepped-plan cache's size/hits/misses/maxsize/evictions."""
        return self._plans.info()

    # -- counting -----------------------------------------------------------

    def count_group(self, compat_key: tuple, prepped: Sequence[PreppedGraph],
                    options) -> Tuple[List[int], List[int]]:
        """Count a compatible group; returns (counts, chunk_sizes), both
        aligned with ``prepped`` — ``chunk_sizes[i]`` is the size of the
        device dispatch that served request i."""
        with self._lock:
            layout = self._layouts.setdefault(compat_key, _Layout())
            for pg in prepped:
                layout.absorb(pg)
            # freeze this dispatch's view of the (monotone) layout
            e_pads = dict(layout.e_pads)
            id_range = layout.max_n + 2

        counts: List[int] = []
        chunk_sizes: List[int] = []
        pos = 0
        for size in _pow2_chunks(len(prepped)):
            chunk = prepped[pos:pos + size]
            pos += size
            if size == 1:
                counts.append(self._count_single(chunk[0], options))
            else:
                counts.extend(self._count_batch(chunk, options, e_pads,
                                                id_range))
            chunk_sizes.extend([size] * size)
        return counts, chunk_sizes

    def _count_single(self, pg: PreppedGraph, options) -> int:
        """Single-request pass-through: the graph's own bucket shapes, the
        ordinary per-bucket executables (shared with every facade plan)."""
        total = 0
        for b in pg.buckets:
            strat, bits = _resolve_bucket_strategy(
                b.width, pg.n + 2, options.strategy, options.bitmap_bits
            )
            fn = get_executable("intersection", options.backend,
                                options.resolved_interpret, b.shape,
                                strategy=strat, bitmap_bits=bits)
            total += int(fn(b.u_lists, b.v_lists))
        if pg.divisor != 1:
            assert total % pg.divisor == 0, total
            total //= pg.divisor
        return total

    def _count_batch(self, chunk: Sequence[PreppedGraph], options,
                     e_pads: Dict[int, int], id_range: int) -> List[int]:
        """Stack ``chunk`` into the layout and count it in ONE vmapped
        dispatch — the same harmonization as ``GraphBatch.from_graphs``
        (missing widths become all-padding buckets; u=-1/v=-2 never
        match), but against the monotone layout so specs are stable."""
        specs, arrays = [], []
        for w in sorted(e_pads):
            e_pad = e_pads[w]
            us, vs = [], []
            for pg in chunk:
                b = next((b for b in pg.buckets if b.width == w), None)
                if b is None:
                    us.append(jnp.full((e_pad, w), -1, jnp.int32))
                    vs.append(jnp.full((e_pad, w), -2, jnp.int32))
                else:
                    us.append(_pad_bucket_rows(b.u_lists, e_pad, -1))
                    vs.append(_pad_bucket_rows(b.v_lists, e_pad, -2))
            strat, bits = _resolve_bucket_strategy(
                w, id_range, options.strategy, options.bitmap_bits
            )
            specs.append((strat, bits, (e_pad, w)))
            arrays.extend([jnp.stack(us), jnp.stack(vs)])
        if not specs:
            return [0] * len(chunk)
        fn = get_batch_executable(tuple(specs), options.backend,
                                  options.resolved_interpret, len(chunk))
        out = [int(c) for c in fn(*arrays)]
        divisor = 6 if options.variant == "full" else 1
        if divisor != 1:
            assert all(c % divisor == 0 for c in out), out
            out = [c // divisor for c in out]
        return out

    # -- warmup -------------------------------------------------------------

    def warmup(self, compat_key: tuple, graphs_with_fps: Sequence[tuple],
               options, max_batch: int) -> float:
        """Deterministically pre-populate everything steady state needs for
        a request pool: prep + cache every graph (fixing the monotone
        layout), run each through the single pass-through, and dispatch one
        synthetic batch per pow-2 chunk size ≤ ``max_batch`` — after which
        serving any mix of pool graphs in any group size compiles nothing.
        Returns the wall-clock seconds spent."""
        t0 = time.perf_counter()
        prepped = [self.prep(g, fp, options) for g, fp in graphs_with_fps]
        with self._lock:
            layout = self._layouts.setdefault(compat_key, _Layout())
            for pg in prepped:
                layout.absorb(pg)
            e_pads = dict(layout.e_pads)
            id_range = layout.max_n + 2
        for pg in prepped:
            self._count_single(pg, options)
        size = 2
        while size <= max_batch:
            chunk = [prepped[i % len(prepped)] for i in range(size)]
            self._count_batch(chunk, options, e_pads, id_range)
            size *= 2
        return time.perf_counter() - t0
