"""Serving metrics: counters, bounded latency stats, one snapshot dict.

The service records every request's queue-wait / execution / total latency,
admission outcomes (offered / completed / shed-by-reason / errors), and
coalescing effectiveness (device dispatches vs requests they carried).
``MetricsRegistry.snapshot()`` folds in the engine's executable-cache
counters so a single dict answers the three questions ``fig_serve`` asks of
a QPS step: how long do requests wait (p50/p99), how many ride per device
dispatch (coalesce factor), and does steady state recompile anything
(hit/miss deltas).

Everything here is thread-safe under one lock per object; the histograms
keep a bounded reservoir of the most recent samples (default 4096) so a
long-lived service never grows without bound.
"""

from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, List, Optional

__all__ = ["LatencyStat", "MetricsRegistry", "quantile"]

DEFAULT_RESERVOIR = 4096


def quantile(sorted_values: List[float], q: float) -> float:
    """Nearest-rank quantile of an ascending-sorted non-empty list."""
    if not sorted_values:
        raise ValueError("quantile of an empty sample")
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    n = len(sorted_values)
    rank = min(n, max(1, int(math.ceil(q * n))))
    return float(sorted_values[rank - 1])


class LatencyStat:
    """One latency series: exact count/total/max plus a bounded reservoir
    of the most recent samples for the quantile snapshot."""

    def __init__(self, reservoir: int = DEFAULT_RESERVOIR):
        if reservoir < 1:
            raise ValueError(f"reservoir must be >= 1, got {reservoir}")
        self._lock = threading.Lock()
        self._recent: "deque[float]" = deque(maxlen=reservoir)
        self._count = 0
        self._total = 0.0
        self._max = 0.0

    def record(self, seconds: float) -> None:
        s = float(seconds)
        with self._lock:
            self._recent.append(s)
            self._count += 1
            self._total += s
            if s > self._max:
                self._max = s

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def snapshot(self) -> dict:
        """``{count, mean_ms, p50_ms, p90_ms, p99_ms, max_ms}`` (zeros when
        no sample has landed); quantiles come from the bounded reservoir,
        count/mean/max from the exact running totals."""
        with self._lock:
            count, total, mx = self._count, self._total, self._max
            recent = sorted(self._recent)
        if not count:
            return dict(count=0, mean_ms=0.0, p50_ms=0.0, p90_ms=0.0,
                        p99_ms=0.0, max_ms=0.0)
        return dict(
            count=count,
            mean_ms=1e3 * total / count,
            p50_ms=1e3 * quantile(recent, 0.50),
            p90_ms=1e3 * quantile(recent, 0.90),
            p99_ms=1e3 * quantile(recent, 0.99),
            max_ms=1e3 * mx,
        )


class MetricsRegistry:
    """Named counters + named latency series behind one lock.

    Counter names the service uses (all monotone):
      offered / accepted / completed / errors — request admission outcomes
      shed, shed_queue-full, shed_deadline, shed_shutdown — load-shedding,
        total and by reason
      dispatches / dispatched_requests — device dispatches and the requests
        they carried; their ratio is the coalesce factor
      coalesced_requests — requests that shared a dispatch with >= 1 other
    Latency series: queue_wait / exec / total (seconds in, ms out).
    """

    def __init__(self, reservoir: int = DEFAULT_RESERVOIR):
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._latency: Dict[str, LatencyStat] = {}
        self._reservoir = int(reservoir)

    def inc(self, name: str, delta: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(delta)

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            stat = self._latency.get(name)
            if stat is None:
                stat = self._latency[name] = LatencyStat(self._reservoir)
        stat.record(seconds)

    def latency(self, name: str) -> Optional[LatencyStat]:
        with self._lock:
            return self._latency.get(name)

    def coalesce_factor(self) -> float:
        """Mean requests per device dispatch (1.0 = no coalescing yet)."""
        with self._lock:
            d = self._counters.get("dispatches", 0)
            r = self._counters.get("dispatched_requests", 0)
        return (r / d) if d else 1.0

    def snapshot(self) -> dict:
        """One plain dict: counters, per-series latency stats, the coalesce
        factor, and the engine's executable-cache counters (so callers can
        assert the zero-steady-state-recompile contract from here)."""
        from repro.core.engine import executable_cache_info

        with self._lock:
            counters = dict(self._counters)
            latency = dict(self._latency)
        return dict(
            counters=counters,
            latency={name: stat.snapshot() for name, stat in latency.items()},
            coalesce_factor=self.coalesce_factor(),
            engine_cache=executable_cache_info(),
        )
