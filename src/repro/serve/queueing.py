"""Bounded admission: the queue between ``submit()`` and the dispatcher.

Admission control is where the service keeps its two hard promises — never
OOM (depth is bounded; request ``max_queue_depth + 1`` is rejected at the
door, not buffered) and never hang (every request either completes, fails
with its own error, or fails fast with a typed ``RequestShed`` carrying the
reason). The dispatcher side adds the coalescing hook:
``take_compatible`` pulls every queued request sharing a compatibility key
without disturbing the FIFO order of the rest, which is how a batching
window fills from work that is *already waiting* instead of re-sorting the
whole queue.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

__all__ = [
    "AdmissionQueue",
    "QueuedRequest",
    "RequestShed",
    "SHED_DEADLINE",
    "SHED_QUEUE_FULL",
    "SHED_SHUTDOWN",
]

SHED_QUEUE_FULL = "queue-full"
SHED_DEADLINE = "deadline"
SHED_SHUTDOWN = "shutdown"


class RequestShed(RuntimeError):
    """A request the service rejected instead of serving.

    ``reason`` is one of ``"queue-full"`` (admission depth exceeded),
    ``"deadline"`` (the request's deadline budget expired before execution
    started), or ``"shutdown"`` (the service is stopping). Raised out of
    the request's future, never silently dropped.
    """

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        self.detail = detail
        super().__init__(f"request shed ({reason})"
                         + (f": {detail}" if detail else ""))


@dataclasses.dataclass
class QueuedRequest:
    """One admitted request, queue-resident until dispatch."""

    request_id: int
    kind: str              # "count" | "vertex" | "edge_support" | "k_truss"
    #                        | "update"
    tenant: str
    graph: Any             # Graph for graph kinds; None for "update"
    options: Any           # resolved CountOptions
    compat_key: Optional[tuple]  # non-None => coalescible count request
    fingerprint: Optional[str]   # graph content hash (session/plan reuse)
    payload: Dict[str, Any]      # kind-specific extras (k, updates, handle)
    future: Future = dataclasses.field(default_factory=Future)
    submitted: float = dataclasses.field(default_factory=time.perf_counter)
    deadline: Optional[float] = None  # absolute perf_counter seconds

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline is None:
            return False
        return (time.perf_counter() if now is None else now) > self.deadline


class AdmissionQueue:
    """A bounded FIFO with load-shedding admission and compatible-take.

    ``offer`` returns None on admission or the shed reason string when the
    request must be rejected (queue at ``max_depth``, queue closed, or the
    request's deadline already expired at the door) — the caller owns
    failing the future, the queue never buffers a rejected request.
    """

    def __init__(self, max_depth: int):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = int(max_depth)
        self._items: "deque[QueuedRequest]" = deque()
        self._lock = threading.Lock()
        self._arrival = threading.Condition(self._lock)
        self._closed = False

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    def offer(self, req: QueuedRequest) -> Optional[str]:
        """Admit ``req`` (None) or return the shed reason."""
        now = time.perf_counter()
        with self._lock:
            if self._closed:
                return SHED_SHUTDOWN
            if req.expired(now):
                return SHED_DEADLINE
            if len(self._items) >= self.max_depth:
                return SHED_QUEUE_FULL
            self._items.append(req)
            self._arrival.notify_all()
            return None

    def pop(self, timeout: Optional[float] = None) -> Optional[QueuedRequest]:
        """Head of the queue, waiting up to ``timeout`` for an arrival;
        None on timeout (or immediately when closed and empty)."""
        with self._lock:
            if not self._items and not self._closed:
                self._arrival.wait(timeout)
            if self._items:
                return self._items.popleft()
            return None

    def take_compatible(self, compat_key: tuple,
                        limit: int) -> List[QueuedRequest]:
        """Remove and return up to ``limit`` queued requests whose
        ``compat_key`` equals ``compat_key`` (queue order), leaving the
        relative order of everything else untouched."""
        taken: List[QueuedRequest] = []
        if limit <= 0:
            return taken
        with self._lock:
            kept: "deque[QueuedRequest]" = deque()
            while self._items:
                r = self._items.popleft()
                if len(taken) < limit and r.compat_key == compat_key:
                    taken.append(r)
                else:
                    kept.append(r)
            self._items = kept
        return taken

    def wait_for_arrival(self, timeout: float) -> None:
        """Block up to ``timeout`` for the next ``offer`` (or close)."""
        with self._lock:
            self._arrival.wait(timeout)

    def close(self) -> None:
        """Stop admitting; queued items stay poppable (drain)."""
        with self._lock:
            self._closed = True
            self._arrival.notify_all()

    def drain(self) -> List[QueuedRequest]:
        """Remove and return everything still queued (shutdown shedding)."""
        with self._lock:
            items = list(self._items)
            self._items.clear()
            return items
