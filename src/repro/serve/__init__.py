"""``repro.serve`` — the concurrent triangle-counting service layer.

The GraphChallenge framing of the paper's workload is repeated counting
over streams of graphs, not one count: throughput across many inputs is
the figure of merit. This package turns the repo's engine (nine lanes, a
measured auto chooser, vmapped ``GraphBatch`` dispatch, dynamic sessions)
into that serving story:

    TriangleService — accepts concurrent per-tenant requests ("count",
        "vertex", "edge_support", "k_truss", "update"), each resolved by a
        future; see ``repro.serve.service``.
    ServeConfig / ServeResult — the knob bag and the per-request outcome.
    RequestShed — the typed rejection (reasons: queue-full / deadline /
        shutdown) raised by futures the admission queue load-sheds;
        SHED_QUEUE_FULL / SHED_DEADLINE / SHED_SHUTDOWN are the reason
        constants.
    AdmissionQueue — the bounded FIFO with compatible-take
        (``repro.serve.queueing``).
    Coalescer — compatible-request grouping into single vmapped dispatches
        over a bounded prepped-plan cache (``repro.serve.coalescer``).
    MetricsRegistry / LatencyStat — counters + bounded latency stats; the
        service's ``snapshot()`` folds in the engine's executable-cache
        counters (``repro.serve.metrics``).

Benchmarked by ``benchmarks/run.py --figures fig_serve``; documented in
``docs/ARCHITECTURE.md`` §Serving.
"""

from repro.serve.coalescer import Coalescer, PreppedGraph
from repro.serve.metrics import LatencyStat, MetricsRegistry
from repro.serve.queueing import (
    SHED_DEADLINE,
    SHED_QUEUE_FULL,
    SHED_SHUTDOWN,
    AdmissionQueue,
    RequestShed,
)
from repro.serve.service import (
    KINDS,
    ServeConfig,
    ServeResult,
    TriangleService,
)

__all__ = [
    "AdmissionQueue",
    "Coalescer",
    "KINDS",
    "LatencyStat",
    "MetricsRegistry",
    "PreppedGraph",
    "RequestShed",
    "SHED_DEADLINE",
    "SHED_QUEUE_FULL",
    "SHED_SHUTDOWN",
    "ServeConfig",
    "ServeResult",
    "TriangleService",
]
