#!/usr/bin/env python
"""Public-API snapshot gate (the CI `api` job; also runnable locally).

Renders the public surface of `repro.core` — `__all__`, the facade's
signatures (`CountOptions`, `CountResult`, `CounterSession`,
`TriangleCounter`, `DynamicTriangleCounter`), the algorithm registry
contents, and every public callable's signature — and compares it
line-for-line against the committed `docs/api_surface.txt`, so future PRs
change the API deliberately (regenerate + commit the snapshot) rather than
by drift.

Usage:
    PYTHONPATH=src python tools/check_api.py           # verify (CI)
    PYTHONPATH=src python tools/check_api.py --write   # regenerate snapshot

Signatures are rendered without type annotations so the snapshot is stable
across Python versions (annotation repr changed between 3.9 and 3.12).
Exits non-zero with a unified diff on mismatch.
"""

from __future__ import annotations

import argparse
import dataclasses
import difflib
import inspect
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SNAPSHOT = ROOT / "docs" / "api_surface.txt"

sys.path.insert(0, str(ROOT / "src"))

HEADER = "# Public-API snapshot. Regenerate: PYTHONPATH=src python tools/check_api.py --write"


def _sig(fn) -> str:
    """``inspect.signature`` with annotations stripped (version-stable)."""
    sig = inspect.signature(fn)
    params = [p.replace(annotation=inspect.Parameter.empty)
              for p in sig.parameters.values()]
    return str(sig.replace(parameters=params,
                           return_annotation=inspect.Signature.empty))


def _class_block(cls) -> list:
    """One line per dataclass field / public member of ``cls``.

    Members are collected across the MRO (base first, so overrides win),
    keeping inherited surface visible: ``DynamicTriangleCounter`` lists the
    ``CounterSession`` methods it shares with ``TriangleCounter``.
    """
    lines = [f"class {cls.__name__}"]
    if dataclasses.is_dataclass(cls):
        for f in dataclasses.fields(cls):
            if f.default_factory is not dataclasses.MISSING:
                default = "<factory>"
            elif f.default is dataclasses.MISSING:
                default = "<required>"
            else:
                default = repr(f.default)
            lines.append(f"  field {f.name} = {default}")
    members: dict = {}
    for base in reversed(cls.__mro__):
        if base is object:
            continue
        members.update(vars(base))
    for name, member in sorted(members.items()):
        if name.startswith("_"):
            continue
        if isinstance(member, property):
            lines.append(f"  property {name}")
        elif isinstance(member, staticmethod):
            lines.append(f"  def {name}{_sig(member.__func__)} [static]")
        elif callable(member):
            lines.append(f"  def {name}{_sig(member)}")
    return lines


def render() -> str:
    import repro.core as core
    from repro.core import api, options, registry

    lines = [HEADER, "", "[repro.core.__all__]"]
    lines += sorted(core.__all__)

    lines += ["", "[registered algorithms]"]
    lines += list(registry.available_algorithms())

    lines += ["", "[facade]"]
    for cls in (options.CountOptions, api.CountResult, api.CounterSession,
                api.TriangleCounter, api.DynamicTriangleCounter):
        lines += _class_block(cls)

    lines += ["", "[functions]"]
    for name in sorted(core.__all__):
        obj = getattr(core, name)
        if inspect.isclass(obj) or not callable(obj):
            continue
        lines.append(f"def {name}{_sig(obj)}")

    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="regenerate docs/api_surface.txt from the live API")
    args = ap.parse_args()

    current = render()
    if args.write:
        SNAPSHOT.write_text(current, encoding="utf-8")
        print(f"wrote {SNAPSHOT.relative_to(ROOT)}")
        return 0

    if not SNAPSHOT.exists():
        print(f"missing {SNAPSHOT.relative_to(ROOT)}; run with --write")
        return 1
    committed = SNAPSHOT.read_text(encoding="utf-8")
    if committed == current:
        print("api OK: public surface matches docs/api_surface.txt")
        return 0
    diff = difflib.unified_diff(
        committed.splitlines(keepends=True), current.splitlines(keepends=True),
        fromfile="docs/api_surface.txt (committed)",
        tofile="repro.core (live)",
    )
    sys.stdout.writelines(diff)
    print("\napi surface drifted: if intentional, regenerate with "
          "`PYTHONPATH=src python tools/check_api.py --write` and commit")
    return 1


if __name__ == "__main__":
    sys.exit(main())
