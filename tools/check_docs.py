#!/usr/bin/env python
"""Docs gate (the CI `docs` job; also runnable locally):

1. every relative link in README.md and docs/*.md resolves to a real file;
2. the fenced doctest-style quickstart snippet(s) in README.md pass under
   ``python -m doctest``.

Usage: PYTHONPATH=src python tools/check_docs.py
Exits non-zero with one line per failure.
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent

# [text](target) — target captured up to the closing paren, no whitespace
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)
_PY_FENCE_RE = re.compile(r"```python\n(.*?)```", re.S)
_EXTERNAL = ("http://", "https://", "mailto:")


def check_links() -> list:
    """Relative links in README.md and docs/*.md must resolve."""
    errors = []
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for f in files:
        text = f.read_text(encoding="utf-8")
        text = _FENCE_RE.sub("", text)  # code blocks are not links
        for target in _LINK_RE.findall(text):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (f.parent / rel).resolve().exists():
                errors.append(
                    f"{f.relative_to(ROOT)}: broken relative link -> {target}"
                )
    return errors


def check_quickstart_doctest() -> list:
    """Extract ```python fenced blocks containing >>> from README.md and run
    each under `python -m doctest` (the block text is a doctest file)."""
    errors = []
    readme = (ROOT / "README.md").read_text(encoding="utf-8")
    snippets = [b for b in _PY_FENCE_RE.findall(readme) if ">>>" in b]
    if not snippets:
        return ["README.md: no doctest-style ```python quickstart snippet found"]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    for i, snippet in enumerate(snippets):
        with tempfile.NamedTemporaryFile(
            "w", suffix=f".readme-snippet-{i}.txt", delete=False
        ) as fh:
            fh.write(snippet)
            path = fh.name
        try:
            proc = subprocess.run(
                [sys.executable, "-m", "doctest", path],
                capture_output=True, text=True, env=env, timeout=600,
            )
            if proc.returncode != 0:
                errors.append(
                    f"README.md: quickstart snippet {i} failed doctest:\n"
                    f"{proc.stdout}{proc.stderr}"
                )
        finally:
            os.unlink(path)
    return errors


def main() -> int:
    errors = check_links() + check_quickstart_doctest()
    for e in errors:
        print(e)
    if not errors:
        print("docs OK: links resolve, quickstart snippet passes doctest")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
