"""k-truss decomposition + clustering metrics — the paper's motivating
applications of triangle enumeration (§1).

    PYTHONPATH=src python examples/ktruss.py
"""

from repro.graphs import rmat_graph, watts_strogatz_graph
from repro.core import TriangleCounter, k_truss


def main():
    for g in (rmat_graph(10, 8, seed=4), watts_strogatz_graph(2000, 8, 0.05)):
        # clustering metrics ride the session's cached plan (the k-truss
        # peel below still uses listing.py's host-side enumeration — it
        # needs the triangle *lists*, not just counts)
        tc = TriangleCounter(g)
        cc = tc.clustering_coefficients()
        print(f"\n=== {g.name}: n={g.n} m={g.m_undirected}")
        print(f"  mean clustering coefficient: {cc.mean():.4f} "
              f"(small-world signature: {'yes' if cc.mean() > 0.1 else 'no'})")
        print(f"  transitivity: {tc.transitivity():.4f}")
        for k in (3, 4, 5, 6):
            t = k_truss(g, k)
            print(f"  {k}-truss: {t.m_undirected:7d} edges "
                  f"({100.0 * t.m_undirected / max(g.m_undirected,1):5.1f}%)")


if __name__ == "__main__":
    main()
