"""k-truss decomposition + clustering metrics — the paper's motivating
applications of triangle enumeration (§1), all routed through the session.

    PYTHONPATH=src python examples/ktruss.py
"""

from repro.graphs import rmat_graph, watts_strogatz_graph
from repro.core import TriangleCounter


def main():
    for g in (rmat_graph(10, 8, seed=4), watts_strogatz_graph(2000, 8, 0.05)):
        # one session: clustering metrics replay the cached vertex
        # executables, edge_support/k_truss the cached edge executables and
        # the device peel loop — no host-side enumeration anywhere
        tc = TriangleCounter(g)
        cc = tc.clustering_coefficients()
        print(f"\n=== {g.name}: n={g.n} m={g.m_undirected}")
        print(f"  mean clustering coefficient: {cc.mean():.4f} "
              f"(small-world signature: {'yes' if cc.mean() > 0.1 else 'no'})")
        print(f"  transitivity: {tc.transitivity():.4f}")
        _, _, supp = tc.edge_support()
        print(f"  max edge support: {int(supp.max(initial=0))}")
        for k in (3, 4, 5, 6):
            t = tc.k_truss(k)
            print(f"  {k}-truss: {t.m_undirected:7d} edges "
                  f"({100.0 * t.m_undirected / max(g.m_undirected,1):5.1f}%)")
        _, _, trussness = tc.truss_decomposition()
        if trussness.size:
            print(f"  max trussness: {int(trussness.max())}")


if __name__ == "__main__":
    main()
