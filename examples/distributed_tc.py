"""Multi-device triangle counting (the paper's technique on the production
distribution substrate). Uses 8 placeholder CPU devices to demonstrate the
same shard_map decomposition the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/distributed_tc.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

from repro.launch.mesh import make_mesh  # noqa: E402
from repro.graphs import rmat_graph  # noqa: E402
from repro.core import (  # noqa: E402
    CountOptions, TriangleCounter, triangle_count_scipy,
)


def main():
    print(f"devices: {jax.device_count()} × {jax.devices()[0].platform}")
    mesh = make_mesh((4, 2), ("data", "model"))
    g = rmat_graph(12, 8, seed=3)
    truth = triangle_count_scipy(g)
    print(f"graph {g.name}: n={g.n} m={g.m_undirected} truth={truth}")
    # the distributed lanes go through the same front door — select them by
    # name in CountOptions and hand the mesh to the session
    for label, opts in [
        ("distributed masked block-SpGEMM",
         CountOptions(algorithm="matrix_distributed", block=64)),
        ("distributed forward-intersection",
         CountOptions(algorithm="intersection_distributed")),
    ]:
        t0 = time.perf_counter()
        res = TriangleCounter(g, opts, mesh=mesh).count()
        dt = time.perf_counter() - t0
        status = "OK" if res == truth else "MISMATCH"
        print(f"  [{status}] {label}: {res.count}  ({dt*1e3:.1f} ms, "
              f"{mesh.devices.size} devices)")


if __name__ == "__main__":
    main()
