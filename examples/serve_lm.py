"""Batched serving: prefill a prompt batch, then greedy-decode new tokens
through the KV cache (the decode_* dry-run cells exercise exactly this path
at 32k/500k context).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.registry import get_model, get_reduced_config
from repro.train.serve_step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0), dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.vision_tokens, cfg.vision_dim))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.encoder_seq, cfg.d_model))

    max_len = args.prompt_len + args.tokens + 1
    gen = jax.jit(lambda p, b: greedy_generate(
        model, cfg, p, b, steps=args.tokens, max_len=max_len))
    t0 = time.perf_counter()
    out = gen(params, batch)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    total = args.batch * args.tokens
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"generated={args.tokens}/seq")
    print(f"output token ids (first sequence): {out[0].tolist()}")
    print(f"{total} tokens in {dt:.2f}s = {total/dt:.1f} tok/s "
          f"(CPU, includes compile)")


if __name__ == "__main__":
    main()
