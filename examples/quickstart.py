"""Quickstart: count triangles three ways (the paper's three formulations),
then amortize repeated counts through the plan/execute engine.

    PYTHONPATH=src python examples/quickstart.py [--scale 10]
"""

import argparse
import time

from repro.graphs import complete_graph, grid_graph, rmat_graph
from repro.core import (
    plan_triangle_count,
    triangle_count_intersection, triangle_count_matrix,
    triangle_count_subgraph, triangle_count_scipy,
    clustering_coefficients, transitivity, enumerate_triangles,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    args = ap.parse_args()

    # the third graph is dense with a small id range, so strategy="auto"
    # hands its wide bucket to the bitmap core (the first two stay on
    # broadcast/probe) — the per-bucket dispatch printed below
    for g in (rmat_graph(args.scale, 8, seed=1),
              grid_graph(40, spur_fraction=0.3, seed=2),
              complete_graph(100)):
        print(f"\n=== {g.name}: n={g.n} m={g.m_undirected} "
              f"max_deg={g.max_degree} SSD={g.sum_square_degrees}")
        truth = triangle_count_scipy(g)
        for label, fn in [
            ("tc-intersection (forward algorithm)",
             lambda: triangle_count_intersection(g)),
            ("tc-matrix (masked block-SpGEMM)",
             lambda: triangle_count_matrix(g, block=64)),
            ("tc-SM (filter + join)", lambda: triangle_count_subgraph(g)),
        ]:
            t0 = time.perf_counter()
            count = fn()
            dt = time.perf_counter() - t0
            flag = "OK " if count == truth else "BAD"
            print(f"  [{flag}] {label:42s} {count:10d}  ({dt*1e3:7.1f} ms)")

        # plan/execute: host prep + compile once, then device-only replays.
        # strategy="auto" (the default) picks a set-intersection core per
        # degree bucket — broadcast / probe / bitmap — via the documented
        # cost model; count_with_stats() surfaces what it chose.
        plan = plan_triangle_count(g, "intersection")
        count, stats = plan.count_with_stats()  # warms the executable cache
        picks = ", ".join(f"w{w}:{s}" for w, s in stats["bucket_strategies"])
        print(f"  strategy=auto per-bucket dispatch: {picks}")
        t0 = time.perf_counter()
        repeats = 5
        for _ in range(repeats):
            c = plan.count()
            assert c == count
        replay_ms = (time.perf_counter() - t0) * 1e3 / repeats
        print(f"  plan/execute: prep {plan.prep_seconds*1e3:.1f} ms once, "
              f"then {replay_ms:.1f} ms per cached count() "
              f"({plan.num_stages} bucket executables)")

        tris = enumerate_triangles(g)
        cc = clustering_coefficients(g)
        print(f"  enumeration: {tris.shape[0]} triangles listed; "
              f"mean clustering coeff {cc.mean():.4f}; "
              f"transitivity {transitivity(g):.4f}")


if __name__ == "__main__":
    main()
