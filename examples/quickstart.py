"""Quickstart: one front door (`TriangleCounter` + `CountOptions`) over the
paper's three formulations — compare the lanes, let `algorithm="auto"` pick,
then amortize repeated counts through the session's cached plan.

    PYTHONPATH=src python examples/quickstart.py [--scale 10]
"""

import argparse
import time

from repro.graphs import complete_graph, grid_graph, rmat_graph
from repro.core import (
    CountOptions,
    TriangleCounter,
    triangle_count_scipy,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    args = ap.parse_args()

    # three topology classes, three different winners: skewed R-MAT (the
    # intersection lane), a mesh-like grid (the SM lane — 2-core peel
    # collapses the spurs), and a small dense graph (the matrix lane fills
    # whole MXU tiles; its wide bucket also goes to the bitmap core)
    graphs = (rmat_graph(args.scale, 8, seed=1),
              grid_graph(40, spur_fraction=0.3, seed=2),
              complete_graph(100))
    for g in graphs:
        print(f"\n=== {g.name}: n={g.n} m={g.m_undirected} "
              f"max_deg={g.max_degree} SSD={g.sum_square_degrees}")
        truth = triangle_count_scipy(g)

        # every lane through the same front door, one options bag each
        for opts in (CountOptions(algorithm="intersection"),
                     CountOptions(algorithm="matrix", block=64),
                     CountOptions(algorithm="subgraph")):
            t0 = time.perf_counter()
            res = TriangleCounter(g, opts).count()
            dt = time.perf_counter() - t0
            flag = "OK " if res == truth else "BAD"
            print(f"  [{flag}] algorithm={res.algorithm:13s} "
                  f"{res.count:10d}  ({dt*1e3:7.1f} ms)")

        # the cross-lane cost model: CountOptions() means algorithm="auto";
        # CountResult reports the lane it chose and the per-bucket
        # set-intersection strategies the plan stage resolved
        tc = TriangleCounter(g)  # algorithm="auto"
        res = tc.count()
        assert res == truth
        picks = ", ".join(f"w{w}:{s}" for w, s in res.bucket_strategies or [])
        print(f"  auto chose: {res.algorithm}"
              + (f"  (per-bucket dispatch: {picks})" if picks else ""))

        # the session owns ONE plan: replays are device-only
        t0 = time.perf_counter()
        repeats = 5
        for _ in range(repeats):
            assert tc.count() == truth
        replay_ms = (time.perf_counter() - t0) * 1e3 / repeats
        print(f"  session replay: prep {res.prep_seconds*1e3:.1f} ms once, "
              f"then {replay_ms:.1f} ms per cached count()")

        # per-vertex analysis rides the same cached plan (no host-side
        # re-enumeration): clustering + transitivity from one device replay
        cc = tc.clustering_coefficients()
        print(f"  analysis: mean clustering coeff {cc.mean():.4f}; "
              f"transitivity {tc.transitivity():.4f}")

    # batches share the executable cache: same options, many graphs
    batch = [rmat_graph(args.scale - 2, 6, seed=s) for s in range(4)]
    t0 = time.perf_counter()
    results = TriangleCounter(batch[0]).count_many(batch)
    dt = time.perf_counter() - t0
    print(f"\ncount_many over {len(batch)} R-MAT graphs: "
          f"{[r.count for r in results]} ({dt*1e3:.1f} ms; "
          f"same-shaped plans reuse cached executables)")


if __name__ == "__main__":
    main()
