"""Concurrent triangle-counting service: a mixed multi-tenant burst through
``repro.serve.TriangleService`` — coalesced count requests, a per-vertex
analysis request, a dynamic-session update stream, and a deadline-shed
demonstration, ending with the latency/coalesce/shed summary.

    PYTHONPATH=src python examples/serve_tc.py --tenants 4 --requests 32
"""

import argparse
import time

from repro.core import CountOptions
from repro.graphs import rmat_graph
from repro.serve import RequestShed, ServeConfig, TriangleService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--pool", type=int, default=8,
                    help="distinct graphs the tenants request")
    ap.add_argument("--scale", type=int, default=7)
    ap.add_argument("--window-ms", type=float, default=5.0)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    pool = [rmat_graph(args.scale, 6, seed=100 + i, name=f"g{i}")
            for i in range(args.pool)]
    opts = CountOptions(algorithm="intersection")
    svc = TriangleService(opts, config=ServeConfig(
        max_queue_depth=max(64, 2 * args.requests),
        batch_window_ms=args.window_ms, max_batch=args.max_batch))

    t0 = time.perf_counter()
    warm = svc.warmup(pool)
    print(f"warmup: {warm['batchable']} graphs prepped, "
          f"{warm['layouts']} layout(s), {warm['seconds']:.2f}s")

    with svc:
        # the mixed burst: coalescible counts from every tenant...
        futs = [svc.submit("count", pool[i % args.pool],
                           tenant=f"tenant{i % args.tenants}")
                for i in range(args.requests)]
        # ...plus a per-vertex analysis request (single execution)...
        vfut = svc.submit("vertex", pool[0], tenant="tenant0")
        # ...and a dynamic-session update stream (bypasses coalescing)
        handle = svc.open_dynamic_session(pool[1], tenant="tenant1")
        ufut = svc.submit("update", handle=handle,
                          updates=[(0, 1), (1, 2), (0, 2)])

        results = [f.result(timeout=60) for f in futs]
        tri = vfut.result(timeout=60).value
        upd = ufut.result(timeout=60)
        wall = time.perf_counter() - t0

        # a deliberately impossible deadline to show typed load-shedding
        try:
            svc.submit("count", pool[0], deadline_ms=1e-3).result(timeout=60)
            shed_demo = "not shed (machine too fast!)"
        except RequestShed as e:
            shed_demo = f"shed with reason {e.reason!r}"

    counts = {r.tenant: r.count for r in results}
    print(f"{args.requests} counts from {args.tenants} tenants "
          f"over {args.pool} graphs in {wall:.2f}s "
          f"(batch sizes seen: {sorted({r.batch_size for r in results})})")
    print(f"sample counts per tenant: {counts}")
    print(f"per-vertex analysis: n={len(tri)}, total membership "
          f"{int(tri.sum())} (= 3x triangles)")
    print(f"dynamic update batch -> count {upd.count} "
          f"(algorithm={upd.algorithm})")
    print(f"1ms-deadline request: {shed_demo}")

    snap = svc.snapshot()
    lat = snap["latency"]["total"]
    print(f"latency: p50 {lat['p50_ms']:.1f}ms  p99 {lat['p99_ms']:.1f}ms  "
          f"mean {lat['mean_ms']:.1f}ms over {lat['count']} requests")
    print(f"coalesce factor {snap['coalesce_factor']:.2f}  "
          f"shed {snap['counters'].get('shed', 0)}  "
          f"engine cache: {snap['engine_cache']['hits']} hits / "
          f"{snap['engine_cache']['misses']} misses / "
          f"{snap['engine_cache']['evictions']} evictions")


if __name__ == "__main__":
    main()
