"""End-to-end training driver: data pipeline → model → optimizer →
checkpointing → elastic resume. Kill it mid-run and rerun: it resumes from
the latest checkpoint with bit-identical data order.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # tiny CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is a ~100M-param minicpm-family model (the WSD-schedule
arch); tiny fits a single-core CPU smoke budget.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.registry import get_model, get_reduced_config
from repro.train.data import SyntheticDataConfig, SyntheticDataset
from repro.train.elastic import ElasticTrainer, Heartbeat
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def build_cfg(preset: str):
    base = get_reduced_config("minicpm-2b")
    if preset == "tiny":
        return base.replace(name="tiny-lm"), SyntheticDataConfig(8, 129)
    if preset == "100m":
        cfg = base.replace(
            name="lm-100m", num_layers=12, d_model=768, num_heads=12,
            kv_heads=12, d_ff=2048, vocab=32_000, residual_scale=0.4)
        return cfg, SyntheticDataConfig(8, 513)
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()

    cfg, data_cfg = build_cfg(args.preset)
    model = get_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=20,
                          stable_steps=args.steps - 60, decay_steps=40,
                          schedule="wsd", moment_dtype=jnp.float32)
    print(f"model={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={jax.devices()}")

    trainer = ElasticTrainer(
        ckpt_dir=f"{args.ckpt_dir}_{args.preset}",
        save_every=args.save_every,
        heartbeat=Heartbeat(f"{args.ckpt_dir}_{args.preset}.heartbeat",
                            interval_s=5.0))

    def fresh():
        params, opt = init_train_state(model, cfg, opt_cfg,
                                       jax.random.key(0), dtype=jnp.float32)
        return {"params": params, "opt": opt}

    state, start = trainer.resume_or_init(fresh)
    if start:
        print(f"resumed from checkpoint at step {start}")
    ds = SyntheticDataset(cfg, data_cfg, start_step=start)
    step_fn = jax.jit(make_train_step(model, cfg, opt_cfg, microbatches=2))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(ds).items()}
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        trainer.maybe_save(step, state)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{(time.time()-t0):6.1f}s", flush=True)
    trainer.maybe_save(args.steps - 1, state, force=True)
    print("done")


if __name__ == "__main__":
    main()
